# Tier-1 verify — exactly as ROADMAP.md specifies.
PY ?= python

.PHONY: verify bench bench-serve

verify:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# reproduces BOTH serve bench artifacts: BENCH_serve.json (fused vs
# host-loop reference) and BENCH_quant.json (bf16 vs int8 fast path)
bench-serve:
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py --quant int8
