# Tier-1 verify — exactly as ROADMAP.md specifies.
PY ?= python

.PHONY: verify bench bench-serve

verify:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-serve:
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py
