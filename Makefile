# Tier-1 verify — exactly as ROADMAP.md specifies.
PY ?= python

.PHONY: verify lint bench bench-serve bench-train

verify:
	PYTHONPATH=src $(PY) -m pytest -x -q

# repro-lint (DESIGN.md §20): AST invariant passes over src/ — trace
# purity, readback budget, replay determinism, accounting completeness,
# donation safety. Exits nonzero on any finding not justified in
# tools/lint_baseline.txt. Runs in CI before the test suite.
lint:
	PYTHONPATH=src $(PY) tools/repro_lint.py --baseline tools/lint_baseline.txt

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

# reproduces ALL serve bench artifacts: BENCH_serve.json (fused vs
# host-loop reference), BENCH_quant.json (bf16 vs int8 fast path),
# BENCH_serve_paged.json (dense vs paged+prefix-cache on shared prefixes),
# BENCH_serve_spec.json (plain paged vs speculative multi-token decode),
# BENCH_serve_longctx.json (paged flash-prefill kernel: fragmented vs
# contiguous layouts vs the chunked whole-table-gather baseline),
# BENCH_serve_faults.json (chaos tier: one seeded fault arm per kind vs
# the fault-free baseline, DESIGN.md §17), and BENCH_serve_cow.json
# (n-best COW forks vs the duplicate-KV baseline, DESIGN.md §18)
bench-serve:
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py --quant int8
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py --paged
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py --paged --spec-k 4
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py --paged --nbest 4
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py --paged --long-context
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py --chaos

# training fast path (DESIGN.md §13): fused TrainEngine tick vs the
# host-loop autodiff-through-reference Trainer -> BENCH_train.json
bench-train:
	PYTHONPATH=src $(PY) benchmarks/train_bench.py
