"""Shared bench helpers: timing CSV rows + crash-safe JSON emission."""

import json
import os
import tempfile
import time
from typing import Any, Callable, List, Tuple

Row = Tuple[str, float, str]


def atomic_write_json(path: str, payload: Any, *, indent: int = 2) -> None:
    """Write ``payload`` as JSON via tmp-file + fsync + os.replace: a kill
    at ANY instant leaves either the previous complete file or the new
    complete file, never a torn half-write (DESIGN.md §19 — the same
    contract the engine's snapshots honor; CI gates parse these files)."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(prefix=os.path.basename(path) + ".tmp.",
                               dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=indent)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def timed(name: str, fn: Callable, *, reps: int = 5, derived: str = "") -> Row:
    fn()                       # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    dt = (time.perf_counter() - t0) / reps
    return (name, dt * 1e6, derived() if callable(derived) else derived)


def emit(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
