"""Shared timing helper: name,us_per_call,derived CSV rows."""

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timed(name: str, fn: Callable, *, reps: int = 5, derived: str = "") -> Row:
    fn()                       # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    dt = (time.perf_counter() - t0) / reps
    return (name, dt * 1e6, derived() if callable(derived) else derived)


def emit(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
