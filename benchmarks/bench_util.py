"""Shared bench helpers: timing CSV rows, crash-safe JSON emission, and
the schema check the CI smoke gates share."""

import json
import os
import tempfile
import time
from typing import Any, Callable, Iterable, List, Tuple

Row = Tuple[str, float, str]


def required_keys(payload: Any, keys: Iterable[str], *,
                  where: str = "result") -> Any:
    """Assert every key path in ``keys`` exists in ``payload`` and return
    the payload (chainable). Key paths are dotted: ``"paged.j_per_token"``
    descends nested dicts. All missing paths are reported in ONE error so
    a schema drift shows the full damage, not the first casualty — this is
    what the BENCH_*.json smoke gates in verify.yml call instead of
    per-job ad-hoc ``assert key in res`` loops."""
    missing = []
    for path in keys:
        node = payload
        for part in path.split("."):
            if isinstance(node, dict) and part in node:
                node = node[part]
            else:
                missing.append(path)
                break
    if missing:
        raise AssertionError(
            f"{where}: missing required key(s): {', '.join(missing)}; "
            f"have: {sorted(payload) if isinstance(payload, dict) else type(payload).__name__}")
    return payload


def atomic_write_json(path: str, payload: Any, *, indent: int = 2) -> None:
    """Write ``payload`` as JSON via tmp-file + fsync + os.replace: a kill
    at ANY instant leaves either the previous complete file or the new
    complete file, never a torn half-write (DESIGN.md §19 — the same
    contract the engine's snapshots honor; CI gates parse these files)."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(prefix=os.path.basename(path) + ".tmp.",
                               dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=indent)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def timed(name: str, fn: Callable, *, reps: int = 5, derived: str = "") -> Row:
    fn()                       # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    dt = (time.perf_counter() - t0) / reps
    return (name, dt * 1e6, derived() if callable(derived) else derived)


def emit(rows: List[Row]) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
