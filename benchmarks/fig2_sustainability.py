"""Paper Figure 2: break-even (2a) and indifference (2b/2c) surfaces."""

import numpy as np

from repro.core import sustain
from repro.core.sustain import Duty, SECONDS_PER_DAY, SECONDS_PER_YEAR
from benchmarks.bench_util import timed

ACTIVITIES = [0.1, 0.25, 0.5, 0.75, 1.0]
SLEEPS = [0.0, 0.5, 1.0]


def run():
    rows = []
    rm_i = sustain.platform_from_hw("rm_pim", "alexnet", "inference_ternary",
                                    per_module=True)
    ddr = sustain.platform_from_hw("ddr3_pim", "alexnet", "inference_ternary",
                                   per_module=True)

    surf = {}

    def fig2a():
        surf["a"] = sustain.surface(rm_i, ddr, ACTIVITIES, SLEEPS, "breakeven",
                                    ref_throughput=ddr.throughput)
        return surf["a"]

    rows.append(timed("fig2a/breakeven_surface", fig2a,
                      derived=lambda: (
                          f"t_B(a=1)={surf['a'][0, -1] * 365:.0f}d;"
                          f"t_B(a=0.5)={surf['a'][0, -3] * 365:.0f}d;"
                          f"corner={surf['a'][-1, 0]:.1f}yr")))

    for bench, tag in (("alexnet", "fig2b"), ("vgg16", "fig2c")):
        gpu = sustain.platform_from_hw("gpu", bench, "train_fp32")
        rm = sustain.platform_from_hw("rm_pim", bench, "train_fp32")

        def fig(gpu=gpu, rm=rm, store=tag):
            surf[store] = sustain.surface(gpu, rm, ACTIVITIES, SLEEPS,
                                          "indifference",
                                          ref_throughput=rm.throughput)
            return surf[store]

        cross = sustain.crossover_activity(gpu, rm, ref_throughput=rm.throughput)
        rows.append(timed(f"{tag}/indifference_surface_{bench}", fig,
                          derived=f"gpu_beats_rm_above_activity={cross:.3f}"))
    rows.append(("fig2/paper_claims", 0.0,
                 "breakeven~1yr@full;~500d@50%;alexnet crossover 40%;"
                 "vgg crossover 51%;fpga dominated"))
    return rows
