"""Kernel micro-benchmarks: oracle timing + interpret-mode validation.

On this CPU container the Pallas kernels run in interpret mode (Python-speed
— correctness only); the timed path is the jnp oracle, which is also what XLA
executes for the CPU smoke models. TPU wall-times come from the roofline
terms of the dry-run instead.
"""

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.quant import ternary
from benchmarks.bench_util import timed


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # ternary matmul: oracle throughput + kernel-vs-oracle max error
    m, k, n = 256, 2048, 512
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    tw = ternary.ternarize(w)
    oracle_fn = jax.jit(ref.ternary_matmul_ref)
    oracle = lambda: oracle_fn(x, tw.q, tw.scale)
    flops = 2 * m * k * n
    rows.append(timed(
        "kernel/ternary_matmul_oracle", lambda: oracle().block_until_ready(),
        derived=f"shape={m}x{k}x{n};flops={flops:.2e}"))
    kern = ops.ternary_matmul(x, tw)
    err = float(jnp.abs(kern - oracle()).max())
    rows.append(("kernel/ternary_matmul_interpret_vs_oracle", 0.0,
                 f"max_err={err:.2e}"))

    # flash attention oracle + kernel error
    b, s, h, d = 2, 256, 8, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 3), (b, s, 2, d), jnp.float32)
    oracle_fa_fn = jax.jit(partial(ref.attention_ref, scale=d ** -0.5,
                                   causal=True))
    oracle_fa = lambda: oracle_fa_fn(q, kk, v)
    rows.append(timed(
        "kernel/flash_attention_oracle",
        lambda: oracle_fa().block_until_ready(),
        derived=f"shape=b{b}s{s}h{h}kv2d{d}"))
    fa = ops.flash_attention(q, kk, v, causal=True)
    err = float(jnp.abs(fa - oracle_fa()).max())
    rows.append(("kernel/flash_attention_interpret_vs_oracle", 0.0,
                 f"max_err={err:.2e}"))
    return rows
