"""§Roofline: the 40-cell table from the dry-run JSONL + sustainability
columns (the paper's metric applied to the TPU fleet)."""

import json
import os
from typing import Dict, List

from repro.core import energy, grid, hw, lca
from repro.core import roofline as rl

_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
RESULTS = [os.path.join(_DIR, n) for n in
           ("dryrun_baseline.jsonl", "hc_a.jsonl", "hc_b.jsonl",
            "hc_c.jsonl", "hc_extra.jsonl")]


def load_records(paths=None) -> Dict[str, dict]:
    """Latest record per (arch, shape, mesh); §Perf-overridden runs get a
    '+opt' key so baseline and optimized rows coexist."""
    recs: Dict[str, dict] = {}
    for path in paths or RESULTS:
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                key = r["label"] + ("+opt" if r.get("overrides") else "")
                r = dict(r, label=key)
                recs[key] = r
    return recs


def _terms(r: dict) -> rl.RooflineTerms:
    return rl.RooflineTerms(
        flops_per_device=r["flops_per_device"],
        bytes_per_device=r["bytes_per_device"],
        collective_bytes_per_device=r["collective_bytes_per_device"],
        n_devices=r["n_devices"], label=r["label"])


def run():
    rows: List = []
    recs = load_records()
    singles = [r for r in recs.values()
               if r.get("ok") and r["mesh"] == "16x16"]
    if not singles:
        rows.append(("roofline/missing", 0.0,
                     "run launch.dryrun first (results/dryrun_baseline.jsonl)"))
        return rows
    for r in sorted(singles, key=lambda r: r["label"]):
        t = _terms(r)
        se = energy.step_energy(t)
        gco2_1k = {s: energy.carbon_per_1k_steps(t, s) for s in ("NY", "TX")}
        tokens = max(r.get("tokens_per_step", 1.0), 1.0)
        opt = "+opt" if r["label"].endswith("+opt") else ""
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}{opt}", 0.0,
            f"bound={r['bound']};comp={r['compute_s']:.3g}s;"
            f"mem={r['memory_s']:.3g}s;coll={r['collective_s']:.3g}s;"
            f"frac={r['roofline_fraction']:.3f};"
            f"MODEL/HLO={r['useful_flops_ratio']:.2f};"
            f"J/step={se.energy_j:.3g};"
            f"gCO2/1kstep NY={gco2_1k['NY']:.1f} TX={gco2_1k['TX']:.1f};"
            f"J/token={se.energy_j / tokens:.3g}"))
    multi_ok = sum(1 for r in recs.values()
                   if r.get("ok") and r["mesh"] == "2x16x16")
    rows.append(("roofline/multi_pod_pass", 0.0,
                 f"{multi_ok} multi-pod cells compiled OK (pod axis shards)"))
    # fleet embodied amortization headline (the paper's question at scale)
    emb = lca.tpu_package_embodied_mj() * 1e6 * 256
    rows.append(("roofline/fleet_embodied", 0.0,
                 f"256-chip pod embodied={emb/1e9:.1f}GJ="
                 f"{grid.joules_to_gco2(emb, 'NY')/1e6:.1f}tCO2eq(NY fab)"))
    return rows
