# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    from benchmarks import (fig2_sustainability, kernel_bench, roofline_table,
                            serve_bench, table1_gridmix, table2_embodied,
                            table3_efficiency, train_bench)
    from benchmarks.bench_util import emit

    rows = []
    for mod in (table1_gridmix, table2_embodied, table3_efficiency,
                fig2_sustainability, kernel_bench, roofline_table,
                serve_bench, train_bench):
        try:
            rows.extend(mod.run())
        except Exception as e:  # a missing artifact must not hide the rest
            rows.append((f"{mod.__name__}/ERROR", 0.0,
                         f"{type(e).__name__}: {e}"))
    emit(rows)


if __name__ == "__main__":
    main()
