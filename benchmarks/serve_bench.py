"""Serve-core benchmarks: fused vs. reference, bf16 vs. int8, dense vs.
paged, paged vs. speculative.

Four modes on the SAME model and backend:

* default — the fused device-resident engine (one jitted tick, one mask
  readback) against the host-loop reference engine (per-slot ``int(tok)``
  syncs): decode tokens/s and wall-clock-billed J/token. Emits
  ``BENCH_serve.json``.
* ``--quant int8`` — the quantized serving fast path (int8 weights +
  int8 KV cache, DESIGN.md §12) against the bf16-cache baseline: tok/s,
  modeled J/token (FLOPs + per-byte DRAM term — the channel where the byte
  reduction shows; wall-clock J/token reported alongside), resident cache
  bytes, and the teacher-forced token-agreement score vs. the
  full-precision oracle. Emits ``BENCH_quant.json``.
* ``--paged`` — the paged KV cache with prefix reuse (DESIGN.md §14)
  against the dense engine on a **shared-prefix workload** (one system
  prompt, distinct user tails — the millions-of-users serving pattern):
  prefix-hit rate, prefill tokens computed, modeled J/token, saved DRAM
  joules, and the token-agreement score between the two engines. Emits
  ``BENCH_serve_paged.json``.
* ``--paged --spec-k K`` — speculative multi-token decode (DESIGN.md §15)
  against the plain paged engine on the same shared-prefix workload:
  accept rate, emitted tokens per slot-tick, draft vs. verify energy, and
  modeled J/accepted-token — plus the stream-identity check against the
  dense greedy engine (rejection sampling must preserve it exactly).
  Emits ``BENCH_serve_spec.json``.
* ``--chaos`` — the chaos tier (DESIGN.md §17): one seeded fault arm per
  transient kind (plus a deadline-shed arm) against the fault-free
  baseline on the same workload. Gates on the resilience invariant: every
  arm drains in budget with zero crashes, every non-shed stream
  token-identical to the baseline, and quarantine recovery billed as
  nonzero joules. Emits ``BENCH_serve_faults.json``.
* ``--chaos --fault-kind process_kill`` — the durability tier
  (DESIGN.md §19): kill the checkpointed engine mid-workload, restart a
  fresh engine from the latest snapshot + journal replay, and gate on
  every stream being identical to the fault-free baseline with
  ``restore_j > 0``. Emits ``BENCH_serve_restore.json``.
* ``--paged --long-context`` — the long-context tier (DESIGN.md §16) on a
  fragmented-RAG workload (distinct long documents, chunked prefill):
  the paged flash-prefill kernel on a contiguous vs. a maximally
  fragmented page layout, against the chunked whole-table-gather
  baseline. Gates on MODELED prefill throughput (roofline over the
  gather-byte accounting — kernel wall times are meaningless in CPU
  interpret mode): fragmented within 5% of contiguous, and >= 1.3x the
  gather baseline. Emits ``BENCH_serve_longctx.json``.

    PYTHONPATH=src python benchmarks/serve_bench.py \
        [--quant int8|--paged [--spec-k K|--long-context]|--chaos] [--seed N]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.bench_util import atomic_write_json
except ImportError:          # run as `python benchmarks/serve_bench.py`
    from bench_util import atomic_write_json

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
OUT_QUANT_PATH = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_quant.json")
OUT_PAGED_PATH = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_serve_paged.json")
OUT_SPEC_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_serve_spec.json")
OUT_LONGCTX_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_serve_longctx.json")
OUT_FAULTS_PATH = os.path.join(os.path.dirname(__file__), "..",
                               "BENCH_serve_faults.json")
OUT_COW_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serve_cow.json")
OUT_RESTORE_PATH = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_serve_restore.json")

# ONE explicit seed feeds every stochastic input of the bench — workload
# prompt draws AND the engines' sampling streams (ServeConfig.seed). Same
# --seed, same tokens, byte-identical BENCH json; the chaos arms depend on
# this to diff fault runs against the fault-free baseline.
SEED = 0

N_REQUESTS = 12
MAX_TOKENS = 16
MAX_SLOTS = 4
MAX_LEN = 64

# long-context tier workload (DESIGN.md §16): long distinct documents,
# chunked prefill — the gather-heavy fragmented-RAG shape. The pool gets
# headroom beyond the dense-equivalent so compaction can find contiguous
# free runs.
LC_REQUESTS = 8
LC_MAX_TOKENS = 4
LC_SLOTS = 4
LC_MAX_LEN = 256
LC_PAGE = 8
LC_CHUNK = 32
LC_NUM_PAGES = LC_SLOTS * (LC_MAX_LEN // LC_PAGE) + 24


def _model():
    from repro.models import transformer as tf_lib
    # d_model 128 / head_dim 16: wide enough that int8 quantization noise
    # averages out (token agreement >= 99% vs fp, the documented bound)
    # while still CPU-benchmarkable
    cfg = tf_lib.LMConfig(name="bench", d_model=128, n_heads=8, n_kv_heads=4,
                          d_ff=256, vocab=128, pattern=(tf_lib.BlockSpec(),),
                          repeats=2, remat="none", vocab_pad_multiple=1)
    params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                            dtype=jnp.float32).params
    return cfg, params


def _workload(eng, seed=None):
    rng = np.random.default_rng(SEED if seed is None else seed)
    for _ in range(N_REQUESTS):
        prompt = rng.integers(0, 100, size=int(rng.integers(4, 12)))
        eng.submit(prompt, max_tokens=MAX_TOKENS)


def _measure(make_engine):
    """Warm up (compile) and measure on the SAME engine instance — jit
    caches are per-engine closures, so a long-lived server is the honest
    steady state to time."""
    from repro.core import accounting
    eng = make_engine(None)
    _workload(eng)
    eng.run_until_drained()                  # compiles tick + admit buckets
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=1, grid_mix="NY"))
    eng.accountant = acct
    eng.metrics_log = []
    _workload(eng)
    done = eng.run_until_drained()
    assert len(done) == N_REQUESTS
    toks = sum(m.tokens for m in eng.metrics_log)
    wall = sum(m.wall_s for m in eng.metrics_log)
    rep = acct.report()
    return {"decode_tokens": toks,
            "wall_s": round(wall, 4),
            "decode_tokens_per_s": round(toks / wall, 2),
            "j_per_token": rep["j_per_token"],
            "ticks": len(eng.metrics_log)}


def bench() -> dict:
    from repro.serve import ReferenceEngine, ServeConfig, ServeEngine
    cfg, params = _model()

    def fused(acct):
        return ServeEngine(params, cfg,
                           ServeConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                                       seed=SEED),
                           accountant=acct)

    def reference(acct):
        return ReferenceEngine(params, cfg,
                               ServeConfig(max_slots=MAX_SLOTS,
                                           max_len=MAX_LEN, seed=SEED),
                               accountant=acct)

    res = {
        "workload": {"requests": N_REQUESTS, "max_tokens": MAX_TOKENS,
                     "slots": MAX_SLOTS, "backend": jax.default_backend()},
        "fused": _measure(fused),
        "reference": _measure(reference),
    }
    res["speedup_decode_tok_s"] = round(
        res["fused"]["decode_tokens_per_s"]
        / res["reference"]["decode_tokens_per_s"], 2)
    res["j_per_token_ratio"] = round(
        res["reference"]["j_per_token"] / res["fused"]["j_per_token"], 2)
    atomic_write_json(OUT_PATH, res)
    return res


def bench_quant() -> dict:
    """bf16-cache baseline vs. the int8 fast path on the same workload."""
    from repro.core import accounting
    from repro.serve import ServeConfig, ServeEngine, token_agreement
    cfg, params = _model()

    def arm(quant):
        if quant == "none":
            # honest bf16 baseline: bf16 weights AND bf16 KV cache (the
            # int8 arm quantizes the fp32 tree itself)
            arm_params = jax.tree.map(
                lambda a: a.astype(jnp.bfloat16)
                if a.dtype == jnp.float32 else a, params)
            cache_dtype = jnp.bfloat16
        else:
            arm_params, cache_dtype = params, jnp.float32
        eng = ServeEngine(arm_params, cfg,
                          ServeConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                                      cache_dtype=cache_dtype, quant=quant,
                                      seed=SEED))
        _workload(eng)
        eng.run_until_drained()              # warm: compile tick + buckets
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng.accountant = acct
        eng.metrics_log = []
        _workload(eng)
        done = eng.run_until_drained()
        assert len(done) == N_REQUESTS
        toks = sum(m.tokens for m in eng.metrics_log)
        wall = sum(m.wall_s for m in eng.metrics_log)
        rep = acct.report()
        return {"decode_tokens": toks,
                "decode_tokens_per_s": round(toks / wall, 2),
                "j_per_token": rep["modeled_j_per_token"],
                "j_per_token_wall": rep["j_per_token"],
                "bytes_moved": rep["bytes_moved"],
                "modeled_dram_j": rep["modeled_dram_j"],
                "modeled_compute_j": rep["modeled_compute_j"],
                "kv_cache_bytes": eng.kv_cache_bytes,
                "weight_bytes": eng.weight_bytes}

    rng = np.random.default_rng(SEED + 1)
    prompts = rng.integers(0, 100, size=(25, 8))
    agreement = token_agreement(params, cfg, prompts, n_tokens=24)
    res = {
        "workload": {"requests": N_REQUESTS, "max_tokens": MAX_TOKENS,
                     "slots": MAX_SLOTS, "backend": jax.default_backend()},
        "notes": ("j_per_token is the modeled FLOPs + per-byte DRAM energy "
                  "(core/energy.py, DESIGN.md §12) billed from dtype-aware "
                  "per-tick traffic; j_per_token_wall is wall-clock x "
                  "device power on this (CPU test) backend."),
        "bf16": arm("none"),
        "int8": arm("int8"),
        "token_agreement_vs_fp": agreement,
    }
    res["kv_cache_bytes_ratio"] = round(
        res["bf16"]["kv_cache_bytes"] / res["int8"]["kv_cache_bytes"], 2)
    res["weight_bytes_ratio"] = round(
        res["bf16"]["weight_bytes"] / res["int8"]["weight_bytes"], 2)
    res["j_per_token_ratio"] = round(
        res["bf16"]["j_per_token"] / res["int8"]["j_per_token"], 2)
    atomic_write_json(OUT_QUANT_PATH, res)
    return res


def _shared_prefix_prompts(prefix_len=24, tail_len=6):
    """One shared system prompt + distinct per-request tails — the
    serving pattern where prefix caching pays (DESIGN.md §14)."""
    rng = np.random.default_rng(SEED + 7)
    sys_prompt = rng.integers(0, 100, size=prefix_len)
    return [np.concatenate([sys_prompt, rng.integers(0, 100, size=tail_len)])
            for _ in range(N_REQUESTS)]


def bench_paged(prefix_len=24, tail_len=6) -> dict:
    """Dense vs. paged+prefix-cache on the shared-prefix workload."""
    from repro.core import accounting
    from repro.serve import (ServeConfig, ServeEngine, generation_agreement,
                             run_workload)
    cfg, params = _model()
    prompts = _shared_prefix_prompts(prefix_len, tail_len)

    def arm(paged):
        scfg = (ServeConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                            paged=True, page_size=8, seed=SEED)
                if paged else
                ServeConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                            seed=SEED))
        eng = ServeEngine(params, cfg, scfg)
        # warm: compile + prime the prefix cache (the steady state a
        # long-lived server serves from)
        run_workload(eng, prompts, max_tokens=MAX_TOKENS)
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng.accountant = acct
        eng.metrics_log = []
        gens = run_workload(eng, prompts, max_tokens=MAX_TOKENS)
        assert len(gens) == N_REQUESTS
        toks = sum(m.tokens for m in eng.metrics_log)
        wall = sum(m.wall_s for m in eng.metrics_log)
        rep = acct.report()
        out = {"decode_tokens": toks,
               "decode_tokens_per_s": round(toks / wall, 2),
               "prefill_tokens": sum(m.prefill_tokens
                                     for m in eng.metrics_log),
               "j_per_token": rep["modeled_j_per_token"],
               "j_per_token_wall": rep["j_per_token"],
               "bytes_moved": rep["bytes_moved"],
               "modeled_dram_j": rep["modeled_dram_j"]}
        if paged:
            out.update(prefix_hit_tokens=rep["prefix_hit_tokens"],
                       prefix_hit_rate=round(rep["prefix_hit_rate"], 4),
                       saved_bytes=rep["saved_bytes"],
                       saved_dram_j=rep["saved_dram_j"])
        return out, gens

    dense_m, dense_g = arm(False)
    paged_m, paged_g = arm(True)
    # uids differ across engines only by submission order (identical here)
    agreement = generation_agreement(paged_g, dense_g)
    res = {
        "workload": {"requests": N_REQUESTS, "max_tokens": MAX_TOKENS,
                     "slots": MAX_SLOTS, "prefix_len": prefix_len,
                     "tail_len": tail_len,
                     "backend": jax.default_backend()},
        "notes": ("shared-prefix workload: one system prompt + distinct "
                  "tails. j_per_token is modeled FLOPs + per-byte DRAM "
                  "energy (deterministic); the paged engine admits only "
                  "each prompt's non-shared suffix after the first "
                  "request primes the prefix cache."),
        "dense": dense_m,
        "paged": paged_m,
        "token_agreement": agreement,
    }
    res["prefill_token_ratio"] = round(
        dense_m["prefill_tokens"] / max(paged_m["prefill_tokens"], 1), 2)
    res["speedup"] = round(dense_m["j_per_token"] / paged_m["j_per_token"], 3)
    res["wall_speedup"] = round(dense_m["j_per_token_wall"]
                                / paged_m["j_per_token_wall"], 2)
    atomic_write_json(OUT_PAGED_PATH, res)
    return res


def bench_spec(spec_k=4, prefix_len=24, tail_len=6) -> dict:
    """Plain paged vs. speculative (ngram-drafted) paged decode on the
    shared-prefix workload (DESIGN.md §15). The acceptance bar: emitted
    tokens per slot-tick > 1.0 (plain decode is exactly 1.0) and a lower
    modeled J per emitted token than the PR-4 paged baseline — one weight
    stream now commits up to spec_k + 1 tokens per slot."""
    from repro.core import accounting
    from repro.serve import (ServeConfig, ServeEngine, generation_agreement,
                             run_workload)
    cfg, params = _model()
    prompts = _shared_prefix_prompts(prefix_len, tail_len)

    def arm(k):
        scfg = ServeConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN,
                           paged=True, page_size=8, spec_k=k, seed=SEED)
        eng = ServeEngine(params, cfg, scfg)
        run_workload(eng, prompts, max_tokens=MAX_TOKENS)   # warm/compile
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng.accountant = acct
        eng.metrics_log = []
        gens = run_workload(eng, prompts, max_tokens=MAX_TOKENS)
        assert len(gens) == N_REQUESTS
        s = eng.summary()
        rep = acct.report()
        out = {"decode_tokens": s["decode_tokens"],
               "decode_tokens_per_s": round(s["decode_tokens_per_s"], 2),
               "ticks": s["ticks"],
               "j_per_token": rep["modeled_j_per_token"],
               "j_per_token_wall": rep["j_per_token"],
               "bytes_moved": rep["bytes_moved"],
               "modeled_dram_j": rep["modeled_dram_j"]}
        if k > 0:
            out.update(accept_rate=round(s["accept_rate"], 4),
                       accepted_tokens_per_tick=round(
                           s["accepted_tokens_per_tick"], 4),
                       spec_draft_tokens=s["spec_draft_tokens"],
                       spec_accepted_tokens=s["spec_accepted_tokens"],
                       j_per_accepted_token=rep["spec"]
                       ["j_per_accepted_token"],
                       draft_j=rep["spec"]["draft_j"],
                       verify_j=rep["spec"]["verify_j"])
        return out, gens

    paged_m, paged_g = arm(0)
    spec_m, spec_g = arm(spec_k)
    # greedy rejection sampling must reproduce the plain stream exactly
    agreement = generation_agreement(spec_g, paged_g)
    res = {
        "workload": {"requests": N_REQUESTS, "max_tokens": MAX_TOKENS,
                     "slots": MAX_SLOTS, "prefix_len": prefix_len,
                     "tail_len": tail_len, "spec_k": spec_k,
                     "drafter": "ngram",
                     "backend": jax.default_backend()},
        "notes": ("speculative paged decode vs the plain paged engine on "
                  "the shared-prefix workload. accepted_tokens_per_tick "
                  "is emitted decode tokens per slot-tick (plain = 1.0); "
                  "j_per_accepted_token is modeled FLOPs + per-byte DRAM "
                  "energy per emitted token; draft_j/verify_j split the "
                  "decode bill by phase (DESIGN.md §15)."),
        "paged": paged_m,
        "spec": spec_m,
        "token_agreement": agreement,
        "accept_rate": spec_m["accept_rate"],
        "j_per_accepted_token": spec_m["j_per_accepted_token"],
    }
    res["speedup"] = round(
        paged_m["j_per_token"] / spec_m["j_per_accepted_token"], 3)
    res["tick_ratio"] = round(paged_m["ticks"] / max(spec_m["ticks"], 1), 2)
    atomic_write_json(OUT_SPEC_PATH, res)
    return res


def bench_longctx() -> dict:
    """Long-context tier (DESIGN.md §16): three arms on the same
    fragmented-RAG workload (distinct long documents, chunked prefill,
    no shareable prefix):

    * ``chunked_gather`` — the PR-4 XLA extend path: every prefill chunk
      materializes the FULL page-table window per layer;
    * ``kernel_contiguous`` — the paged flash-prefill kernel, free list
      sorted so every slot gets one ascending page run;
    * ``kernel_fragmented`` — the same kernel on a deterministically
      shuffled free list (maximal fragmentation), with page-table
      compaction enabled.

    The gate rides on MODELED prefill throughput — a roofline over the
    engine's gather-byte accounting at TPU v5e constants — because the
    kernel runs in interpret mode on CPU backends, where wall time
    measures the Pallas interpreter, not the machine. Wall numbers are
    reported untrusted. The kernel's page-granular gather makes its
    modeled bytes IDENTICAL across layouts (the whole point: prefill
    cost independent of fragmentation, DMA locality aside), while the
    gather baseline pays the whole table width every chunk."""
    from repro.core import accounting, energy, hw
    from repro.serve import ServeConfig, ServeEngine, generation_agreement, \
        run_workload
    cfg, params = _model()
    rng = np.random.default_rng(SEED + 11)
    prompts = [rng.integers(0, 100, size=int(n))
               for n in rng.integers(100, 180, size=LC_REQUESTS)]

    def arm(kernel: bool, frag: bool, compact: float = 0.0) -> tuple:
        scfg = ServeConfig(max_slots=LC_SLOTS, max_len=LC_MAX_LEN,
                           paged=True, page_size=LC_PAGE,
                           num_pages=LC_NUM_PAGES,
                           prefill_chunk=LC_CHUNK, prefix_cache=False,
                           decode_kernel=kernel,
                           compact_threshold=compact, seed=SEED)
        eng = ServeEngine(params, cfg, scfg)
        run_workload(eng, prompts, max_tokens=LC_MAX_TOKENS)   # warm/compile
        # deterministic page layout for the measured pass: ascending run
        # (pool pops from the list tail) or seeded max-fragmentation
        rs = np.random.default_rng(SEED + 13)
        free = sorted(eng.pool._free)
        eng.pool._free = (list(rs.permutation(free)) if frag
                          else sorted(free, reverse=True))
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng.accountant = acct
        eng.metrics_log = []
        gens = run_workload(eng, prompts, max_tokens=LC_MAX_TOKENS)
        assert len(gens) == LC_REQUESTS
        s = eng.summary()
        ptoks = s["prefill_tokens"]
        flops = sum(2.0 * eng._matmul_elems * len(p)
                    + 2.0 * eng._n_attn * eng._attn_dims * float(len(p)) ** 2
                    for p in prompts)
        n_admit = sum(1 for m in eng.metrics_log if m.prefill_tokens > 0)
        # prefill DRAM bill: cached-window gather (the fragmentation-
        # sensitive term) + chunk KV writes + one weight stream per admit
        # tick + the compaction copies this layout forced
        pre_bytes = (s["prefill_gather_bytes"]
                     + eng._kv_token_bytes * ptoks
                     + eng.weight_bytes * n_admit
                     + 2.0 * s["compaction_moves"] * LC_PAGE
                     * eng._kv_token_bytes)
        t_model = max(pre_bytes / hw.TPU_HBM_BW, flops / hw.TPU_PEAK_FLOPS)
        pre_j = energy.dram_energy_j(pre_bytes) + energy.compute_energy_j(
            flops)
        wall = sum(m.wall_s for m in eng.metrics_log)
        out = {"prefill_tokens": ptoks,
               "prefill_gather_bytes": s["prefill_gather_bytes"],
               "prefill_dram_bytes": pre_bytes,
               "modeled_prefill_s": t_model,
               "modeled_prefill_tok_s": round(ptoks / t_model, 1),
               "modeled_prefill_j_per_token": pre_j / max(ptoks, 1),
               "compaction_moves": s["compaction_moves"],
               "decode_tokens": s["decode_tokens"],
               "wall_s_untrusted": round(wall, 4),
               "ticks": s["ticks"]}
        return out, gens

    base_m, base_g = arm(kernel=False, frag=True)
    contig_m, contig_g = arm(kernel=True, frag=False)
    frag_m, frag_g = arm(kernel=True, frag=True, compact=0.3)
    agree_cf = generation_agreement(frag_g, contig_g)
    agree_kb = generation_agreement(frag_g, base_g)
    res = {
        "workload": {"requests": LC_REQUESTS, "max_tokens": LC_MAX_TOKENS,
                     "slots": LC_SLOTS, "max_len": LC_MAX_LEN,
                     "page_size": LC_PAGE, "prefill_chunk": LC_CHUNK,
                     "num_pages": LC_NUM_PAGES,
                     "prompt_lens": [len(p) for p in prompts],
                     "backend": jax.default_backend()},
        "notes": ("fragmented-RAG long-context workload (distinct "
                  "documents, chunked prefill, prefix cache off). "
                  "modeled_prefill_tok_s is a TPU v5e roofline over the "
                  "engine's gather-byte accounting (DESIGN.md §16); "
                  "wall_s_untrusted measures the Pallas interpreter on "
                  "non-TPU backends, not the machine."),
        "chunked_gather": base_m,
        "kernel_contiguous": contig_m,
        "kernel_fragmented": frag_m,
        "frag_vs_contig_ratio": round(
            frag_m["modeled_prefill_tok_s"]
            / contig_m["modeled_prefill_tok_s"], 4),
        "kernel_vs_gather_speedup": round(
            frag_m["modeled_prefill_tok_s"]
            / base_m["modeled_prefill_tok_s"], 3),
        "token_agreement_frag_vs_contig": agree_cf,
        "token_agreement_vs_gather": agree_kb,
    }
    atomic_write_json(OUT_LONGCTX_PATH, res)
    return res


def bench_cow(nbest=MAX_SLOTS) -> dict:
    """Copy-on-write n-best tier (DESIGN.md §18): fork each request into
    ``nbest`` decode streams sharing prompt KV pages copy-on-write, against
    the duplicate-KV baseline that submits the same prompt ``nbest`` times
    as independent requests (prefix cache OFF in both arms, so the baseline
    genuinely re-prefills and re-stores every copy — the COW channel is
    isolated from the §14 prefix-cache win). The gate: every fork's stream
    token-identical to its independent-decode twin (greedy forks share the
    canonical rng path), KV bytes moved strictly below the baseline's, and
    a clean ``PagePool.audit()`` at drain."""
    from repro.core import accounting
    from repro.serve import ServeConfig, ServeEngine
    cfg, params = _model()
    # prompts long relative to the decode budget: the COW win is the
    # duplicate PROMPT KV the forks never write, bought at ~one boundary-
    # page copy per fork — prompt length is the lever (DESIGN.md §18).
    # nbest defaults to MAX_SLOTS so both arms admit in the same number
    # of full slot waves: the XLA extend path bills a fixed full-table
    # gather per admit CALL, and mismatched wave counts would smear that
    # scheduling artifact into the COW comparison.
    n_req = 6
    rng = np.random.default_rng(SEED + 19)
    prompts = [rng.integers(0, 100, size=int(rng.integers(28, 44)))
               for _ in range(n_req)]

    def measure(submit_fn, n_expected):
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=MAX_SLOTS, max_len=MAX_LEN, paged=True, page_size=8,
            prefix_cache=False, seed=SEED))
        submit_fn(eng)
        eng.run_until_drained()              # warm: compile tick + buckets
        acct = accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))
        eng.accountant = acct
        eng.metrics_log = []
        uids = submit_fn(eng)
        done = eng.run_until_drained()
        assert len(done) == n_expected
        assert eng.pool.audit() == [], eng.pool.audit()
        assert eng.pool.live == 0
        by_uid = {r.uid: r for r in done}
        s = eng.summary()
        rep = acct.report()
        kv_bytes = sum(m.kv_bytes for m in eng.metrics_log)
        out = {"decode_tokens": s["decode_tokens"],
               "prefill_tokens": s["prefill_tokens"],
               "ticks": s["ticks"],
               "kv_bytes": kv_bytes,
               "bytes_moved": rep["bytes_moved"],
               "j_per_token": rep["modeled_j_per_token"],
               "j_per_token_wall": rep["j_per_token"],
               "cow_bytes": rep["cow_bytes"],
               "cow_copies": rep["cow_copies"],
               "forks": rep["forks"],
               "fork_saved_bytes": rep["fork_saved_bytes"],
               "fork_saved_dram_j": rep["fork_saved_dram_j"]}
        return out, [by_uid[u] for u in uids]

    def submit_cow(eng):
        return [eng.submit(p, max_tokens=MAX_TOKENS, n_best=nbest)
                for p in prompts]

    def submit_dup(eng):
        return [eng.submit(p, max_tokens=MAX_TOKENS)
                for p in prompts for _ in range(nbest)]

    dup_m, dup_reqs = measure(submit_dup, n_req * nbest)
    cow_m, cow_reqs = measure(submit_cow, n_req)
    # per-fork agreement: fork j of request i vs. its independent twin
    # (greedy — every independent copy of a prompt decodes identically)
    agree = total = 0
    ident = True
    for i, r in enumerate(cow_reqs):
        assert r.nbest is not None and len(r.nbest) == nbest
        for j, stream in enumerate(r.nbest):
            twin = list(dup_reqs[i * nbest + j].generated)
            stream = list(stream)
            ident &= stream == twin
            total += max(len(stream), len(twin))
            agree += sum(1 for x, y in zip(stream, twin) if x == y)
    res = {
        "workload": {"requests": n_req, "nbest": nbest,
                     "max_tokens": MAX_TOKENS, "slots": MAX_SLOTS,
                     "page_size": 8, "prefix_cache": False,
                     "prompt_lens": [len(p) for p in prompts],
                     "backend": jax.default_backend()},
        "notes": ("n-best COW forks vs. the duplicate-KV baseline "
                  "(same prompt submitted nbest times independently, "
                  "prefix cache off in both arms). kv_bytes_ratio > 1 is "
                  "the duplicate prompt-KV traffic the forks avoided by "
                  "sharing pages; cow_bytes is what fork isolation cost "
                  "in first-write page copies (DESIGN.md §18)."),
        "duplicate": dup_m,
        "cow": cow_m,
        "per_fork_agreement": agree / total if total else 1.0,
        "streams_identical": bool(ident),
    }
    res["kv_bytes_ratio"] = round(dup_m["kv_bytes"] / cow_m["kv_bytes"], 3)
    res["j_per_token_ratio"] = round(
        dup_m["j_per_token"] / cow_m["j_per_token"], 3)
    assert ident, "a fork diverged from its independent-decode twin"
    assert res["kv_bytes_ratio"] > 1.0, res["kv_bytes_ratio"]
    assert cow_m["forks"] == n_req * (nbest - 1)
    atomic_write_json(OUT_COW_PATH, res)
    return res


def bench_chaos() -> dict:
    """Chaos tier (DESIGN.md §17): one arm per fault kind against the
    fault-free baseline on the SAME seeded workload, plus a deadline-shed
    arm. The gate is the resilience invariant itself:

    * every arm drains within a bounded tick budget (no crash, no
      admission livelock);
    * every non-shed request's token stream is IDENTICAL to the
      fault-free baseline — detection + quarantine re-decode must be
      invisible in content, visible only in the energy bill;
    * arms that quarantined bill recovery_j > 0 (the J/token cost of
      resilience is measured, not hand-waved).
    """
    from repro.serve import (TRANSIENT_FAULT_KINDS, FaultPlan, ServeConfig,
                             ServeEngine, generation_agreement, run_workload)
    cfg, params = _model()
    rng = np.random.default_rng(SEED + 3)
    prompts = [rng.integers(0, 100, size=int(rng.integers(6, 14)))
               for _ in range(N_REQUESTS)]

    def arm(plan, deadline=None):
        eng = ServeEngine(params, cfg, ServeConfig(
            max_slots=MAX_SLOTS, max_len=MAX_LEN, paged=True, page_size=8,
            seed=SEED, faults=plan))
        if deadline is None:
            gens = run_workload(eng, prompts, max_tokens=MAX_TOKENS,
                                max_ticks=800)
        else:
            for p in prompts:
                eng.submit(np.asarray(p, np.int32), max_tokens=MAX_TOKENS,
                           deadline_ticks=deadline)
            done = eng.run_until_drained(max_ticks=800)
            gens = {r.uid: list(r.generated) for r in done}
        return eng.summary(), gens

    base_s, base_g = arm(None)
    arms = {}
    # process_kill is the one kind no in-tick rung recovers from — its arm
    # is the kill-and-restart bench (--fault-kind process_kill,
    # DESIGN.md §19), which needs a checkpointed engine to restore into
    for kind in TRANSIENT_FAULT_KINDS:
        plan = FaultPlan.single(kind, tick=3, seed=SEED + 17)
        s, gens = arm(plan)
        agree = generation_agreement(gens, base_g)
        arms[kind] = {
            "faults_injected": s["faults_injected"],
            "quarantined": s["quarantined"],
            "shed": s["shed"],
            "recovery_tokens": s["recovery_tokens"],
            "recovery_j": s["recovery_j"],
            "recovery_j_per_token": s["recovery_j_per_token"],
            "degraded_ticks": s["degraded_ticks"],
            "readback_retries": s["readback_retries"],
            "ticks": s["ticks"],
            "streams_identical": bool(agree["identical"]),
        }
        assert s["faults_injected"] > 0, kind
        assert agree["identical"], (kind, "stream diverged from baseline")
        if s["quarantined"] > 0:
            assert s["recovery_j"] > 0.0, kind
    # deadline arm: a 1-tick deadline under a 12-deep queue on 4 slots
    # MUST shed the overdue tail — and still complete every request
    # (shed requests finish with whatever they have, never vanish)
    dl_s, dl_g = arm(None, deadline=1)
    assert len(dl_g) == N_REQUESTS
    arms["deadline_shed"] = {"shed": dl_s["shed"],
                             "shed_rate": dl_s["shed_rate"],
                             "ticks": dl_s["ticks"],
                             "completed": len(dl_g)}
    assert dl_s["shed"] > 0
    res = {
        "workload": {"requests": N_REQUESTS, "max_tokens": MAX_TOKENS,
                     "slots": MAX_SLOTS, "page_size": 8, "seed": SEED,
                     "fault_tick": 3,
                     "backend": jax.default_backend()},
        "notes": ("one seeded fault per arm at tick 3 vs. the fault-free "
                  "baseline on the same workload. streams_identical means "
                  "every request's tokens match the baseline exactly — "
                  "faults cost joules (recovery_j), never content. "
                  "deadline_shed arms a 1-tick deadline to exercise the "
                  "shedding rung."),
        "baseline": {"ticks": base_s["ticks"],
                     "decode_tokens": base_s["decode_tokens"]},
        "arms": arms,
        "zero_crashes": True,
        "all_streams_identical": all(
            a.get("streams_identical", True) for a in arms.values()),
    }
    atomic_write_json(OUT_FAULTS_PATH, res)
    return res


def bench_restore(kill_tick=8, interval=3) -> dict:
    """Durability tier (DESIGN.md §19): kill the engine mid-workload with a
    seeded ``process_kill`` fault, restart a fresh engine from disk
    (snapshot + journal replay), and gate on the restart invariant:

    * every request's token stream — finished before the kill, recovered
      from the journal, or completed after restart — is IDENTICAL to the
      fault-free baseline's;
    * the restart replayed at least one journaled tick and billed its
      recompute as ``restore_j > 0`` (warm restart has a measured energy
      price, next to the snapshot/journal write bill it trades against).
    """
    from repro.core import accounting
    from repro.serve import (FaultPlan, ProcessKilled, ServeConfig,
                             ServeEngine, generation_agreement, run_workload)
    cfg, params = _model()
    rng = np.random.default_rng(SEED + 23)
    prompts = [rng.integers(0, 100, size=int(rng.integers(6, 14)))
               for _ in range(N_REQUESTS)]

    def _acct():
        return accounting.CarbonAccountant(accounting.AccountantConfig(
            device="tpu_v5e", n_devices=1, grid_mix="NY"))

    # fault-free baseline: same seed + config minus faults/checkpointing —
    # neither alters a pre-kill token, so streams must match exactly
    base = ServeEngine(params, cfg, ServeConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, paged=True, page_size=8,
        seed=SEED))
    base_g = run_workload(base, prompts, max_tokens=MAX_TOKENS,
                          max_ticks=800)
    base_s = base.summary()

    ckpt_dir = tempfile.mkdtemp(prefix="bench_restore.")
    plan = FaultPlan.single("process_kill", tick=kill_tick, seed=SEED + 29)
    scfg = ServeConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN, paged=True,
                       page_size=8, seed=SEED, faults=plan,
                       checkpoint_dir=ckpt_dir,
                       checkpoint_interval=interval)
    eng = ServeEngine(params, cfg, scfg, accountant=_acct())
    for p in prompts:
        eng.submit(np.asarray(p, np.int32), max_tokens=MAX_TOKENS)
    killed = False
    try:
        eng.run_until_drained(max_ticks=800)
    except ProcessKilled:
        killed = True
    assert killed, f"process_kill at tick {kill_tick} never fired"

    # the dead engine's object is abandoned — restart purely from disk
    acct2 = _acct()
    eng2 = ServeEngine(params, cfg, scfg, accountant=acct2)
    recovered = eng2.restore()
    done2 = eng2.run_until_drained(max_ticks=800)
    by_uid = {r.uid: r for r in recovered}    # at-least-once: dedupe
    by_uid.update({r.uid: r for r in done2})
    gens2 = {uid: list(r.generated) for uid, r in by_uid.items()}
    agree = generation_agreement(gens2, base_g)
    s2 = eng2.summary()
    rep2 = acct2.report()
    res = {
        "workload": {"requests": N_REQUESTS, "max_tokens": MAX_TOKENS,
                     "slots": MAX_SLOTS, "page_size": 8, "seed": SEED,
                     "kill_tick": kill_tick,
                     "checkpoint_interval": interval,
                     "backend": jax.default_backend()},
        "notes": ("kill-and-restart arm: a seeded process_kill fault "
                  "aborts the engine mid-workload; a fresh engine "
                  "restores from the latest snapshot and deterministically "
                  "replays the journal tail (DESIGN.md §19). "
                  "streams_identical means every request's tokens match "
                  "the fault-free baseline exactly; restore_j is the "
                  "modeled energy of the replayed recompute, "
                  "durability_write_j the snapshot+journal write bill it "
                  "trades against."),
        "baseline": {"ticks": base_s["ticks"],
                     "decode_tokens": base_s["decode_tokens"]},
        "restore": {"ticks": s2["ticks"],
                    "decode_tokens": s2["decode_tokens"],
                    "snapshots_taken": s2["snapshots_taken"],
                    "snapshot_bytes": s2["snapshot_bytes"],
                    "journal_bytes": s2["journal_bytes"],
                    "replayed_ticks": s2["replayed_ticks"],
                    "restore_j": s2["restore_j"],
                    "restore_j_per_token": s2["restore_j_per_token"],
                    "durability_write_j": s2["durability_write_j"],
                    "accountant_restore_j": rep2["restore_j"],
                    "accountant_replayed_ticks": rep2["replayed_ticks"]},
        "killed": killed,
        "recovered_requests": len(by_uid),
        "streams_identical": bool(agree["identical"]),
        "agreement": agree["agreement"],
    }
    assert res["streams_identical"], "a stream diverged after restart"
    assert res["recovered_requests"] == N_REQUESTS
    assert s2["snapshots_taken"] > 0
    assert s2["replayed_ticks"] >= 1
    assert s2["restore_j"] > 0.0
    assert s2["journal_bytes"] > 0.0
    atomic_write_json(OUT_RESTORE_PATH, res)
    return res


def run():
    """benchmarks/run.py hook: name,us_per_call,derived rows."""
    res = bench()
    f, r = res["fused"], res["reference"]
    tick_us = lambda d: d["wall_s"] / d["ticks"] * 1e6
    return [
        ("serve/fused_tick", tick_us(f),
         f"{f['decode_tokens_per_s']} tok/s; {f['j_per_token']:.2f} J/tok"),
        ("serve/reference_tick", tick_us(r),
         f"{r['decode_tokens_per_s']} tok/s; {r['j_per_token']:.2f} J/tok"),
        ("serve/speedup", 0.0,
         f"{res['speedup_decode_tok_s']}x decode tok/s; "
         f"{res['j_per_token_ratio']}x J/token"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", choices=("none", "int8"), default="none",
                    help="int8: benchmark the quantized serving fast path "
                         "(bf16 vs int8 arms) into BENCH_quant.json")
    ap.add_argument("--paged", action="store_true",
                    help="benchmark the paged KV + prefix-cache engine vs "
                         "the dense engine on a shared-prefix workload "
                         "into BENCH_serve_paged.json")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="with --paged: benchmark speculative decode "
                         "(draft k tokens/tick, DESIGN.md §15) vs the "
                         "plain paged engine into BENCH_serve_spec.json")
    ap.add_argument("--long-context", action="store_true",
                    help="with --paged: benchmark the long-context tier "
                         "(paged flash-prefill kernel, fragmented vs "
                         "contiguous layouts vs the chunked-gather "
                         "baseline, DESIGN.md §16) into "
                         "BENCH_serve_longctx.json")
    ap.add_argument("--nbest", type=int, default=0,
                    help="with --paged: benchmark n-best COW forks "
                         "(DESIGN.md §18) vs the duplicate-KV baseline "
                         "into BENCH_serve_cow.json (0 = off)")
    ap.add_argument("--chaos", action="store_true",
                    help="chaos tier (DESIGN.md §17): one seeded fault "
                         "arm per kind vs the fault-free baseline, gating "
                         "on stream identity + bounded drain, into "
                         "BENCH_serve_faults.json")
    ap.add_argument("--fault-kind", default=None,
                    choices=("process_kill",),
                    help="with --chaos: run ONE dedicated fault arm "
                         "instead of the transient matrix. process_kill "
                         "is the kill-and-restart durability bench "
                         "(DESIGN.md §19) into BENCH_serve_restore.json")
    ap.add_argument("--seed", type=int, default=0,
                    help="one seed for ALL stochastic bench inputs: "
                         "workload prompt draws and engine sampling "
                         "streams (same seed => identical runs)")
    args = ap.parse_args()
    SEED = args.seed
    if args.chaos and args.fault_kind == "process_kill":
        out = bench_restore()
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_RESTORE_PATH)}")
        r = out["restore"]
        print(f"restore: killed at tick "
              f"{out['workload']['kill_tick']}, "
              f"{r['snapshots_taken']:.0f} snapshots, replayed "
              f"{r['replayed_ticks']:.0f} ticks "
              f"({r['restore_j']:.3g} J); {out['recovered_requests']} "
              f"requests recovered, streams identical: "
              f"{out['streams_identical']}")
    elif args.chaos:
        out = bench_chaos()
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_FAULTS_PATH)}")
        n_q = sum(a.get("quarantined", 0) for a in out["arms"].values())
        print(f"chaos: {len(out['arms'])} arms, zero crashes, streams "
              f"identical: {out['all_streams_identical']}; "
              f"{n_q} quarantines, deadline arm shed "
              f"{out['arms']['deadline_shed']['shed']}")
    elif args.paged and args.long_context:
        out = bench_longctx()
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_LONGCTX_PATH)}")
        print(f"modeled prefill tok/s: fragmented/contiguous "
              f"{out['frag_vs_contig_ratio']}x; kernel vs chunked gather "
              f"{out['kernel_vs_gather_speedup']}x; streams identical: "
              f"{out['token_agreement_vs_gather']['identical']}")
    elif args.paged and args.nbest > 1:
        out = bench_cow(nbest=args.nbest)
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_COW_PATH)}")
        print(f"kv bytes {out['kv_bytes_ratio']}x lower than duplicate-KV; "
              f"modeled J/token {out['j_per_token_ratio']}x; "
              f"{out['cow']['forks']:.0f} forks, "
              f"{out['cow']['cow_copies']:.0f} COW copies; per-fork "
              f"agreement {out['per_fork_agreement']:.2%} "
              f"(identical: {out['streams_identical']})")
    elif args.paged and args.spec_k > 0:
        out = bench_spec(spec_k=args.spec_k)
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_SPEC_PATH)}")
        print(f"accept rate {out['accept_rate']:.1%}; "
              f"{out['spec']['accepted_tokens_per_tick']:.2f} emitted "
              f"tokens/slot-tick; modeled J/accepted-token "
              f"{out['speedup']}x lower than plain paged; "
              f"stream identical: {out['token_agreement']['identical']}")
    elif args.paged:
        out = bench_paged()
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_PAGED_PATH)}")
        print(f"prefix hit rate {out['paged']['prefix_hit_rate']:.1%}; "
              f"prefill tokens {out['prefill_token_ratio']}x fewer; "
              f"modeled J/token {out['speedup']}x lower; "
              f"agreement {out['token_agreement']['agreement']:.2%}")
    elif args.quant == "int8":
        out = bench_quant()
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_QUANT_PATH)}")
        print(f"KV-cache bytes: {out['kv_cache_bytes_ratio']}x lower; "
              f"modeled J/token: {out['j_per_token_ratio']}x lower; "
              f"agreement {out['token_agreement_vs_fp']['agreement']:.2%}")
    else:
        out = bench()
        print(json.dumps(out, indent=2))
        print(f"\nwrote {os.path.abspath(OUT_PATH)}")
        print(f"decode speedup: {out['speedup_decode_tok_s']}x")
