"""Serve-core benchmark: decode tokens/s and J/token, fused vs. reference.

Measures the tentpole claim directly on the live serving path: the fused
device-resident engine (one jitted tick, one mask readback) against the
host-loop reference engine (per-slot ``int(tok)`` syncs) on the SAME model,
workload, and backend. Emits ``BENCH_serve.json`` next to the repo root and
CSV rows via benchmarks/run.py.

    PYTHONPATH=src python benchmarks/serve_bench.py
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

N_REQUESTS = 12
MAX_TOKENS = 16
MAX_SLOTS = 4
MAX_LEN = 64


def _model():
    from repro.models import transformer as tf_lib
    cfg = tf_lib.LMConfig(name="bench", d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=128, pattern=(tf_lib.BlockSpec(),),
                          repeats=2, remat="none", vocab_pad_multiple=1)
    params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                            dtype=jnp.float32).params
    return cfg, params


def _workload(eng):
    rng = np.random.default_rng(0)
    for _ in range(N_REQUESTS):
        prompt = rng.integers(0, 100, size=int(rng.integers(4, 12)))
        eng.submit(prompt, max_tokens=MAX_TOKENS)


def _measure(make_engine):
    """Warm up (compile) and measure on the SAME engine instance — jit
    caches are per-engine closures, so a long-lived server is the honest
    steady state to time."""
    from repro.core import accounting
    eng = make_engine(None)
    _workload(eng)
    eng.run_until_drained()                  # compiles tick + admit buckets
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=1, grid_mix="NY"))
    eng.accountant = acct
    eng.metrics_log = []
    _workload(eng)
    done = eng.run_until_drained()
    assert len(done) == N_REQUESTS
    toks = sum(m.tokens for m in eng.metrics_log)
    wall = sum(m.wall_s for m in eng.metrics_log)
    rep = acct.report()
    return {"decode_tokens": toks,
            "wall_s": round(wall, 4),
            "decode_tokens_per_s": round(toks / wall, 2),
            "j_per_token": rep["j_per_token"],
            "ticks": len(eng.metrics_log)}


def bench() -> dict:
    from repro.serve import ReferenceEngine, ServeConfig, ServeEngine
    cfg, params = _model()

    def fused(acct):
        return ServeEngine(params, cfg,
                           ServeConfig(max_slots=MAX_SLOTS, max_len=MAX_LEN),
                           accountant=acct)

    def reference(acct):
        return ReferenceEngine(params, cfg,
                               ServeConfig(max_slots=MAX_SLOTS,
                                           max_len=MAX_LEN),
                               accountant=acct)

    res = {
        "workload": {"requests": N_REQUESTS, "max_tokens": MAX_TOKENS,
                     "slots": MAX_SLOTS, "backend": jax.default_backend()},
        "fused": _measure(fused),
        "reference": _measure(reference),
    }
    res["speedup_decode_tok_s"] = round(
        res["fused"]["decode_tokens_per_s"]
        / res["reference"]["decode_tokens_per_s"], 2)
    res["j_per_token_ratio"] = round(
        res["reference"]["j_per_token"] / res["fused"]["j_per_token"], 2)
    with open(OUT_PATH, "w") as f:
        json.dump(res, f, indent=2)
    return res


def run():
    """benchmarks/run.py hook: name,us_per_call,derived rows."""
    res = bench()
    f, r = res["fused"], res["reference"]
    tick_us = lambda d: d["wall_s"] / d["ticks"] * 1e6
    return [
        ("serve/fused_tick", tick_us(f),
         f"{f['decode_tokens_per_s']} tok/s; {f['j_per_token']:.2f} J/tok"),
        ("serve/reference_tick", tick_us(r),
         f"{r['decode_tokens_per_s']} tok/s; {r['j_per_token']:.2f} J/tok"),
        ("serve/speedup", 0.0,
         f"{res['speedup_decode_tok_s']}x decode tok/s; "
         f"{res['j_per_token_ratio']}x J/token"),
    ]


if __name__ == "__main__":
    out = bench()
    print(json.dumps(out, indent=2))
    print(f"\nwrote {os.path.abspath(OUT_PATH)}")
    print(f"decode speedup: {out['speedup_decode_tok_s']}x")
