"""Paper Table 1: grid-mix carbon intensities."""

from repro.core import grid
from benchmarks.bench_util import timed


def run():
    rows = []
    mixes = {}

    def compute():
        nonlocal mixes
        mixes = grid.all_mix_intensities()
        return mixes

    rows.append(timed("table1/grid_mixes", compute,
                      derived=lambda: ";".join(
                          f"{s}={v:.0f}gCO2eq/kWh" for s, v in mixes.items())))
    for state, paper in grid.PAPER_MIX_ROW.items():
        got = grid.mix_intensity(state)
        rows.append((f"table1/{state}", 0.0,
                     f"computed={got:.1f};paper={paper:.0f};"
                     f"delta={abs(got-paper):.2f}"))
    return rows
