"""Paper Table 2: embodied energy & carbon per die (all LCA studies)."""

from repro.core import lca
from benchmarks.bench_util import timed


def run():
    rows = []
    t2 = {}

    def compute():
        nonlocal t2
        t2 = lca.table2()
        return t2

    rows.append(timed("table2/recompute_all", compute, derived=""))
    for label, row in t2.items():
        ref = lca.PAPER_TABLE2[label]
        rows.append((
            f"table2/{label}", 0.0,
            f"PE={row['pe_kwh']:.0f}kWh(paper {ref['pe_kwh']:.0f});"
            f"E={row['mj_die']:.2f}MJ(paper {ref['mj_die']});"
            f"AZ={row['az']:.0f}({ref['az']});NY={row['ny']:.0f}({ref['ny']})"))
    rows.append(("table2/tpu_v5e_package", 0.0,
                 f"estimate={lca.tpu_package_embodied_mj():.1f}MJ;"
                 "beyond-paper (PPACE 5nm logic + HBM)"))
    return rows
