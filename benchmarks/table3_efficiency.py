"""Paper Table 3: per-watt and per-gCO2eq efficiency of every accelerator."""

from repro.core import energy
from benchmarks.bench_util import timed


def run():
    rows = []
    cases = [("alexnet", "inference_ternary"), ("alexnet", "train_fp32"),
             ("vgg16", "train_fp32")]
    tables = {}

    def compute():
        for b, p in cases:
            tables[(b, p)] = energy.table3_efficiency(b, p)
        return tables

    rows.append(timed("table3/recompute_all", compute))
    for (b, p), table in tables.items():
        for dev, row in table.items():
            ref = energy.PAPER_TABLE3_EFF.get((b, p, dev))
            rows.append((
                f"table3/{b}/{p}/{dev}", 0.0,
                f"{row['per_w']:.2f}{row['unit']}/W;"
                f"{row['carbon_eff_min']:.2f}-{row['carbon_eff_max']:.2f}"
                f"{row['carbon_eff_unit']}"
                + (f";paper={ref[0]}-{ref[1]}" if ref else "")))
    return rows
