"""On-line training fast path benchmark: fused TrainEngine vs host loop.

Two arms on the SAME model, token stream, optimizer, and backend:

* **reference** — the host-loop Trainer (train/loop.py): per-step batch
  staging, jitted-step dispatch, and a loss-readback sync every step, with
  autodiff through the reference attention ops. This is the seed training
  path and the "autodiff-through-reference baseline".
* **fused** — the device-resident TrainEngine tick (train/engine.py,
  DESIGN.md §13): ``steps_per_tick`` optimizer steps scanned inside one
  jitted call, double-buffered batch staging overlapped with device
  compute, one metrics readback per tick.

The bench model is the paper's edge regime — on-line adaptation with small
incremental updates (batch 2, seq 16), where step latency is dominated by
the per-step host work the fused tick eliminates. Alongside the step-time
ratio, the bench verifies the fast path is *numerically honest*: the fused
engine's parameter updates match the reference loop bit-tight, and the
custom-VJP kernel gradients match jax.grad through kernels/ref.py.

    PYTHONPATH=src python benchmarks/train_bench.py
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_train.json")

# edge on-line adaptation workload: small incremental updates
D_MODEL, N_HEADS, N_KV, D_FF, VOCAB = 64, 4, 2, 128, 128
BATCH, SEQ = 2, 16
STEPS = 64
STEPS_PER_TICK = 32
WARMUP = STEPS_PER_TICK        # covers the timed run's tick shape (compile)


def _model():
    from repro.models import transformer as tf_lib
    cfg = tf_lib.LMConfig(name="train-bench", d_model=D_MODEL,
                          n_heads=N_HEADS, n_kv_heads=N_KV, d_ff=D_FF,
                          vocab=VOCAB, pattern=(tf_lib.BlockSpec(),),
                          repeats=2, remat="none", vocab_pad_multiple=1)
    params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                            dtype=jnp.float32).params
    return cfg, params


def _pipeline():
    from repro.data import DataConfig, make_pipeline
    return make_pipeline(DataConfig(vocab=VOCAB, seq_len=SEQ,
                                    global_batch=BATCH, seed=0,
                                    source="markov"))


def _bench_reference(cfg, params, opt):
    """Host-loop Trainer: stage -> dispatch -> sync, every step."""
    from repro.models import transformer as tf_lib
    from repro.train import TrainConfig, Trainer
    tr = Trainer(loss_fn=lambda p, b: tf_lib.loss_fn(p, cfg, b),
                 params=params, opt_cfg=opt,
                 train_cfg=TrainConfig(num_steps=STEPS, log_every=10 ** 9),
                 pipeline=_pipeline())
    tr.run(WARMUP)                    # compile + steady-state caches
    t0 = time.monotonic()
    tr.run(STEPS)
    wall = time.monotonic() - t0
    loss = float(tr._jit_step(tr.params, tr.opt_state,
                              {k: jnp.asarray(v) for k, v in
                               tr.pipeline.batch_at(tr.step_num).items()}
                              )[2]["loss"])
    return {"steps": STEPS, "wall_s": round(wall, 4),
            "s_per_step": wall / STEPS, "final_loss": loss}


def _bench_fused(cfg, params, opt):
    """Device-resident tick: scan-fused steps, one readback per tick."""
    from repro.core import accounting
    from repro.train import TrainEngine, TrainEngineConfig
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=1, grid_mix="NY"))
    eng = TrainEngine.for_lm(
        params, cfg, opt_cfg=opt, pipeline=_pipeline(),
        engine_cfg=TrainEngineConfig(steps_per_tick=STEPS_PER_TICK),
        accountant=acct)
    eng.run(WARMUP)
    eng.metrics_log.clear()
    t0 = time.monotonic()
    last = eng.run(STEPS)
    wall = time.monotonic() - t0
    rep = acct.train_report()
    return {"steps": STEPS, "wall_s": round(wall, 4),
            "s_per_step": wall / STEPS, "final_loss": last["loss"],
            "steps_per_tick": STEPS_PER_TICK,
            "ticks": len(eng.metrics_log),
            "host_readbacks_per_step": eng.host_readbacks / (WARMUP + STEPS),
            "energy": {k: rep[k] for k in
                       ("fwd_j", "bwd_j", "opt_j", "total_j", "j_per_step",
                        "j_per_sample", "bwd_fwd_ratio")}}


def _grad_parity():
    """Gradients through the custom-VJP kernels vs jax.grad through
    kernels/ref.py (interpret mode on CPU) — max abs error."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref
    rng = np.random.default_rng(0)
    b, sq, h, hkv, d = 2, 13, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, hkv, d)), jnp.float32)
    ct = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    gk = jax.grad(lambda q, k, v: jnp.sum(kops.flash_attention_train(
        q, k, v, scale=0.35) * ct), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: jnp.sum(kref.attention_ref(
        q, k, v, scale=0.35) * ct), argnums=(0, 1, 2))(q, k, v)
    flash_err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(gk, gr))

    x = jnp.asarray(rng.standard_normal((5, 40)), jnp.float32)
    qw = jnp.asarray(rng.integers(-127, 128, (40, 24)), jnp.int8)
    sc = jnp.asarray(rng.uniform(0.01, 0.1, (24,)), jnp.float32)
    ct2 = jnp.asarray(rng.standard_normal((5, 24)), jnp.float32)
    gx = jax.grad(lambda x: jnp.sum(kops.int8_matmul_train(
        x, qw, sc, block_n=16, block_k=32) * ct2))(x)
    rx = jax.grad(lambda x: jnp.sum(
        kref.ternary_matmul_ref(x, qw, sc, out_dtype=jnp.float32) * ct2))(x)
    int8_err = float(jnp.max(jnp.abs(gx - rx)))
    return {"flash_attention_max_abs_err": flash_err,
            "int8_matmul_max_abs_err": int8_err}


def _update_parity(cfg, opt):
    """Fused engine vs reference loop after 4 identical steps."""
    from repro.data import DataConfig, make_pipeline  # noqa: F401
    from repro.models import transformer as tf_lib
    from repro.optim import init_opt_state
    from repro.train import TrainEngine, TrainEngineConfig, make_train_step
    eng = TrainEngine.for_lm(
        tf_lib.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32).params,
        cfg, opt_cfg=opt, pipeline=_pipeline(),
        engine_cfg=TrainEngineConfig(steps_per_tick=4))
    eng.run(4)
    step = jax.jit(make_train_step(
        lambda p, b: tf_lib.loss_fn(p, cfg, b), opt))
    params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                            dtype=jnp.float32).params
    state = init_opt_state(params, opt)
    pipe = _pipeline()
    for i in range(4):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, state, _ = step(params, state, batch)
    return max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), eng.params, params)))


def bench() -> dict:
    from repro.models import transformer as tf_lib
    from repro.optim import AdamWConfig
    cfg, params = _model()
    opt = AdamWConfig(lr=1e-3)

    def fresh():
        return tf_lib.init_lm(jax.random.PRNGKey(0), cfg,
                              dtype=jnp.float32).params

    res = {
        "workload": {"d_model": D_MODEL, "layers": cfg.n_layers,
                     "batch": BATCH, "seq_len": SEQ, "steps": STEPS,
                     "regime": "edge on-line adaptation (small incremental "
                               "updates; step latency host-dominated)",
                     "backend": jax.default_backend()},
        "reference": _bench_reference(cfg, fresh(), opt),
        "fused": _bench_fused(cfg, fresh(), opt),
        "grad_parity_vs_ref": _grad_parity(),
        "update_parity_max_abs_diff": _update_parity(cfg, opt),
    }
    res["speedup_s_per_step"] = round(
        res["reference"]["s_per_step"] / res["fused"]["s_per_step"], 2)
    with open(OUT_PATH, "w") as f:
        json.dump(res, f, indent=2)
    return res


def run():
    """benchmarks/run.py hook: name,us_per_call,derived rows."""
    res = bench()
    f, r = res["fused"], res["reference"]
    return [
        ("train/fused_step", f["s_per_step"] * 1e6,
         f"{f['energy']['j_per_step']:.2e} modeled J/step"),
        ("train/reference_step", r["s_per_step"] * 1e6, ""),
        ("train/speedup", 0.0,
         f"{res['speedup_s_per_step']}x s/step; grad err "
         f"{res['grad_parity_vs_ref']['flash_attention_max_abs_err']:.1e}"),
    ]


if __name__ == "__main__":
    argparse.ArgumentParser(description=__doc__).parse_args()
    out = bench()
    print(json.dumps(out, indent=2))
    print(f"\nwrote {os.path.abspath(OUT_PATH)}")
    print(f"step-time speedup: {out['speedup_s_per_step']}x; "
          f"update parity {out['update_parity_max_abs_diff']:.1e}; "
          f"flash grad err "
          f"{out['grad_parity_vs_ref']['flash_attention_max_abs_err']:.1e}")
