"""Paper-faithful edge workload: AlexNet ternary inference + FP32 training,
with the full Table-2/3 + Figure-2 sustainability analysis.

This is the paper's experiment end-to-end: quantize the CNN the way the PIM
engine does (TWN ternary, multiplication-free execution contract), compare
platform efficiencies from the measured Table-3 points, and decide between
accelerators with Eq. 1.

    PYTHONPATH=src python examples/edge_cnn_repro.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.core import advisor, energy, grid, lca, sustain
from repro.core.sustain import Duty, SECONDS_PER_DAY, SECONDS_PER_YEAR
from repro.kernels import ops as kops
from repro.models import cnn
from repro.quant import ternary


def main():
    # -- 1. the workload: AlexNet (reduced for CPU), fp32 vs ternary ---------
    cfg = cfgbase.get("alexnet").make_smoke()
    ax = cnn.init_cnn(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3))
    logits_fp32 = cnn.forward(ax.params, cfg, imgs)

    qparams = ternary.quantize_tree(
        ax.params, predicate=lambda n, x: x.ndim == 2 and "fc" in n)

    def ternary_mm(x, w):
        if isinstance(w, ternary.TernaryWeight):
            return kops.ternary_matmul(x, w)      # PIM-adapted Pallas kernel
        return x @ w.astype(x.dtype)

    logits_tern = cnn.forward(ternary.dequantize_tree(qparams), cfg, imgs)
    agree = float(np.mean(np.argmax(np.asarray(logits_fp32), -1)
                          == np.argmax(np.asarray(logits_tern), -1)))
    print(f"AlexNet ternary-FC inference: top-1 agreement with fp32 = {agree:.0%}")
    print(f"  (paper: ternary model reduction keeps reasonable accuracy; "
          f"training stays FP32)\n")

    # -- 2. Table 3: who executes it most efficiently? -----------------------
    print("Table 3 (inference, ternary PIM):")
    for dev, row in energy.table3_efficiency("alexnet",
                                             "inference_ternary").items():
        print(f"  {dev:10s} {row['throughput']:7.1f} FPS @ {row['power_w']:.2f} W"
              f" -> {row['per_w']:6.1f} FPS/W, "
              f"{row['carbon_eff_min']:.2f}-{row['carbon_eff_max']:.2f} MF/gCO2eq")

    # -- 3. Fig 2a: replace deployed DDR3-PIM with RM-PIM? --------------------
    rm = sustain.platform_from_hw("rm_pim", "alexnet", "inference_ternary",
                                  per_module=True)
    ddr = sustain.platform_from_hw("ddr3_pim", "alexnet", "inference_ternary",
                                   per_module=True)
    print("\nFig 2a break-even (RM-PIM replacing deployed DDR3-PIM):")
    for a in (1.0, 0.5, 0.25):
        c = sustain.compare(rm, ddr, Duty(a), ref_throughput=ddr.throughput)
        print(f"  activity {a:4.0%}: t_B = {c.breakeven_s / SECONDS_PER_DAY:5.0f}"
              f" days")

    # -- 4. Fig 2b + Eq.1 decision: GPU vs RM for on-line training -----------
    gpu = sustain.platform_from_hw("gpu", "alexnet", "train_fp32")
    rmt = sustain.platform_from_hw("rm_pim", "alexnet", "train_fp32")
    fpga = sustain.platform_from_hw("fpga", "alexnet", "train_fp32")
    print("\nFig 2b indifference (GPU vs RM-PIM, FP32 training):")
    cross = sustain.crossover_activity(gpu, rmt, ref_throughput=rmt.throughput)
    print(f"  GPU beats RM above activity ratio {cross:.0%} "
          f"(paper: 'at least 40%')")
    for a in (0.3, 0.6, 0.9):
        rec = advisor.recommend([gpu, rmt, fpga], Duty(a),
                                5 * SECONDS_PER_YEAR,
                                ref_throughput=rmt.throughput)
        print(f"  activity {a:4.0%}: winner={rec.winner} "
              f"(dominated: {rec.dominated})")


if __name__ == "__main__":
    main()
