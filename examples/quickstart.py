"""Quickstart: train a tiny LM with live carbon accounting, then generate.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting
from repro.data import DataConfig, make_pipeline
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.optim.schedules import warmup_cosine
from repro.serve import ServeConfig, ServeEngine
from repro.train import TrainConfig, Trainer


def main():
    cfg = tf.LMConfig(name="quickstart", d_model=96, n_heads=4, n_kv_heads=2,
                      d_ff=192, vocab=128, pattern=(tf.BlockSpec(),),
                      repeats=3, remat="none")
    params = tf.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32).params

    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=jax.device_count(), grid_mix="CA"))
    trainer = Trainer(
        loss_fn=lambda p, b: tf.loss_fn(p, cfg, b),
        params=params,
        opt_cfg=AdamWConfig(lr=warmup_cosine(3e-3, 10, 100)),
        train_cfg=TrainConfig(num_steps=100, log_every=20),
        pipeline=make_pipeline(DataConfig(vocab=128, seq_len=64,
                                          global_batch=8, source="markov")),
        accountant=acct)
    print("training 100 steps on markov data...")
    trainer.run()
    for e in trainer.metrics_log:
        print(f"  step {e['step']:4d} loss {e['loss']:.3f} "
              f"({e['step_time_s']*1e3:.0f} ms/step)")

    print("\ncarbon report (the paper's holistic accounting, live):")
    for k, v in acct.report().items():
        print(f"  {k}: {v}")

    print("\ngreedy generation from the trained model:")
    eng = ServeEngine(trainer.params, cfg,
                      ServeConfig(max_slots=2, max_len=96,
                                  cache_dtype=jnp.float32))
    eng.submit(np.arange(8), max_tokens=12)
    for r in eng.run_until_drained():
        print(f"  prompt={list(r.prompt)} -> {r.generated}")


if __name__ == "__main__":
    main()
