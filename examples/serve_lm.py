"""End-to-end serving driver (deliverable b): batched request serving with
continuous batching, KV caches, and live carbon accounting.

The paper's kind is edge INFERENCE sustainability — this is the e2e driver:
a small LM serves a stream of batched requests; every decode tick is billed
by the CarbonAccountant; the final report answers the paper's question
(operational energy, carbon by grid mix, embodied amortization).

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-27b]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.core import accounting, grid
from repro.models import transformer as tf
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b",
                    help="arch whose SMOKE config is served")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--grid-mix", default="CA")
    args = ap.parse_args()

    arch = cfgbase.get(args.arch)
    if arch.kind != "lm":
        raise SystemExit(f"{args.arch} is {arch.kind}; pick an LM arch")
    cfg = arch.make_smoke()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32).params

    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=jax.device_count(),
        grid_mix=args.grid_mix))
    eng = ServeEngine(params, cfg,
                      ServeConfig(max_slots=args.slots, max_len=256,
                                  cache_dtype=jnp.float32),
                      accountant=acct)

    rng = np.random.default_rng(0)
    print(f"serving {args.requests} requests on {args.arch} (smoke config), "
          f"{args.slots} slots, continuous batching:")
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 16)))
        eng.submit(prompt, max_tokens=args.max_tokens)
    done = eng.run_until_drained()
    for r in done[:6]:
        print(f"  req {r.uid:2d}: {len(r.prompt):2d} prompt toks -> "
              f"{len(r.generated)} generated")
    print(f"  ... {len(done)} requests completed")

    s = eng.summary()
    print(f"\nserve metrics (live path): {s['ticks']} ticks, "
          f"{s['decode_tokens']:.0f} decode tokens at "
          f"{s['decode_tokens_per_s']:.1f} tok/s "
          f"(+ {s['prefill_tokens']:.0f} prefill tokens)")

    rep = acct.report()
    print("\ncarbon report:")
    print(f"  decode ticks: {rep['steps']}, tokens: {rep['tokens']:.0f}")
    if rep.get("j_per_token") is not None:
        print(f"  J/token (live): {rep['j_per_token']:.3f}")
    print(f"  operational: {rep['operational_j']:.1f} J = "
          f"{rep['operational_gco2']:.4f} gCO2eq ({args.grid_mix} grid)")
    print(f"  tokens/J: {rep['tokens_per_j']:.2f}")
    print(f"  fleet embodied budget: {rep['embodied_j']/1e6:.0f} MJ "
          f"({rep['embodied_gco2']/1e3:.1f} kgCO2eq)")
    print(f"  lifecycle amortized so far: {rep['amortized_fraction']:.2e}")
    print("\n(the production decode shapes are proven by "
          "`python -m repro.launch.dryrun --arch "
          f"{args.arch} --shape decode_32k`)")


if __name__ == "__main__":
    main()
