"""Full sustainability report: paper tables + fleet-scale extension.

Regenerates Table 1/2/3 and the Figure-2 analyses from first principles,
then applies the same engine to the TPU-v5e fleet using the dry-run roofline
records (results/dryrun_baseline.jsonl) — the beyond-paper contribution.

    PYTHONPATH=src python examples/sustainability_report.py
"""

import json
import os

import numpy as np

from repro.core import advisor, energy, grid, lca, roofline as rl, sustain
from repro.core.sustain import Duty, SECONDS_PER_YEAR


def paper_tables():
    print("=" * 72)
    print("PAPER REPRODUCTION")
    print("=" * 72)
    print("\nTable 1 — grid mixes (gCO2eq/kWh):")
    for s, v in grid.all_mix_intensities().items():
        print(f"  {s}: {v:6.1f}   (paper: {grid.PAPER_MIX_ROW[s]:.0f})")

    print("\nTable 2 — embodied energy/carbon per die:")
    for label, row in lca.table2().items():
        ref = lca.PAPER_TABLE2[label]
        print(f"  {label:18s} PE={row['pe_kwh']:6.0f} kWh/wafer  "
              f"E={row['mj_die']:6.2f} MJ (paper {ref['mj_die']:5.2f})  "
              f"NY={row['ny']:5.0f} g (paper {ref['ny']})")

    print("\nTable 3 — operational efficiency:")
    for bench, phase in (("alexnet", "inference_ternary"),
                         ("alexnet", "train_fp32"), ("vgg16", "train_fp32")):
        for dev, row in energy.table3_efficiency(bench, phase).items():
            print(f"  {bench:8s} {phase:17s} {dev:9s} "
                  f"{row['per_w']:7.2f}/W  "
                  f"{row['carbon_eff_min']:7.2f}-{row['carbon_eff_max']:7.2f} "
                  f"{row['carbon_eff_unit']}")

    print("\nFigure 2 — break-even / indifference claims:")
    rm = sustain.platform_from_hw("rm_pim", "alexnet", "inference_ternary",
                                  per_module=True)
    ddr = sustain.platform_from_hw("ddr3_pim", "alexnet", "inference_ternary",
                                   per_module=True)
    for a in (1.0, 0.5):
        c = sustain.compare(rm, ddr, Duty(a), ref_throughput=ddr.throughput)
        print(f"  2a: RM replaces DDR3 @ {a:.0%} activity: "
              f"{c.breakeven_s/86400:.0f} days")
    for bench in ("alexnet", "vgg16"):
        gpu = sustain.platform_from_hw("gpu", bench, "train_fp32")
        rmt = sustain.platform_from_hw("rm_pim", bench, "train_fp32")
        cr = sustain.crossover_activity(gpu, rmt, ref_throughput=rmt.throughput)
        print(f"  2b/2c: GPU beats RM ({bench}) above activity {cr:.0%}")


def fleet_report():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_baseline.jsonl")
    if not os.path.exists(path):
        print("\n(no dry-run records; run `python -m repro.launch.dryrun` "
              "for the fleet section)")
        return
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("ok"):
                recs[r["label"]] = r
    print("\n" + "=" * 72)
    print("BEYOND PAPER: TPU-v5e FLEET (from the multi-pod dry-run)")
    print("=" * 72)
    emb_chip = lca.tpu_package_embodied_mj()
    emb_fleet_j = emb_chip * 1e6 * 256
    # Eq.1 at fleet scale, first the duty-independent headline: a 256-chip pod
    # at 100% duty burns its own embodied energy in
    #   18.7 GJ / 51.2 kW ~ 4.2 days
    # — the paper's edge finding ("embodied is 80-90% of lifecycle") INVERTS
    # at datacenter duty cycles; embodied only dominates when fleets idle.
    t_amort = emb_fleet_j / (256 * 200.0) / 86400.0
    print(f"\nper-chip embodied estimate: {emb_chip:.0f} MJ "
          f"({grid.joules_to_gco2(emb_chip*1e6, 'NY')/1e3:.1f} kgCO2eq @ NY fab)")
    print(f"fleet embodied amortizes vs operational in {t_amort:.1f} days at "
          f"100% duty (vs years on edge devices — the paper's split inverts)")
    print(f"\n{'cell':42s} {'J/token':>10s} {'gCO2/Mtok NY':>13s} "
          f"{'embodied gCO2/Mtok*':>20s}")
    for label, r in sorted(recs.items()):
        if r["mesh"] != "16x16" or r["shape"] not in ("decode_32k", "train_4k"):
            continue
        t = rl.RooflineTerms(r["flops_per_device"], r["bytes_per_device"],
                             r["collective_bytes_per_device"], r["n_devices"])
        se = energy.step_energy(t)
        jtok = se.energy_j / max(r["tokens_per_step"], 1)
        g_mtok = grid.joules_to_gco2(jtok, "NY") * 1e6
        # embodied carbon amortized over a 3-year 100%-duty token budget
        tokens_life = (3 * SECONDS_PER_YEAR / max(se.step_time_s, 1e-12)) \
            * r["tokens_per_step"]
        emb_mtok = grid.joules_to_gco2(emb_fleet_j, "NY") \
            / max(tokens_life / 1e6, 1e-12)
        print(f"{label:42s} {jtok:10.3g} {g_mtok:13.1f} {emb_mtok:20.3g}")
    print("\n* fleet embodied carbon spread over a 3-yr full-duty token "
          "budget — the per-workload form of the paper's Eq. 1 question")


if __name__ == "__main__":
    paper_tables()
    fleet_report()
