"""End-to-end training driver at ~100M scale (deliverable b).

On a TPU fleet this trains a ~100M-param gemma3-family model for a few
hundred steps with the full production stack (sharding, checkpointing,
heartbeats, carbon accounting). On this CPU container the same driver runs
with ``--cpu-scale`` (a ~2M model, identical code path); the 100M config's
distribution story is proven by `repro.launch.dryrun`.

    PYTHONPATH=src python examples/train_e2e.py --cpu-scale --steps 60
"""

import argparse
import json
import tempfile

import jax
import jax.numpy as jnp

from repro.core import accounting
from repro.data import DataConfig, make_pipeline
from repro.models import transformer as tf
from repro.optim import AdamWConfig
from repro.optim.schedules import warmup_cosine
from repro.checkpoint import CheckpointConfig
from repro.train import TrainConfig, Trainer
from repro.train.ft import HeartbeatWriter


def model_100m() -> tf.LMConfig:
    """~100M params: 12L, d=768, gemma3-style 5:1 local:global pattern."""
    local, glob = tf.BlockSpec(window=256), tf.BlockSpec(window=-1)
    return tf.LMConfig(name="e2e-100m", d_model=768, n_heads=12,
                       n_kv_heads=4, d_ff=3072, vocab=32768,
                       pattern=(local,) * 5 + (glob,), repeats=2,
                       act="gelu", remat="none")


def model_cpu() -> tf.LMConfig:
    local, glob = tf.BlockSpec(window=64), tf.BlockSpec(window=-1)
    return tf.LMConfig(name="e2e-cpu", d_model=128, n_heads=4, n_kv_heads=2,
                       d_ff=256, vocab=512, pattern=(local, glob), repeats=2,
                       act="gelu", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--cpu-scale", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grid-mix", default="NY")
    args = ap.parse_args()

    cfg = model_cpu() if args.cpu_scale else model_100m()
    params = tf.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32).params
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{cfg.n_layers} layers")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="e2e_ckpt_")
    hb_dir = tempfile.mkdtemp(prefix="e2e_hb_")
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=jax.device_count(),
        grid_mix=args.grid_mix))
    trainer = Trainer(
        loss_fn=lambda p, b: tf.loss_fn(p, cfg, b),
        params=params,
        opt_cfg=AdamWConfig(lr=warmup_cosine(3e-3, args.steps // 10,
                                             args.steps)),
        train_cfg=TrainConfig(num_steps=args.steps,
                              log_every=max(args.steps // 10, 1),
                              checkpoint_every=max(args.steps // 4, 1),
                              grad_accum=1),
        pipeline=make_pipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                          global_batch=args.batch,
                                          source="markov")),
        ckpt_cfg=CheckpointConfig(directory=ckpt_dir, keep_last=2),
        accountant=acct,
        heartbeat=HeartbeatWriter(hb_dir, host_id="host0"))
    trainer.install_preemption_handler()
    resumed = trainer.maybe_restore()
    print(f"{'resumed from step ' + str(trainer.step_num) if resumed else 'fresh start'}; "
          f"training {args.steps} steps...")
    trainer.run()
    for e in trainer.metrics_log:
        print(f"  step {e['step']:5d} loss={e['loss']:.3f} "
              f"gnorm={e.get('grad_norm', 0):.2f} "
              f"({e['step_time_s']*1e3:.0f} ms)")
    trainer.save(wait=True)
    print(f"checkpoints in {ckpt_dir}: latest step {trainer.ckpt.latest_step()}")
    print("carbon report:", json.dumps(acct.report(), default=float, indent=2))


if __name__ == "__main__":
    main()
