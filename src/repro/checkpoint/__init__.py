"""Fault-tolerant checkpointing substrate."""

from repro.checkpoint.manager import CheckpointManager, CheckpointConfig  # noqa: F401
