"""Atomic, async, resharding-aware checkpointing (pure numpy/npz backend).

Fault-tolerance contract (tested in tests/test_checkpoint.py):

* **Atomicity**: a checkpoint directory appears only via os.rename of a fully
  written tmp dir — a crash mid-save can never corrupt the latest checkpoint.
* **Async**: saves run on a writer thread off the training loop; ``wait()``
  joins before the next save or process exit.
* **Keep-k GC**: old steps are garbage-collected after a successful save.
* **Reshard-on-load**: arrays restore host-side and are device_put with the
  *target* sharding — restoring a 32-host checkpoint onto 24 healthy hosts
  (elastic restart) is the same code path as same-shape restore.
* **Iterator state**: the data-pipeline step rides in the manifest, so a
  restart replays the exact token stream.

Layout:  <dir>/ckpt_00000042/{manifest.json, arrays.npz}
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    keep_last: int = 3
    async_save: bool = True


def tree_checksum(named: List[Tuple[str, np.ndarray]],
                  extra: Dict[str, Any]) -> str:
    """Content checksum over a checkpoint's arrays (name, dtype, shape,
    raw bytes — in manifest order) and its ``extra`` dict (canonical JSON).
    Stored in the manifest at save and re-verified at restore: a flipped
    bit anywhere in the payload makes restore refuse loudly instead of
    serving corrupt state (DESIGN.md §19). Public so integrity tests can
    re-sign a deliberately doctored manifest and prove the load-time
    semantic checks are independent of this digest."""
    h = hashlib.sha256()
    for name, arr in named:
        a = np.ascontiguousarray(np.asarray(arr))
        h.update(name.encode("utf-8"))
        h.update(str(a.dtype).encode("utf-8"))
        h.update(repr(tuple(a.shape)).encode("utf-8"))
        h.update(a.tobytes())
    h.update(json.dumps(extra, sort_keys=True).encode("utf-8"))
    return h.hexdigest()


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        out.append((name, np.asarray(leaf)))
    return out


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- paths -----------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.cfg.directory, f"ckpt_{step:08d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.cfg.directory):
            if name.startswith("ckpt_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save --------------------------------------------------------------------

    def save(self, step: int, tree: PyTree,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot then (maybe async) persist. Host copy happens here so the
        caller may mutate/donate device arrays immediately after return."""
        self.wait()
        named = _flatten_with_names(tree)
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "names": [n for n, _ in named],
            "shapes": {n: list(a.shape) for n, a in named},
            "dtypes": {n: str(a.dtype) for n, a in named},
            "extra": extra or {},
        }
        manifest["checksum"] = tree_checksum(named, manifest["extra"])

        def _write():
            try:
                final = self._step_dir(step)
                tmp = tempfile.mkdtemp(prefix=f"ckpt_{step:08d}.tmp.",
                                       dir=self.cfg.directory)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{n: a for n, a in named})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.cfg.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}") from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.cfg.keep_last] if self.cfg.keep_last > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # clean stale tmp dirs from crashed saves
        for name in os.listdir(self.cfg.directory):
            if ".tmp." in name:
                shutil.rmtree(os.path.join(self.cfg.directory, name),
                              ignore_errors=True)

    def peek_extra(self, step: Optional[int] = None) -> Dict[str, Any]:
        """Read a checkpoint's manifest ``extra`` without loading arrays —
        restore paths that must rebuild their runtime to match the
        snapshot (e.g. the serve engine's int8->fp fallback flag) peek
        here BEFORE calling :meth:`restore` with a target tree."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoints in {self.cfg.directory}")
        with open(os.path.join(self._step_dir(step),
                               "manifest.json")) as f:
            return json.load(f).get("extra", {})

    # -- restore -------------------------------------------------------------------

    def restore(self, step: Optional[int] = None, *, target: PyTree = None,
                shardings: PyTree = None) -> Tuple[int, PyTree, Dict[str, Any]]:
        """Load a checkpoint.

        target: a pytree (arrays or ShapeDtypeStructs) giving the structure to
        restore into. shardings: matching NamedSharding pytree — arrays are
        device_put with these (reshard-on-load).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.cfg.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))
        by_name = {n: arrays[n] for n in manifest["names"]}

        # integrity gate: refuse a tampered/bit-rotted checkpoint before
        # any of it reaches the caller (pre-checksum checkpoints from
        # older saves carry no digest and skip the gate)
        want_sum = manifest.get("checksum")
        if want_sum is not None:
            got_sum = tree_checksum(
                [(n, by_name[n]) for n in manifest["names"]],
                manifest.get("extra", {}))
            if got_sum != want_sum:
                raise RuntimeError(
                    f"checkpoint {d} failed integrity check: manifest "
                    f"checksum {want_sum[:16]}..., recomputed "
                    f"{got_sum[:16]}... — refusing to restore corrupt "
                    f"state")

        if target is None:
            raise ValueError("restore requires a target structure")
        flat_t = jax.tree_util.tree_flatten_with_path(target)
        leaves = []
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(flat_t[0]))
        for (path, leaf), shard in zip(flat_t[0], shard_leaves):
            name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                            for p in path)
            if name not in by_name:
                raise KeyError(f"checkpoint missing leaf {name}")
            arr = by_name[name]
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {want_shape}")
            want_dtype = leaf.dtype
            val = jnp.asarray(arr, dtype=want_dtype)
            if shard is not None:
                val = jax.device_put(val, shard)
            leaves.append(val)
        tree = jax.tree_util.tree_unflatten(flat_t[1], leaves)
        return int(manifest["step"]), tree, manifest.get("extra", {})
