"""Architecture registry: the 10 assigned archs + the paper's own CNNs.

``get(arch_id)`` -> ArchSpec; ``REGISTRY`` lists all. Each arch module defines
``SPEC`` with the exact assigned config plus a reduced smoke config of the
same family.
"""

from repro.configs.base import ArchSpec, ShapeSpec, SHAPES, get, REGISTRY  # noqa: F401
