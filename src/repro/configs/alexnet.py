"""AlexNet — the paper's own Table-3/Fig-2 benchmark (not part of the 40-cell
LM grid). Ternary PIM inference + FP32 training workloads."""

import dataclasses

from repro.configs.base import ArchSpec
from repro.models import cnn


def make_config() -> cnn.CNNConfig:
    return cnn.ALEXNET


def make_smoke() -> cnn.CNNConfig:
    return dataclasses.replace(
        cnn.ALEXNET, name="alexnet-smoke", image_size=32,
        convs=cnn.ALEXNET.convs[:2], fcs=(64,), num_classes=10)


SPEC = ArchSpec(
    arch_id="alexnet", family="cnn", kind="cnn",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=61e6, long_context_ok=False,
    source="paper Table 3 / ELP^2IM [20] / FPIRM [19]",
    notes="paper-faithful workload: ternary inference (84.8 FPS DDR3-PIM / "
          "490 FPS RM-PIM) and FP32 training",
)
