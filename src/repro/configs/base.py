"""ArchSpec registry + the assigned input-shape grid.

Shapes (assignment):
  train_4k     seq 4096  x global_batch 256   (training: lowers train_step)
  prefill_32k  seq 32768 x global_batch 32    (inference prefill)
  decode_32k   seq 32768 x global_batch 128   (decode: 1 token, 32k KV)
  long_500k    seq 524288 x global_batch 1    (long-context decode; only for
               sub-quadratic archs — see DESIGN.md §8 for the skip list)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm|cnn
    kind: str                         # "lm" | "encdec" | "cnn"
    make_config: Callable             # () -> LMConfig / EncDecConfig / CNNConfig
    make_smoke: Callable              # () -> reduced config, same family
    params_nominal: float             # headline param count (B) from the pool
    long_context_ok: bool = False     # run long_500k?
    source: str = ""
    notes: str = ""
    # approximate share of params active per token (MoE); 1.0 for dense
    active_fraction: float = 1.0

    @property
    def shapes(self) -> Tuple[str, ...]:
        base = ("train_4k", "prefill_32k", "decode_32k")
        return base + (("long_500k",) if self.long_context_ok else ())


_ARCH_MODULES = [
    "gemma3_27b", "starcoder2_7b", "granite_34b", "qwen1_5_110b",
    "moonshot_v1_16b_a3b", "kimi_k2_1t_a32b", "whisper_large_v3",
    "zamba2_7b", "qwen2_vl_72b", "mamba2_1_3b", "alexnet", "vgg16",
]

REGISTRY: Dict[str, ArchSpec] = {}


def _load() -> None:
    if REGISTRY:
        return
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        spec: ArchSpec = mod.SPEC
        REGISTRY[spec.arch_id] = spec


def get(arch_id: str) -> ArchSpec:
    _load()
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def all_arch_ids(lm_only: bool = False) -> Tuple[str, ...]:
    _load()
    ids = tuple(sorted(a for a, s in REGISTRY.items()
                       if not lm_only or s.kind in ("lm", "encdec")))
    return ids
