"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import ArchSpec
from repro.models.transformer import BlockSpec, LMConfig

WINDOW = 1024  # gemma3 local sliding window

_LOCAL = BlockSpec(kind="attn", window=WINDOW)
_GLOBAL = BlockSpec(kind="attn", window=-1)


def make_config() -> LMConfig:
    # 62 layers = 10 x (5 local + 1 global) + 2 local tail
    return LMConfig(
        name="gemma3-27b",
        d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504, vocab=262144,
        pattern=(_LOCAL,) * 5 + (_GLOBAL,), repeats=10,
        tail=(_LOCAL, _LOCAL),
        act="gelu", rope_theta=10000.0, logit_softcap=0.0,
        tie_embeddings=True, remat="full",
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="gemma3-smoke",
        d_model=96, n_heads=4, n_kv_heads=2, d_ff=192, vocab=128,
        pattern=(BlockSpec(window=8),) * 2 + (BlockSpec(window=-1),),
        repeats=2, tail=(BlockSpec(window=8),),
        act="gelu", remat="none",
    )


SPEC = ArchSpec(
    arch_id="gemma3-27b", family="dense", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=27e9, long_context_ok=True,
    source="hf:google/gemma-3-1b-pt (family); unverified",
    notes="5:1 local(1024):global; long_500k runs (sub-quadratic local layers "
          "+ 10 global layers with sharded KV); ring_cache hillclimb target",
)
