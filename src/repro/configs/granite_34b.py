"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152 — llama-arch code model. [arXiv:2405.04324; hf]
"""

from repro.configs.base import ArchSpec
from repro.models.transformer import BlockSpec, LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="granite-34b",
        d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152,
        head_dim=128,
        pattern=(BlockSpec(),), repeats=88,
        act="gelu", mlp_gated=False, rope_theta=10000.0,
        tie_embeddings=True, remat="full",
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="granite-smoke",
        d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=128, head_dim=16,
        pattern=(BlockSpec(),), repeats=3,
        act="gelu", mlp_gated=False, remat="none",
    )


SPEC = ArchSpec(
    arch_id="granite-34b", family="dense", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=34e9, long_context_ok=False,
    source="arXiv:2405.04324; hf",
    notes="MQA (kv=1): KV replicates across TP ranks; deepest dense stack "
          "(88L); pure full attention -> long_500k skipped",
)
