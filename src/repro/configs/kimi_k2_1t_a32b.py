"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]

Config-derived counts: ~1.03T total, ~30B active — matches the headline.
The real model uses MLA; the assigned spec says GQA kv=8, which is what we
implement (DESIGN.md §10).

Memory note: at 1T params the optimizer must be quantized — the dry-run
lowers train_4k with bf16 Adam moments (+int8 option); single-pod (256 chip)
training is physically over-HBM and is recorded as such in EXPERIMENTS.md;
the multi-pod 512-chip mesh fits.
"""

from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import BlockSpec, LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="kimi-k2-1t-a32b",
        d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840,
        head_dim=112,
        pattern=(BlockSpec(moe=True),), repeats=61,
        moe_cfg=MoEConfig(d_model=7168, d_ff=2048, n_experts=384, top_k=8,
                          capacity_factor=1.25),
        act="silu", rope_theta=50000.0,
        tie_embeddings=True, remat="full", moe_group_size=4096,
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="kimi-smoke",
        d_model=64, n_heads=8, n_kv_heads=2, d_ff=48, vocab=128, head_dim=8,
        pattern=(BlockSpec(moe=True),), repeats=2,
        moe_cfg=MoEConfig(d_model=64, d_ff=48, n_experts=12, top_k=3,
                          capacity_factor=2.0),
        act="silu", remat="none", moe_group_size=64,
    )


SPEC = ArchSpec(
    arch_id="kimi-k2-1t-a32b", family="moe", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=1e12, long_context_ok=False,
    active_fraction=8.0 / 384.0,
    source="arXiv:2501.kimi2 (paper-table); unverified",
    notes="384 experts = 24/rank on 16-way model axis; kv=8 < 16 -> KV "
          "replicated; full attention -> long_500k skipped",
)
