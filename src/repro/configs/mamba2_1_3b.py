"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]
"""

from repro.configs.base import ArchSpec
from repro.models.ssd import SSDConfig
from repro.models.transformer import BlockSpec, LMConfig

_M = BlockSpec(kind="ssd", has_ffn=False)


def make_config() -> LMConfig:
    return LMConfig(
        name="mamba2-1.3b",
        d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280,
        pattern=(_M,), repeats=48,
        ssd_cfg=SSDConfig(d_model=2048, d_state=128, head_dim=64, expand=2,
                          n_groups=1, d_conv=4, chunk=256),
        pos_emb="none", act="silu",
        tie_embeddings=True, remat="full",
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="mamba2-smoke",
        d_model=64, n_heads=1, n_kv_heads=1, d_ff=0, vocab=128,
        pattern=(_M,), repeats=3,
        ssd_cfg=SSDConfig(d_model=64, d_state=16, head_dim=16, expand=2,
                          n_groups=1, d_conv=4, chunk=8),
        pos_emb="none", remat="none",
    )


SPEC = ArchSpec(
    arch_id="mamba2-1.3b", family="ssm", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=1.3e9, long_context_ok=True,
    source="arXiv:2405.21060; unverified",
    notes="attention-free: flash-attention kernel inapplicable (SSD chunked "
          "path instead — DESIGN.md §8); long_500k runs (O(1) decode state)",
)
