"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight family.
[hf:moonshotai/Moonlight-16B-A3B; hf]

Note (DESIGN.md §10): the config as assigned computes ~27B total / ~3.3B
active; the "16b" headline disagrees with the assigned layer count — the
assigned config is the contract.
"""

from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import BlockSpec, LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="moonshot-v1-16b-a3b",
        d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
        head_dim=128,
        pattern=(BlockSpec(moe=True),), repeats=48,
        moe_cfg=MoEConfig(d_model=2048, d_ff=1408, n_experts=64, top_k=6,
                          capacity_factor=1.25),
        act="silu", rope_theta=50000.0,
        tie_embeddings=True, remat="full", moe_group_size=4096,
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="moonshot-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=96, vocab=128, head_dim=16,
        pattern=(BlockSpec(moe=True),), repeats=2,
        moe_cfg=MoEConfig(d_model=64, d_ff=96, n_experts=8, top_k=2,
                          capacity_factor=2.0),
        act="silu", remat="none", moe_group_size=64,
    )


SPEC = ArchSpec(
    arch_id="moonshot-v1-16b-a3b", family="moe", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=16e9, long_context_ok=False,
    active_fraction=6.0 / 64.0,
    source="hf:moonshotai/Moonlight-16B-A3B",
    notes="64 experts shard 4-per-rank on the 16-way model axis; "
          "full attention -> long_500k skipped",
)
