"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias. [hf:Qwen/Qwen1.5-0.5B (family); hf]
"""

from repro.configs.base import ArchSpec
from repro.models.transformer import BlockSpec, LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-110b",
        d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152, vocab=152064,
        head_dim=128, qkv_bias=True,
        pattern=(BlockSpec(),), repeats=80,
        act="silu", mlp_gated=True, rope_theta=1e6,
        tie_embeddings=False, remat="full",
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="qwen1.5-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        qkv_bias=True, pattern=(BlockSpec(),), repeats=3,
        act="silu", tie_embeddings=False, remat="none",
    )


SPEC = ArchSpec(
    arch_id="qwen1.5-110b", family="dense", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=110e9, long_context_ok=False,
    source="hf:Qwen/Qwen1.5 family",
    notes="largest dense arch in the pool; QKV bias exercises the bias path; "
          "pure full attention -> long_500k skipped",
)
