"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only per the assignment: the vision tower is a STUB — input_specs
provides precomputed patch embeddings (B, 1024, d_model) merged at the front
of the sequence, plus (B, S, 3) t/h/w M-RoPE position ids.
"""

from repro.configs.base import ArchSpec
from repro.models.transformer import BlockSpec, LMConfig

VISION_TOKENS = 1024


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen2-vl-72b",
        d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064,
        head_dim=128, qkv_bias=True,
        pattern=(BlockSpec(),), repeats=80,
        pos_emb="mrope", mrope_sections=(16, 24, 24),
        vision_tokens=VISION_TOKENS,
        act="silu", rope_theta=1e6,
        tie_embeddings=False, remat="full",
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="qwen2vl-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128, head_dim=16,
        qkv_bias=True, pattern=(BlockSpec(),), repeats=2,
        pos_emb="mrope", mrope_sections=(2, 3, 3), vision_tokens=4,
        act="silu", tie_embeddings=False, remat="none",
    )


SPEC = ArchSpec(
    arch_id="qwen2-vl-72b", family="vlm", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=72e9, long_context_ok=False,
    source="arXiv:2409.12191; hf",
    notes="vision frontend stubbed (1024 patch embeddings); M-RoPE is real "
          "(3 position streams over disjoint frequency sections); "
          "full attention -> long_500k skipped",
)
