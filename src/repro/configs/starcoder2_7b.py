"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE. [arXiv:2402.19173; hf]

36 heads don't divide the 16-way model axis: the sharding layer replicates
heads and TPs the (non-gated) FFN — a deliberate §Perf baseline/hillclimb.
"""

from repro.configs.base import ArchSpec
from repro.models.transformer import BlockSpec, LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="starcoder2-7b",
        d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152,
        head_dim=128,
        pattern=(BlockSpec(),), repeats=32,
        act="gelu", mlp_gated=False, rope_theta=1e5,
        tie_embeddings=True, remat="full",
        # §Perf HC-A: context-parallel attention + seq-sharded residual —
        # the 36-head TP fallback otherwise replicates attention across the
        # model axis (collective term 399 s -> 4.1 s on prefill_32k)
        sp_attention=True, sp_residual=True,
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="starcoder2-smoke",
        d_model=72, n_heads=6, n_kv_heads=2, d_ff=144, vocab=128, head_dim=16,
        pattern=(BlockSpec(),), repeats=3,
        act="gelu", mlp_gated=False, remat="none",
    )


SPEC = ArchSpec(
    arch_id="starcoder2-7b", family="dense", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=7e9, long_context_ok=False,
    source="arXiv:2402.19173; hf",
    notes="36H % 16 != 0 -> heads replicate on model axis (baseline); "
          "pure full attention -> long_500k skipped",
)
