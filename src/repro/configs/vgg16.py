"""VGG-16 — the paper's second Table-3 benchmark (not part of the 40-cell
LM grid)."""

import dataclasses

from repro.configs.base import ArchSpec
from repro.models import cnn


def make_config() -> cnn.CNNConfig:
    return cnn.VGG16


def make_smoke() -> cnn.CNNConfig:
    return dataclasses.replace(
        cnn.VGG16, name="vgg16-smoke", image_size=32,
        convs=cnn.VGG16.convs[:4], fcs=(64,), num_classes=10)


SPEC = ArchSpec(
    arch_id="vgg16", family="cnn", kind="cnn",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=138e6, long_context_ok=False,
    source="paper Table 3 / EF-Train [1] / FPIRM [19]",
    notes="paper-faithful FP32 training workload (GPU 848 GFLOPS / RM 81.95 "
          "/ FPGA 46.99)",
)
