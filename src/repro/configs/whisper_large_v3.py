"""whisper-large-v3 [audio]: enc-dec, 32+32L d_model=1280 20H d_ff=5120
vocab=51866 — conv frontend is a STUB (input_specs provides precomputed mel
frame embeddings). [arXiv:2212.04356; unverified]

20 heads don't divide the 16-way model axis -> heads replicate, FFN TPs
(same fallback family as starcoder2). Decode shapes run (enc-dec has a
decoder); long_500k skipped (30 s audio context makes 500k decode
architecturally meaningless).
"""

from repro.configs.base import ArchSpec
from repro.models.encdec import EncDecConfig


def make_config() -> EncDecConfig:
    return EncDecConfig(
        name="whisper-large-v3",
        n_enc_layers=32, n_dec_layers=32,
        d_model=1280, n_heads=20, d_ff=5120, vocab=51866,
        n_audio_ctx=1500, act="gelu",
        # §Perf HC-A (same fallback family as starcoder2): 20 heads don't
        # divide the 16-way model axis -> context-parallel attention
        sp_attention=True,
    )


def make_smoke() -> EncDecConfig:
    return EncDecConfig(
        name="whisper-smoke",
        n_enc_layers=2, n_dec_layers=2,
        d_model=64, n_heads=4, d_ff=128, vocab=128,
        n_audio_ctx=16, act="gelu",
    )


SPEC = ArchSpec(
    arch_id="whisper-large-v3", family="audio", kind="encdec",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=1.55e9, long_context_ok=False,
    source="arXiv:2212.04356; unverified",
    notes="modality frontend stubbed: input_specs provides (B,1500,d) frame "
          "embeddings; train_4k/prefill_32k drive the decoder at the LM "
          "shape grid (mechanical; beyond whisper's 448-token design)",
)
