"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
ssm_state=64 — Mamba2 blocks + shared attention blocks.
[arXiv:2411.15242; unverified]

Modeled as 13 x (5 mamba + 1 shared-attn invocation) + 3 mamba tail = 81
layer slots with ONE shared attention/MLP parameter set (real zamba2
alternates two shared blocks with per-site LoRA — simplification recorded in
DESIGN.md §10).
"""

from repro.configs.base import ArchSpec
from repro.models.ssd import SSDConfig
from repro.models.transformer import BlockSpec, LMConfig

_M = BlockSpec(kind="ssd", has_ffn=False)
_A = BlockSpec(kind="attn", shared_attn=True)


def make_config() -> LMConfig:
    return LMConfig(
        name="zamba2-7b",
        d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
        head_dim=112,
        pattern=(_M, _M, _M, _M, _M, _A), repeats=13,
        tail=(_M, _M, _M),
        ssd_cfg=SSDConfig(d_model=3584, d_state=64, head_dim=64, expand=2,
                          n_groups=1, d_conv=4, chunk=256),
        act="gelu", rope_theta=10000.0,
        tie_embeddings=True, remat="full",
    )


def make_smoke() -> LMConfig:
    return LMConfig(
        name="zamba2-smoke",
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128, head_dim=16,
        pattern=(_M, _M, _A), repeats=2, tail=(_M,),
        ssd_cfg=SSDConfig(d_model=64, d_state=16, head_dim=16, expand=2,
                          n_groups=1, d_conv=4, chunk=8),
        act="gelu", remat="none",
    )


SPEC = ArchSpec(
    arch_id="zamba2-7b", family="hybrid", kind="lm",
    make_config=make_config, make_smoke=make_smoke,
    params_nominal=7e9, long_context_ok=True,
    source="arXiv:2411.15242; unverified",
    notes="sub-quadratic (SSM backbone; 13 attention sites) -> long_500k "
          "runs; decode state = SSD states + 13 shared-attn KV slots",
)
