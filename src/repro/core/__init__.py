"""Core sustainability engine — the paper's primary contribution.

Layers (see DESIGN.md §1):
  hw          platform database (paper Table 2/3 devices + TPU v5e fleet target)
  grid        grid-mix carbon intensity (Table 1)
  lca         process-LCA embodied energy/carbon (Table 2)
  sustain     Eq. 1 indifference/break-even + GreenChip duty model (Fig. 2)
  energy      operational energy & Table-3 efficiency columns
  roofline    three-term roofline from compiled XLA artifacts
  accounting  CarbonAccountant (live holistic accounting in train/serve loops)
  advisor     platform/fleet decision procedure
"""

from repro.core import (  # noqa: F401
    accounting,
    advisor,
    energy,
    grid,
    hw,
    lca,
    roofline,
    sustain,
)

CarbonAccountant = accounting.CarbonAccountant
AccountantConfig = accounting.AccountantConfig
RooflineTerms = roofline.RooflineTerms
Duty = sustain.Duty
Platform = sustain.Platform
