"""CarbonAccountant — the paper's holistic evaluation wired into the runtime.

A first-class training/serving-loop component: every step reports its wall
time (measured, or the roofline bound when dry-running), the accountant
accumulates operational energy/carbon, tracks the fleet's embodied budget
(paper Eq. 1's M term), and answers "has this deployment amortized its
embodied energy yet?" — the paper's core question, asked live.

Thread-safe and cheap (pure python floats); the Trainer calls ``observe_step``
outside jit.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Dict, Optional

from repro.core import energy, grid, hw, lca, roofline as rl

SECONDS_PER_YEAR = 365.0 * 86400.0


@dataclasses.dataclass
class AccountantConfig:
    device: str = "tpu_v5e"
    n_devices: int = 1
    grid_mix: str = "NY"
    # Embodied energy per device (J). None -> auto from the LCA layer.
    embodied_j_per_device: Optional[float] = None
    # Duty model for extrapolations (activity of the fleet over its life).
    activity: float = 1.0
    sleep_ratio: float = 0.0
    service_years: float = 3.0


class CarbonAccountant:
    def __init__(self, config: AccountantConfig):
        self.config = config
        self._spec = hw.DEVICES[config.device]
        if config.embodied_j_per_device is not None:
            self._embodied_j_dev = config.embodied_j_per_device
        elif config.device == "tpu_v5e":
            self._embodied_j_dev = lca.tpu_package_embodied_mj() * 1e6
        else:
            self._embodied_j_dev = lca.embodied_energy_mj(self._spec) * 1e6
        self._lock = threading.Lock()
        self._steps = 0
        self._tokens = 0.0
        self._active_s = 0.0
        self._bytes_moved = 0.0
        self._modeled_flops = 0.0
        # prefix-cache ledger (DESIGN.md §14): prompt tokens served from
        # reused KV pages, and the DRAM/FLOP bill they avoided — the
        # sustainability win of paged serving, reported first-class
        self._prefill_tokens = 0.0
        self._prefix_hit_tokens = 0.0
        self._saved_bytes = 0.0
        self._saved_flops = 0.0
        # long-context ledger (DESIGN.md §16): the cached-window gather
        # share of prefill DRAM traffic (the fragmentation-sensitive term
        # the paged prefill kernel bounds) and pages relocated by
        # page-table compaction
        self._prefill_gather_bytes = 0.0
        self._compaction_moves = 0.0
        # speculative-decode ledger (DESIGN.md §15): draft and verify
        # phases bill separately — the drafter may be nearly free (n-gram
        # history scan) or a full extra model pass per draft token
        # (oracle), and the sustainability claim is J per *accepted* token
        self._spec_draft_tokens = 0.0
        self._spec_accepted_tokens = 0.0
        self._draft_flops = 0.0
        self._draft_bytes = 0.0
        self._verify_flops = 0.0
        self._verify_bytes = 0.0
        # copy-on-write ledger (DESIGN.md §18): pages copied when a forked
        # slot first writes into shared KV (the price of fork isolation)
        # vs. the duplicate prompt KV bytes and prefill FLOPs the forks
        # did NOT spend — the n-best sustainability claim, first-class
        self._cow_bytes = 0.0
        self._cow_copies = 0.0
        self._forks = 0.0
        self._fork_saved_bytes = 0.0
        self._fork_saved_flops = 0.0
        # resilience ledger (DESIGN.md §17): the energy cost of *recovery*
        # — re-prefilling quarantined slots' context after a fault — bills
        # first-class next to prefill and gather traffic ("On the
        # Sustainability of AI Inferences in the Edge", PAPERS.md), plus
        # the degradation counters (shed requests never produced tokens
        # but still consumed admission work)
        self._recovery_tokens = 0.0
        self._recovery_flops = 0.0
        self._recovery_bytes = 0.0
        self._quarantined = 0.0
        self._shed = 0.0
        # chaos-exposure counters (repro-lint L401 closed the gap): faults
        # the injector landed, ticks served under a degradation rung, and
        # torn-readback re-reads — each retry is a real extra device→host
        # transfer the ONE-readback budget had to pay twice for. Needed to
        # interpret recovery_j (joules per fault, not just per run) and to
        # weigh degraded-mode J/token in the advisor.
        self._faults_injected = 0.0
        self._degraded_ticks = 0.0
        self._readback_retries = 0.0
        # durability ledger (DESIGN.md §19): what crash-consistency costs —
        # snapshot + journal bytes written to persistent storage (billed at
        # the per-byte DRAM cost as a floor) and the replayed recompute a
        # warm restart spent re-deriving post-snapshot state. The
        # checkpoint-interval J/token vs. recovery-time tradeoff reads
        # straight off these channels.
        self._snapshot_bytes = 0.0
        self._journal_bytes = 0.0
        self._restore_flops = 0.0
        self._restore_bytes = 0.0
        self._replayed_ticks = 0.0
        self._snapshots = 0.0
        # training-phase ledgers (DESIGN.md §13): forward and backward bill
        # separately — the per-phase split the edge-training literature
        # (DeepEn2023, Sobhani et al.) calls for
        self._train_steps = 0
        self._train_samples = 0.0
        self._fwd_flops = 0.0
        self._bwd_flops = 0.0
        self._fwd_bytes = 0.0
        self._bwd_bytes = 0.0
        self._opt_bytes = 0.0
        self._wall_start = time.monotonic()

    # -- observation ---------------------------------------------------------

    def observe_step(self, step_time_s: float, n_tokens: float = 0.0) -> None:
        with self._lock:
            self._steps += 1
            self._tokens += n_tokens
            self._active_s += step_time_s

    def observe_roofline(self, terms: rl.RooflineTerms, n_tokens: float = 0.0) -> None:
        """Dry-run variant: bill the roofline-bound step time."""
        self.observe_step(terms.step_time_s, n_tokens)

    def observe_serve(self, metrics) -> None:
        """Bill one serve-engine tick (serve.StepMetrics-shaped: ``wall_s``
        wall seconds, ``tokens`` decode tokens) — the live J/token path.

        Ticks that report dtype-aware traffic (``weight_bytes``/``kv_bytes``)
        and modeled ``flops`` additionally feed the per-byte DRAM + FLOPs
        energy model (core.energy, DESIGN.md §12) — the channel where the
        int8 serving path's byte reduction becomes a visible J/token drop."""
        self.observe_step(metrics.wall_s, n_tokens=float(metrics.tokens))
        n_bytes = (float(getattr(metrics, "weight_bytes", 0.0))
                   + float(getattr(metrics, "kv_bytes", 0.0)))
        flops = float(getattr(metrics, "flops", 0.0))
        with self._lock:
            self._bytes_moved += n_bytes
            self._modeled_flops += flops
            self._prefill_tokens += float(getattr(metrics,
                                                  "prefill_tokens", 0.0))
            self._prefix_hit_tokens += float(getattr(metrics,
                                                     "prefix_hit_tokens",
                                                     0.0))
            self._saved_bytes += float(getattr(metrics, "saved_bytes", 0.0))
            self._saved_flops += float(getattr(metrics, "saved_flops", 0.0))
            self._prefill_gather_bytes += float(
                getattr(metrics, "prefill_gather_bytes", 0.0))
            self._compaction_moves += float(
                getattr(metrics, "compaction_moves", 0.0))
            self._spec_draft_tokens += float(
                getattr(metrics, "spec_draft_tokens", 0.0))
            self._spec_accepted_tokens += float(
                getattr(metrics, "spec_accepted_tokens", 0.0))
            self._draft_flops += float(getattr(metrics, "draft_flops", 0.0))
            self._draft_bytes += float(getattr(metrics, "draft_bytes", 0.0))
            self._verify_flops += float(
                getattr(metrics, "verify_flops", 0.0))
            self._verify_bytes += float(
                getattr(metrics, "verify_bytes", 0.0))
            self._cow_bytes += float(getattr(metrics, "cow_bytes", 0.0))
            self._cow_copies += float(getattr(metrics, "cow_copies", 0.0))
            self._forks += float(getattr(metrics, "forks", 0.0))
            self._fork_saved_bytes += float(
                getattr(metrics, "fork_saved_bytes", 0.0))
            self._fork_saved_flops += float(
                getattr(metrics, "fork_saved_flops", 0.0))
            self._recovery_tokens += float(
                getattr(metrics, "recovery_tokens", 0.0))
            self._recovery_flops += float(
                getattr(metrics, "recovery_flops", 0.0))
            self._recovery_bytes += float(
                getattr(metrics, "recovery_bytes", 0.0))
            self._quarantined += float(getattr(metrics, "quarantined", 0.0))
            self._shed += float(getattr(metrics, "shed", 0.0))
            self._faults_injected += float(
                getattr(metrics, "faults_injected", 0.0))
            self._degraded_ticks += float(getattr(metrics, "degraded", 0.0))
            self._readback_retries += float(
                getattr(metrics, "readback_retries", 0.0))

    def observe_durability(self, *, snapshot_bytes: float = 0.0,
                           journal_bytes: float = 0.0,
                           restore_flops: float = 0.0,
                           restore_bytes: float = 0.0,
                           replayed_ticks: float = 0.0,
                           snapshots: float = 0.0) -> None:
        """Bill durability work (DESIGN.md §19): snapshot/journal writes as
        they land on disk, and replayed recompute during a warm restart.
        Replay's flops/bytes are ALSO observed via observe_serve (the
        recompute is physically real) — this channel breaks the same
        joules out so restore cost is visible next to recovery_j."""
        with self._lock:
            self._snapshot_bytes += float(snapshot_bytes)
            self._journal_bytes += float(journal_bytes)
            self._restore_flops += float(restore_flops)
            self._restore_bytes += float(restore_bytes)
            self._replayed_ticks += float(replayed_ticks)
            self._snapshots += float(snapshots)

    def observe_train(self, metrics) -> None:
        """Bill one train-engine tick (train.TrainStepMetrics-shaped).

        ``wall_s``/``tokens`` feed the wall-clock ledger exactly like serve
        ticks; the per-phase modeled terms (``fwd_flops``/``bwd_flops``,
        ``fwd_bytes``/``bwd_bytes``/``opt_bytes``) land in separate
        forward/backward ledgers so J/step splits by phase in report() —
        and the grand bytes/FLOPs totals stay comparable with serving."""
        self.observe_step(metrics.wall_s, n_tokens=float(metrics.tokens))
        with self._lock:
            self._train_steps += int(getattr(metrics, "steps", 1))
            self._train_samples += float(getattr(metrics, "samples", 0.0))
            self._fwd_flops += float(getattr(metrics, "fwd_flops", 0.0))
            self._bwd_flops += float(getattr(metrics, "bwd_flops", 0.0))
            self._fwd_bytes += float(getattr(metrics, "fwd_bytes", 0.0))
            self._bwd_bytes += float(getattr(metrics, "bwd_bytes", 0.0))
            self._opt_bytes += float(getattr(metrics, "opt_bytes", 0.0))
            self._bytes_moved += (float(getattr(metrics, "fwd_bytes", 0.0))
                                  + float(getattr(metrics, "bwd_bytes", 0.0))
                                  + float(getattr(metrics, "opt_bytes", 0.0)))
            self._modeled_flops += (float(getattr(metrics, "fwd_flops", 0.0))
                                    + float(getattr(metrics, "bwd_flops", 0.0)))

    # -- accounting ----------------------------------------------------------

    @property
    def embodied_j(self) -> float:
        return self._embodied_j_dev * self.config.n_devices

    @property
    def operational_j(self) -> float:
        """Energy so far: active time at P_active + residual wall time idle."""
        p = self._spec.power
        wall = max(time.monotonic() - self._wall_start, self._active_s)
        idle_s = wall - self._active_s
        return self.config.n_devices * (self._active_s * p.active_w
                                        + idle_s * p.idle_w)

    @property
    def operational_active_j(self) -> float:
        return self.config.n_devices * self._active_s * self._spec.power.active_w

    def carbon_g(self, *, include_embodied: bool = True,
                 fab_mix: Optional[str] = None) -> float:
        g = grid.joules_to_gco2(self.operational_j, self.config.grid_mix)
        if include_embodied:
            g += grid.joules_to_gco2(self.embodied_j, fab_mix or self.config.grid_mix)
        return g

    def amortized_fraction(self) -> float:
        """Operational / (operational + embodied): how far into the lifecycle
        the deployment is. The paper: embodied can be 80-90% for edge."""
        op = self.operational_active_j
        total = op + self.embodied_j
        return op / total if total > 0 else 0.0

    def breakeven_vs(self, rival_power_w: float) -> float:
        """Years to amortize this fleet's embodied energy against a rival
        platform whose average power for the same work is ``rival_power_w``
        (Eq. 1's t_B at the observed duty)."""
        from repro.core import sustain
        p_self = sustain.average_power_w(self._spec.power, self.config.activity,
                                         self.config.sleep_ratio)
        p_self_total = p_self * self.config.n_devices
        dp = rival_power_w - p_self_total
        if dp <= 0:
            return float("inf")
        return self.embodied_j / dp / SECONDS_PER_YEAR

    @property
    def modeled_dram_j(self) -> float:
        return energy.dram_energy_j(self._bytes_moved)

    @property
    def modeled_compute_j(self) -> float:
        return energy.compute_energy_j(self._modeled_flops, self._spec)

    def train_report(self) -> Optional[Dict]:
        """Per-phase training energy (None until observe_train was called).

        ``fwd_j``/``bwd_j`` are the modeled FLOPs + per-byte DRAM energy of
        the forward and backward phases; ``opt_j`` the optimizer-update
        traffic. J/step and J/sample put on-line training next to the serve
        path's J/token (paper Table 3's train rows, live)."""
        if self._train_steps == 0:
            return None
        cost = energy.TrainStepCost(
            fwd_flops=self._fwd_flops, bwd_flops=self._bwd_flops,
            fwd_bytes=self._fwd_bytes, bwd_bytes=self._bwd_bytes,
            opt_bytes=self._opt_bytes)
        phases = energy.train_phase_energy_j(cost, self._spec)
        n = self._train_steps
        return {
            "steps": n,
            "samples": self._train_samples,
            "fwd_flops": self._fwd_flops,
            "bwd_flops": self._bwd_flops,
            "fwd_bytes": self._fwd_bytes,
            "bwd_bytes": self._bwd_bytes,
            "opt_bytes": self._opt_bytes,
            **phases,
            "j_per_step": phases["total_j"] / n,
            "j_per_sample": (phases["total_j"] / self._train_samples
                             if self._train_samples > 0 else None),
            "bwd_fwd_ratio": (phases["bwd_j"] / phases["fwd_j"]
                              if phases["fwd_j"] > 0 else None),
        }

    def spec_report(self) -> Optional[Dict]:
        """Speculative-decode phase split (None until a spec tick was
        observed). ``j_per_accepted_token`` is the modeled energy per
        EMITTED decode token (accepted drafts + corrections — what the
        user receives), the metric the paper's throughput-per-joule
        argument cares about; every ratio degrades to 0.0 on empty or
        all-rejected workloads."""
        if self._spec_draft_tokens <= 0:
            return None
        modeled_j = self.modeled_compute_j + self.modeled_dram_j
        return {
            "draft_tokens": self._spec_draft_tokens,
            "accepted_tokens": self._spec_accepted_tokens,
            "accept_rate": (self._spec_accepted_tokens
                            / self._spec_draft_tokens),
            "draft_flops": self._draft_flops,
            "draft_bytes": self._draft_bytes,
            "verify_flops": self._verify_flops,
            "verify_bytes": self._verify_bytes,
            "draft_j": (energy.compute_energy_j(self._draft_flops,
                                                self._spec)
                        + energy.dram_energy_j(self._draft_bytes)),
            "verify_j": (energy.compute_energy_j(self._verify_flops,
                                                 self._spec)
                         + energy.dram_energy_j(self._verify_bytes)),
            "j_per_accepted_token": (modeled_j / self._tokens
                                     if self._tokens > 0 else 0.0),
        }

    def report(self) -> Dict:
        op = self.operational_active_j
        modeled_j = self.modeled_compute_j + self.modeled_dram_j
        train = self.train_report()
        spec = self.spec_report()
        prompt_toks = self._prefill_tokens + self._prefix_hit_tokens
        return {
            **({"train": train} if train else {}),
            **({"spec": spec} if spec else {}),
            "bytes_moved": self._bytes_moved,
            "modeled_flops": self._modeled_flops,
            # prefix-cache savings (zero for non-paged serving): what the
            # reused pages did NOT cost in DRAM energy (paper Eq. energy
            # per byte) and compute
            "prefix_hit_tokens": self._prefix_hit_tokens,
            "prefix_hit_rate": (self._prefix_hit_tokens / prompt_toks
                                if prompt_toks > 0 else 0.0),
            "saved_bytes": self._saved_bytes,
            "saved_dram_j": energy.dram_energy_j(self._saved_bytes),
            "saved_compute_j": energy.compute_energy_j(self._saved_flops,
                                                       self._spec),
            # long-context tier (DESIGN.md §16): gather share of the
            # prefill DRAM bill, and its energy at the per-byte DRAM cost
            "prefill_gather_bytes": self._prefill_gather_bytes,
            "prefill_gather_dram_j": energy.dram_energy_j(
                self._prefill_gather_bytes),
            "compaction_moves": self._compaction_moves,
            # copy-on-write tier (DESIGN.md §18): what fork isolation cost
            # (page copies, already inside bytes_moved) vs. the duplicate
            # prompt KV writes and prefill compute the forks avoided by
            # sharing pages. Zero on fork-free runs.
            "cow_bytes": self._cow_bytes,
            "cow_copies": self._cow_copies,
            "cow_dram_j": energy.dram_energy_j(self._cow_bytes),
            "forks": self._forks,
            "fork_saved_bytes": self._fork_saved_bytes,
            "fork_saved_dram_j": energy.dram_energy_j(
                self._fork_saved_bytes),
            "fork_saved_compute_j": energy.compute_energy_j(
                self._fork_saved_flops, self._spec),
            # resilience tier (DESIGN.md §17): what recovery — the
            # re-prefill of quarantined slots' context — cost in modeled
            # energy, and the degradation counters. Ratios degrade to
            # 0.0 on fault-free runs (never NaN/raise).
            "quarantined": self._quarantined,
            "shed": self._shed,
            "faults_injected": self._faults_injected,
            "degraded_ticks": self._degraded_ticks,
            "degraded_tick_rate": (self._degraded_ticks / self._steps
                                   if self._steps > 0 else 0.0),
            "readback_retries": self._readback_retries,
            "recovery_tokens": self._recovery_tokens,
            "recovery_j_per_fault": (
                (energy.compute_energy_j(self._recovery_flops, self._spec)
                 + energy.dram_energy_j(self._recovery_bytes))
                / self._faults_injected
                if self._faults_injected > 0 else 0.0),
            "recovery_j": (energy.compute_energy_j(self._recovery_flops,
                                                   self._spec)
                           + energy.dram_energy_j(self._recovery_bytes)),
            "recovery_j_per_token": (
                (energy.compute_energy_j(self._recovery_flops, self._spec)
                 + energy.dram_energy_j(self._recovery_bytes))
                / self._tokens if self._tokens > 0 else 0.0),
            # durability tier (DESIGN.md §19): snapshot/journal write
            # traffic and warm-restart replay recompute. All 0.0 on a run
            # that never checkpoints (zero-state guard, regression-locked).
            "snapshots_taken": self._snapshots,
            "snapshot_bytes": self._snapshot_bytes,
            "journal_bytes": self._journal_bytes,
            "replayed_ticks": self._replayed_ticks,
            "restore_j": (energy.compute_energy_j(self._restore_flops,
                                                  self._spec)
                          + energy.dram_energy_j(self._restore_bytes)),
            "restore_j_per_token": (
                (energy.compute_energy_j(self._restore_flops, self._spec)
                 + energy.dram_energy_j(self._restore_bytes))
                / self._tokens if self._tokens > 0 else 0.0),
            "durability_write_j": energy.dram_energy_j(
                self._snapshot_bytes + self._journal_bytes),
            "modeled_dram_j": self.modeled_dram_j,
            "modeled_compute_j": self.modeled_compute_j,
            "modeled_j_per_token": (modeled_j / self._tokens
                                    if self._tokens > 0 else None),
            "device": self.config.device,
            "n_devices": self.config.n_devices,
            "grid_mix": self.config.grid_mix,
            "steps": self._steps,
            "tokens": self._tokens,
            "active_s": self._active_s,
            "embodied_j": self.embodied_j,
            "embodied_gco2": grid.joules_to_gco2(self.embodied_j, self.config.grid_mix),
            "operational_j": op,
            "operational_gco2": grid.joules_to_gco2(op, self.config.grid_mix),
            "amortized_fraction": self.amortized_fraction(),
            "tokens_per_j": (self._tokens / op) if op > 0 else None,
            "j_per_token": (op / self._tokens) if self._tokens > 0 else None,
            "gco2_per_mtoken": (grid.joules_to_gco2(op, self.config.grid_mix)
                                / (self._tokens / 1e6)) if self._tokens else None,
        }

    # every accumulated ledger — the crash-consistent snapshot payload
    # (DESIGN.md §19). Identity/config (_spec, _embodied_j_dev, config)
    # and the wall-clock anchor (_wall_start) stay the restored
    # instance's own: a restore resumes counting, not the dead clock.
    _LEDGER_FIELDS = (
        "_steps", "_tokens", "_active_s", "_bytes_moved", "_modeled_flops",
        "_prefill_tokens", "_prefix_hit_tokens", "_saved_bytes",
        "_saved_flops", "_prefill_gather_bytes", "_compaction_moves",
        "_spec_draft_tokens", "_spec_accepted_tokens", "_draft_flops",
        "_draft_bytes", "_verify_flops", "_verify_bytes",
        "_cow_bytes", "_cow_copies", "_forks", "_fork_saved_bytes",
        "_fork_saved_flops", "_recovery_tokens", "_recovery_flops",
        "_recovery_bytes", "_quarantined", "_shed",
        "_faults_injected", "_degraded_ticks", "_readback_retries",
        "_snapshot_bytes", "_journal_bytes", "_restore_flops",
        "_restore_bytes", "_replayed_ticks", "_snapshots",
        "_train_steps", "_train_samples", "_fwd_flops", "_bwd_flops",
        "_fwd_bytes", "_bwd_bytes", "_opt_bytes")

    def state_dict(self) -> Dict:
        """JSON-serializable counter state for engine snapshots."""
        with self._lock:
            return {k: getattr(self, k) for k in self._LEDGER_FIELDS}

    def load_state(self, d: Dict) -> None:
        """Restore counters saved by :meth:`state_dict` (missing keys keep
        their fresh-instance zeros — older snapshots stay loadable)."""
        with self._lock:
            for k in self._LEDGER_FIELDS:
                if k in d:
                    cast = int if k in ("_steps", "_train_steps") else float
                    setattr(self, k, cast(d[k]))

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=2)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        r = self.report()
        return (f"CarbonAccountant(steps={r['steps']}, "
                f"op={r['operational_j']:.3g} J, "
                f"embodied={r['embodied_j']:.3g} J, "
                f"amortized={r['amortized_fraction']:.2%})")
