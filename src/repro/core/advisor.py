"""Sustainability advisor — the paper's decision procedure as an API.

Answers the deployment questions the paper poses:

* "Which accelerator minimizes holistic energy for this workload, duty cycle
  and service time?" (Fig. 2 / Eq. 1, incl. the FPGA-dominated case)
* "Given an already-deployed incumbent, when does replacing it break even?"
* Beyond paper: "Which mesh/fleet size minimizes carbon per token for this
  architecture?" — driven by dry-run roofline terms.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core import energy, hw, roofline as rl, sustain


@dataclasses.dataclass
class Recommendation:
    winner: str
    totals_j: Dict[str, float]
    dominated: List[str]
    indifference: Dict[str, float]       # pair -> t_I (years)
    narrative: List[str]


def recommend(platforms: Sequence[sustain.Platform], duty: sustain.Duty,
              service_time_s: float,
              ref_throughput: Optional[float] = None) -> Recommendation:
    totals = sustain.decide(list(platforms), duty, service_time_s, ref_throughput)
    winner = min(totals, key=totals.get)
    narrative: List[str] = []
    by_name = {p.name: p for p in platforms}

    # dominance: platform is dominated if another has both lower embodied and
    # lower average operational power (the paper's FPGA observation).
    ref = ref_throughput if ref_throughput is not None else min(
        p.throughput for p in platforms)
    avg_p = {p.name: p.average_power_w(duty, ref) for p in platforms}
    dominated = []
    for a in platforms:
        for b in platforms:
            if b.name == a.name:
                continue
            if (b.embodied_j <= a.embodied_j and avg_p[b.name] <= avg_p[a.name]
                    and (b.embodied_j < a.embodied_j or avg_p[b.name] < avg_p[a.name])):
                dominated.append(a.name)
                narrative.append(
                    f"{a.name} is dominated by {b.name} (higher embodied and "
                    f"higher operational energy): indifference never selects it.")
                break

    indiff: Dict[str, float] = {}
    names = [p.name for p in platforms if p.name not in dominated]
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            hi, lo = (a, b) if by_name[a].embodied_j >= by_name[b].embodied_j else (b, a)
            t = sustain.indifference_time_s(
                by_name[hi].embodied_j, by_name[lo].embodied_j,
                avg_p[lo], avg_p[hi])
            indiff[f"{hi}-vs-{lo}"] = t / sustain.SECONDS_PER_YEAR
            if math.isinf(t):
                narrative.append(
                    f"{hi} never amortizes its embodied-energy premium over "
                    f"{lo} at activity={duty.activity:.0%}.")
            else:
                pick = hi if service_time_s > t else lo
                narrative.append(
                    f"{hi} vs {lo}: t_I = {t / sustain.SECONDS_PER_YEAR:.2f} yr "
                    f"at activity={duty.activity:.0%} -> choose {pick} for the "
                    f"proposed service time.")
    narrative.append(f"Minimum holistic energy: {winner}.")
    return Recommendation(winner, totals, dominated, indiff, narrative)


# ---------------------------------------------------------------------------
# Beyond paper: fleet/mesh advisor from roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshOption:
    label: str
    terms: rl.RooflineTerms
    tokens_per_step: float


def fleet_recommend(options: Sequence[MeshOption], grid_mix: str,
                    service_years: float = 3.0,
                    activity: float = 1.0) -> Dict[str, Dict[str, float]]:
    """Carbon per token + embodied amortization for each mesh option.

    The paper's insight at fleet scale: more chips lower step time (operational
    energy/token roughly constant or worse due to collectives) but add embodied
    carbon; the right size is the smallest fleet that meets the service-rate
    requirement — quantified here.
    """
    from repro.core import lca
    out: Dict[str, Dict[str, float]] = {}
    for opt in options:
        se = energy.step_energy(opt.terms)
        embodied_j = lca.tpu_package_embodied_mj() * 1e6 * opt.terms.n_devices
        service_s = service_years * sustain.SECONDS_PER_YEAR * activity
        steps_life = service_s / max(se.step_time_s, 1e-12)
        tokens_life = steps_life * opt.tokens_per_step
        op_j_life = se.energy_j * steps_life
        from repro.core import grid
        out[opt.label] = {
            "n_devices": opt.terms.n_devices,
            "step_time_s": se.step_time_s,
            "tokens_per_s": opt.tokens_per_step / max(se.step_time_s, 1e-12),
            "energy_j_per_step": se.energy_j,
            "j_per_token": se.energy_j / max(opt.tokens_per_step, 1e-12),
            "op_gco2_per_mtoken": grid.joules_to_gco2(
                se.energy_j / max(opt.tokens_per_step, 1e-12), grid_mix) * 1e6,
            "embodied_gco2": grid.joules_to_gco2(embodied_j, grid_mix),
            "embodied_share_of_lifecycle": embodied_j / (embodied_j + op_j_life),
            "lifecycle_gco2_per_mtoken": grid.joules_to_gco2(
                (embodied_j + op_j_life) / max(tokens_life, 1e-12), grid_mix) * 1e6,
        }
    return out
