"""Operational energy & efficiency models (paper Table 3 + fleet extension).

Two layers:

1. **Paper-faithful**: efficiency columns of Table 3 — FPS/W, MF/gCO2eq for
   ternary PIM inference and GFLOPS/W, TFLOPS/gCO2eq for FP32 training — are
   recomputed from the measured (throughput, power) points and the grid-mix
   range of Table 1.

2. **Beyond-paper (fleet)**: a dry-run roofline (core.roofline) converts to a
   per-step wall-time bound, which with the TPU power model gives energy/step,
   carbon/step per grid mix, and tokens/J — the quantities the accounting and
   advisor layers consume.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import grid, hw, roofline

J_PER_KWH = 3.6e6


# ---------------------------------------------------------------------------
# Paper Table 3 efficiency columns
# ---------------------------------------------------------------------------

def work_per_gco2(throughput: float, power_w: float, mix: str) -> float:
    """(work-units per gCO2eq) = throughput/power * 1kWh / mix_intensity.

    For ``throughput`` in FPS this returns frames/gCO2eq; the paper's tabled
    MF/gCO2eq divides by 1e6, TFLOPS/gCO2eq divides GFLOPS-work by 1e3.
    """
    work_per_j = throughput / power_w
    work_per_kwh = work_per_j * J_PER_KWH
    return work_per_kwh / grid.mix_intensity(mix)


def table3_efficiency(benchmark: str, phase: str,
                      states: Tuple[str, ...] = ("AZ", "CA", "TX", "NY"),
                      ) -> Dict[str, Dict[str, float]]:
    """Recompute the efficiency columns of Table 3 for one benchmark/phase."""
    out: Dict[str, Dict[str, float]] = {}
    for device, point in hw.workload_points(benchmark, phase).items():
        per_g = {s: work_per_gco2(point.throughput, point.power_w, s) for s in states}
        row = {
            "throughput": point.throughput,
            "unit": point.throughput_unit,
            "power_w": point.power_w,
            "per_w": point.efficiency_per_w,
        }
        if point.throughput_unit == "FPS":
            # Mega-frames per gCO2eq (paper's MF/gCO2eq column)
            row["carbon_eff_min"] = min(per_g.values()) / 1e6
            row["carbon_eff_max"] = max(per_g.values()) / 1e6
            row["carbon_eff_unit"] = "MF/gCO2eq"
        else:
            # GFLOPS-seconds of work per gCO2eq -> TFLOPS/gCO2eq
            row["carbon_eff_min"] = min(per_g.values()) / 1e3
            row["carbon_eff_max"] = max(per_g.values()) / 1e3
            row["carbon_eff_unit"] = "TFLOPS/gCO2eq"
        out[device] = row
    return out


# Paper's published efficiency ranges (test oracles).  The RM inference row is
# internally inconsistent in the paper (~6.5% high vs. its own FPS/W); see
# DESIGN.md §10.
PAPER_TABLE3_EFF = {
    ("alexnet", "inference_ternary", "ddr3_pim"): (0.35, 0.81),
    ("alexnet", "inference_ternary", "rm_pim"): (4.6, 10.8),    # paper-inconsistent
    ("alexnet", "train_fp32", "gpu"): (521.0, 1214.0),
    ("alexnet", "train_fp32", "rm_pim"): (74.0, 172.0),
    ("alexnet", "train_fp32", "fpga"): (37.0, 85.0),
    ("vgg16", "train_fp32", "gpu"): (342.0, 797.0),
    ("vgg16", "train_fp32", "rm_pim"): (118.0, 275.0),
    ("vgg16", "train_fp32", "fpga"): (50.0, 117.0),
}


# ---------------------------------------------------------------------------
# Fleet (TPU) operational energy from roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StepEnergy:
    """Energy/carbon accounting for one compiled step on a fleet."""
    step_time_s: float
    n_devices: int
    energy_j: float
    energy_j_no_overlap: float

    def carbon_g(self, mix: str) -> float:
        return grid.joules_to_gco2(self.energy_j, mix)


def step_energy(terms: roofline.RooflineTerms,
                power: Optional[hw.PowerStates] = None) -> StepEnergy:
    """Energy per step: bound wall-time x fleet active power.

    Uses the perfect-overlap time bound for the headline number and the
    no-overlap bound as the pessimistic bracket.
    """
    p = power or hw.TPU_V5E.power
    t, t_hi = terms.step_time_s, terms.step_time_no_overlap_s
    return StepEnergy(
        step_time_s=t,
        n_devices=terms.n_devices,
        energy_j=t * terms.n_devices * p.active_w,
        energy_j_no_overlap=t_hi * terms.n_devices * p.active_w,
    )


def tokens_per_joule(terms: roofline.RooflineTerms, n_tokens: float,
                     power: Optional[hw.PowerStates] = None) -> float:
    se = step_energy(terms, power)
    return n_tokens / se.energy_j if se.energy_j > 0 else float("inf")


def carbon_per_1k_steps(terms: roofline.RooflineTerms, mix: str,
                        power: Optional[hw.PowerStates] = None) -> float:
    """gCO2eq per 1000 steps — the fleet analogue of Table 3's carbon column."""
    return 1000.0 * step_energy(terms, power).carbon_g(mix)


# ---------------------------------------------------------------------------
# Per-byte DRAM term (quantized serving path, DESIGN.md §12)
# ---------------------------------------------------------------------------
# The paper's core claim is that per-byte data movement — not FLOPs —
# dominates edge-inference energy (hence PIM). The serving path makes that
# measurable: every engine tick reports dtype-aware bytes moved (weights +
# KV cache) and modeled FLOPs, and the accountant bills
#
#     E_modeled = flops * (P_active / peak_flops)  +  bytes * e_dram
#
# so J/token visibly drops when the int8 path halves-to-quarters the bytes
# while leaving FLOPs unchanged. Access-energy constants are literature
# order-of-magnitude values (pJ/byte): HBM2E ~3.9 pJ/bit, LPDDR4 ~8 pJ/bit
# (the edge case), DDR4 ~15 pJ/bit.

DRAM_PJ_PER_BYTE = {"hbm2e": 31.0, "lpddr4": 64.0, "ddr4": 120.0}


def dram_energy_j(n_bytes: float, kind: str = "hbm2e") -> float:
    """Energy to move ``n_bytes`` through the memory interface."""
    return float(n_bytes) * DRAM_PJ_PER_BYTE[kind] * 1e-12


def compute_energy_j(flops: float,
                     spec: Optional[hw.DeviceSpec] = None) -> float:
    """Compute-side energy at peak-rate efficiency (active power / peak
    FLOPs — ~1 pJ/FLOP on TPU v5e). Devices without a published peak fall
    back to the TPU constants."""
    spec = spec if spec is not None and spec.peak_flops else hw.TPU_V5E
    return float(flops) * spec.power.active_w / spec.peak_flops


def modeled_serve_energy_j(flops: float, n_bytes: float,
                           spec: Optional[hw.DeviceSpec] = None,
                           dram: str = "hbm2e") -> float:
    """FLOPs + per-byte DRAM energy for one serving interval."""
    return compute_energy_j(flops, spec) + dram_energy_j(n_bytes, dram)


# ---------------------------------------------------------------------------
# Training-phase energy (on-line training fast path, DESIGN.md §13)
# ---------------------------------------------------------------------------
# The paper evaluates edge platforms for inference AND on-line training, and
# the related edge-energy literature (DeepEn2023, Sobhani et al.) insists on
# *per-phase* measurement: forward and backward bill separately, because the
# backward's 2x FLOPs + grad-write traffic is exactly what a serve-only
# energy model misses. TrainStepCost carries one optimizer step's modeled
# phases; models/costing.py derives it from a live param/opt-state tree.

@dataclasses.dataclass(frozen=True)
class TrainStepCost:
    """Modeled FLOPs/bytes of ONE training step, split by phase."""
    fwd_flops: float
    bwd_flops: float
    fwd_bytes: float
    bwd_bytes: float
    opt_bytes: float = 0.0
    tokens: float = 0.0
    samples: float = 0.0

    def scaled(self, n_steps: int) -> "TrainStepCost":
        f = float(n_steps)
        return TrainStepCost(
            fwd_flops=self.fwd_flops * f, bwd_flops=self.bwd_flops * f,
            fwd_bytes=self.fwd_bytes * f, bwd_bytes=self.bwd_bytes * f,
            opt_bytes=self.opt_bytes * f, tokens=self.tokens * f,
            samples=self.samples * f)


def train_phase_energy_j(cost: TrainStepCost,
                         spec: Optional[hw.DeviceSpec] = None,
                         dram: str = "hbm2e") -> Dict[str, float]:
    """Per-phase modeled energy of one training step (J): the FLOPs term at
    peak-rate efficiency plus the per-byte DRAM term, forward and backward
    separately; the optimizer phase is pure traffic (negligible FLOPs)."""
    fwd = compute_energy_j(cost.fwd_flops, spec) + dram_energy_j(
        cost.fwd_bytes, dram)
    bwd = compute_energy_j(cost.bwd_flops, spec) + dram_energy_j(
        cost.bwd_bytes, dram)
    opt = dram_energy_j(cost.opt_bytes, dram)
    return {"fwd_j": fwd, "bwd_j": bwd, "opt_j": opt,
            "total_j": fwd + bwd + opt}
