"""Analytic FLOPs / HBM-traffic accounting via jaxpr traversal.

Why this exists: XLA's ``compiled.cost_analysis()`` on this backend counts a
``while`` body's FLOPs **once**, so scan-over-layers models (every arch here)
under-report by ~n_layers (verified empirically: flops identical at
repeats=12 vs 24 — EXPERIMENTS.md §Dry-run notes). This walker computes exact
semantic FLOPs from the jaxpr, multiplying scan bodies by their trip counts —
including the remat recompute (so the MODEL_FLOPS/HLO ratio still exposes
rematerialization waste).

Traffic model (memory term numerator): a fusion-aware *materialization*
estimate — bytes are billed at ops that force HBM round-trips (dots, convs,
gathers/scatters/dynamic slices, reduces, sorts, scan carries), while pure
elementwise/broadcast/convert ops are assumed fused into their consumers.
This is a lower-bound-flavored model; the XLA "bytes accessed" (body counted
once) and this estimate bracket the truth and are both recorded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore

# primitives billed as HBM materialization points (read ins + write outs)
_MATERIALIZING = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "scatter_add", "dynamic_slice", "dynamic_update_slice", "sort", "top_k",
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax",
    "cumprod", "all_to_all", "all_gather", "psum", "ppermute", "reduce_window",
    "select_and_scatter_add",
}

_CALL_PRIMS = {"pjit", "closed_call", "remat2", "checkpoint", "custom_jvp_call",
               "custom_vjp_call", "custom_vjp_call_jaxpr", "core_call",
               "xla_call", "sharding_constraint", "custom_partitioning"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    by_prim: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, prim: str, flops: float, traffic: float, mult: float) -> None:
        self.flops += flops * mult
        self.traffic_bytes += traffic * mult
        if flops:
            self.by_prim[prim] = self.by_prim.get(prim, 0.0) + flops * mult

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.traffic_bytes * k,
                    {p: v * k for p, v in self.by_prim.items()})


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * np.dtype(aval.dtype).itemsize
    except Exception:
        return 0.0


def _nelems(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lhs_c, _rhs_c), (lhs_b, _rhs_b) = dnums
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = 1.0
    for d in lhs_c:
        k *= lhs.shape[d]
    return 2.0 * _nelems(out) * k


def _conv_flops(eqn) -> float:
    rhs = eqn.invars[1].aval          # kernel
    out = eqn.outvars[0].aval
    dn = eqn.params["dimension_numbers"]
    # kernel: spatial dims + in-feature dim contribute per output element
    k_elems = _nelems(rhs) / rhs.shape[dn.rhs_spec[0]]   # / out-features
    batch_groups = eqn.params.get("feature_group_count", 1)
    return 2.0 * _nelems(out) * k_elems / max(batch_groups, 1) * 1.0


def _eqn_io_bytes(eqn) -> float:
    return (sum(_nbytes(v.aval) for v in eqn.invars if hasattr(v, "aval"))
            + sum(_nbytes(v.aval) for v in eqn.outvars))


_SHAPE_PRESERVING = {"convert_element_type", "mul", "broadcast_in_dim",
                     "reshape", "transpose", "add", "copy",
                     "sharding_constraint", "optimization_barrier"}


def _narrow_source_bytes(var, env, depth: int = 4):
    """BFS shape-preserving producers: if a dot operand is a dequantized
    int8/fp8 weight, the HBM read is the NARROW dtype (the convert/scale
    fuses into the matmul's operand load). Returns itemsize or None."""
    target_bytes = np.dtype(var.aval.dtype).itemsize
    frontier = [var]
    for _ in range(depth):
        nxt = []
        for v in frontier:
            eqn = env.get(id(v))
            if eqn is None or eqn.primitive.name not in _SHAPE_PRESERVING:
                continue
            for iv in eqn.invars:
                aval = getattr(iv, "aval", None)
                if aval is None or getattr(aval, "shape", None) != var.aval.shape:
                    continue
                if np.dtype(aval.dtype).itemsize < target_bytes:
                    return np.dtype(aval.dtype).itemsize
                nxt.append(iv)
        if not nxt:
            return None
        frontier = nxt
    return None


def jaxpr_cost(jaxpr, mult: float = 1.0, cost: Cost = None) -> Cost:
    cost = cost if cost is not None else Cost()
    env = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            env[id(ov)] = eqn
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            length = eqn.params["length"]
            inner = jaxpr_cost(eqn.params["jaxpr"].jaxpr, mult * length, cost)
            # carry traffic per iteration
            n_carry = eqn.params["num_carry"]
            carry_bytes = sum(_nbytes(v.aval) for v in eqn.outvars[:n_carry])
            cost.add("scan_carry", 0.0, 2.0 * carry_bytes, mult * length)
            continue
        if name == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            jaxpr_cost(body, mult, cost)   # trip count unknown: counted once
            continue
        if name == "cond":
            branches = eqn.params["branches"]
            sub = [jaxpr_cost(b.jaxpr, 1.0, Cost()) for b in branches]
            worst = max(sub, key=lambda c: c.flops) if sub else Cost()
            cost.flops += worst.flops * mult
            cost.traffic_bytes += worst.traffic_bytes * mult
            continue
        inner = None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in eqn.params:
                inner = eqn.params[key]
                break
        if inner is not None:
            inner_jaxpr = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            jaxpr_cost(inner_jaxpr, mult, cost)
            continue
        if name == "dot_general":
            io = 0.0
            for v in eqn.invars:
                if not hasattr(v, "aval"):
                    continue
                narrow = _narrow_source_bytes(v, env)
                full = _nbytes(v.aval)
                io += (full / np.dtype(v.aval.dtype).itemsize * narrow
                       if narrow else full)
            io += sum(_nbytes(v.aval) for v in eqn.outvars)
            cost.add(name, _dot_flops(eqn), io, mult)
        elif name == "conv_general_dilated":
            cost.add(name, _conv_flops(eqn), _eqn_io_bytes(eqn), mult)
        elif name in _MATERIALIZING or name.startswith("reduce"):
            cost.add(name, _nelems(eqn.invars[0].aval) if eqn.invars else 0.0,
                     _eqn_io_bytes(eqn), mult)
    return cost


def cost_of_fn(fn, *args_sds, n_devices: int = 1) -> Dict[str, float]:
    """Trace ``fn`` with ShapeDtypeStructs and return global + per-device
    analytic cost."""
    jaxpr = jax.make_jaxpr(fn)(*args_sds)
    c = jaxpr_cost(jaxpr.jaxpr)
    return {
        "flops_global": c.flops,
        "traffic_bytes_global": c.traffic_bytes,
        "flops_per_device": c.flops / n_devices,
        "traffic_per_device": c.traffic_bytes / n_devices,
        "by_prim": dict(sorted(c.by_prim.items(), key=lambda kv: -kv[1])[:8]),
    }
