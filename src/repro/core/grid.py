"""Electrical grid-mix carbon-intensity model (paper Table 1).

Carbon intensity of generation sources (gCO2eq/kWh, NREL [17]) combined with
state grid mixes [18] for the four states with significant semiconductor
fabrication activity. ``mix_intensity`` reproduces the paper's Mix row
(AZ 395 / CA 234 / TX 438 / NY 188) exactly from first principles — this is a
hard validation target in tests/test_lca.py.
"""

from __future__ import annotations

from typing import Dict, Iterable, Mapping

# gCO2eq per kWh by generation source (Table 1, left column; NREL [17]).
SOURCE_INTENSITY_G_PER_KWH: Dict[str, float] = {
    "coal": 980.0,
    "natural_gas": 465.0,
    "geothermal": 27.0,
    "hydroelectric": 24.0,
    "solar_pv": 65.0,
    "wind": 11.0,
    "nuclear": 27.0,
    "biopower": 54.0,
}

# State grid mixes (Table 1; fractions of generation). Rows absent from the
# paper's table are 0.
GRID_MIXES: Dict[str, Dict[str, float]] = {
    "AZ": {"coal": 0.20, "natural_gas": 0.40, "hydroelectric": 0.05,
           "solar_pv": 0.07, "nuclear": 0.28},
    "CA": {"coal": 0.03, "natural_gas": 0.39, "geothermal": 0.05,
           "hydroelectric": 0.18, "solar_pv": 0.20, "wind": 0.07,
           "nuclear": 0.07, "biopower": 0.03},
    "TX": {"coal": 0.19, "natural_gas": 0.53, "solar_pv": 0.02,
           "wind": 0.17, "nuclear": 0.09},
    "NY": {"natural_gas": 0.37, "hydroelectric": 0.22, "solar_pv": 0.02,
           "wind": 0.04, "nuclear": 0.33},
}

# The paper's published Mix row, used only as a test oracle.
PAPER_MIX_ROW = {"AZ": 395.0, "CA": 234.0, "TX": 438.0, "NY": 188.0}


def mix_intensity(mix: Mapping[str, float] | str) -> float:
    """gCO2eq/kWh of a grid mix (state name or explicit source->fraction map)."""
    if isinstance(mix, str):
        try:
            mix = GRID_MIXES[mix]
        except KeyError as e:
            raise KeyError(f"unknown grid mix {mix!r}; have {sorted(GRID_MIXES)}") from e
    total_frac = sum(mix.values())
    # The paper's own columns sum to 98-102% (rounded percentages); accept that.
    if not 0.0 < total_frac <= 1.05:
        raise ValueError(f"grid mix fractions sum to {total_frac}, expected (0, 1.05]")
    return sum(SOURCE_INTENSITY_G_PER_KWH[src] * frac for src, frac in mix.items())


def all_mix_intensities(states: Iterable[str] = ("AZ", "CA", "TX", "NY")) -> Dict[str, float]:
    return {s: mix_intensity(s) for s in states}


def intensity_range(states: Iterable[str] = ("AZ", "CA", "TX", "NY")) -> tuple[float, float]:
    """(min, max) gCO2eq/kWh over the given states — the paper's range columns."""
    vals = [mix_intensity(s) for s in states]
    return min(vals), max(vals)


def kwh_to_gco2(kwh: float, mix: Mapping[str, float] | str) -> float:
    return kwh * mix_intensity(mix)


def joules_to_gco2(joules: float, mix: Mapping[str, float] | str) -> float:
    return kwh_to_gco2(joules / 3.6e6, mix)
