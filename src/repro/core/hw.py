"""Hardware platform database.

Encodes every platform the paper characterizes (Table 2 / Table 3):

* ``rm_pim``    — PIM-enabled Racetrack (domain-wall) memory, PIRM [13] / FPIRM [19]
* ``ddr3_pim``  — DDR3-1600 PIM (ELP^2IM [20]), 16 dies per tested 1 GB DIMM
* ``gpu``       — NVIDIA Jetson Xavier NX mobile GPU
* ``fpga``      — AMD/Xilinx Versal Prime VM1802

plus the beyond-paper TPU v5e target used for the multi-pod roofline and the
fleet-level sustainability analysis.

Power-state values for the paper platforms: *active* powers are the paper's
measured Table-3 workload powers; *idle*/*sleep* powers are not published in
the paper (it relies on GreenChip defaults) and are calibrated here so that
every Figure-2 claim reproduces (see DESIGN.md §10 and
tests/test_sustain.py::test_paper_claims_*).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class PowerStates:
    """Power draw (watts) in the three GreenChip duty states."""

    active_w: float
    idle_w: float
    sleep_w: float

    def validate(self) -> None:
        if not (self.active_w >= self.idle_w >= self.sleep_w >= 0.0):
            raise ValueError(f"power states must be ordered: {self}")


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """A platform whose embodied + operational sustainability we evaluate."""

    name: str
    die_area_mm2: float
    tech_node_nm: float
    lca_study: str                      # key into lca.STUDIES
    power: PowerStates
    # Compute/memory roofline constants (None where not meaningful, e.g. DIMMs)
    peak_flops: Optional[float] = None  # FLOP/s at the native compute dtype
    hbm_bw: Optional[float] = None      # bytes/s
    link_bw: Optional[float] = None     # bytes/s per ICI/interconnect link
    mem_bytes: Optional[float] = None
    dies_per_module: int = 1            # e.g. 16 DRAM dies / 1 GB DIMM (Table 2 fn.5)
    # Paper-published dies/wafer (Table 2); geometric model used when absent.
    dies_per_wafer_published: Optional[int] = None
    notes: str = ""

    def __post_init__(self):
        self.power.validate()


# ----------------------------------------------------------------------------
# Paper platforms (Table 2 rows; active powers from Table 3)
# ----------------------------------------------------------------------------

# The paper evaluates the RM die under three LCA studies (Boyd'11, Higgs'09,
# imec PPACE'20). ``rm_pim`` pins the headline Boyd'11 estimate; the
# per-study variants are produced by core.lca (see embodied_energy_mj).
RM_PIM = DeviceSpec(
    name="rm_pim",
    die_area_mm2=38.0,
    tech_node_nm=32.0,
    lca_study="boyd2011",
    power=PowerStates(active_w=0.93, idle_w=0.025, sleep_w=0.002),
    dies_per_module=16,   # like-for-like 1 GB PIM DIMM replacement (vs DDR3)
    dies_per_wafer_published=1847,
    notes="PIRM/FPIRM PIM-enabled domain-wall memory; +3 spintronic masks [14]",
)

DDR3_PIM = DeviceSpec(
    name="ddr3_pim",
    die_area_mm2=73.0,
    tech_node_nm=55.0,
    lca_study="boyd2011_dram",
    power=PowerStates(active_w=2.0, idle_w=0.5, sleep_w=0.1),
    dies_per_module=16,   # Table 2 footnote 5: 16 dies per tested 1 GB DIMM
    dies_per_wafer_published=967,
    notes="DDR3-1600 PIM per ELP^2IM [20]",
)

JETSON_NX = DeviceSpec(
    name="gpu",
    die_area_mm2=350.0,
    tech_node_nm=14.0,
    lca_study="bardon2020",
    power=PowerStates(active_w=21.05, idle_w=2.0, sleep_w=0.3),
    peak_flops=21e12,     # fp16 dense (Xavier NX marketing 21 TOPS class)
    dies_per_wafer_published=201,
    notes="NVIDIA Jetson Xavier NX mobile GPU",
)

VERSAL_VM1802 = DeviceSpec(
    name="fpga",
    die_area_mm2=324.0,
    tech_node_nm=7.0,
    lca_study="bardon2020",
    power=PowerStates(active_w=7.74, idle_w=2.5, sleep_w=0.5),
    dies_per_wafer_published=217,
    notes="AMD/Xilinx Versal Prime VM1802",
)

# ----------------------------------------------------------------------------
# Beyond-paper target: TPU v5e (the platform of the multi-pod dry-run).
# Die area / node / power are public-information estimates, flagged as such.
# ----------------------------------------------------------------------------

TPU_V5E = DeviceSpec(
    name="tpu_v5e",
    die_area_mm2=325.0,                 # estimate (v4 ~ <400 mm^2; v5e smaller)
    tech_node_nm=5.0,
    lca_study="bardon2020",
    power=PowerStates(active_w=200.0, idle_w=60.0, sleep_w=10.0),
    peak_flops=197e12,                  # bf16, per chip (assignment constant)
    hbm_bw=819e9,                       # bytes/s HBM (assignment constant)
    link_bw=50e9,                       # bytes/s per ICI link (assignment constant)
    mem_bytes=16 * 1024**3,             # 16 GB HBM
    dies_per_module=1,
    notes="beyond-paper fleet target; embodied estimate = logic die via PPACE "
          "curve + 8 HBM DRAM-die equivalents (cross-study caveat applies)",
)

DEVICES: Dict[str, DeviceSpec] = {
    d.name: d for d in (RM_PIM, DDR3_PIM, JETSON_NX, VERSAL_VM1802, TPU_V5E)
}


# ----------------------------------------------------------------------------
# Table 3 measured operational characterization.
#
# ``throughput`` units: FPS for inference rows, GFLOPS for training rows —
# recorded verbatim from the paper; ``power_w`` is the measured workload power.
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkloadPoint:
    benchmark: str        # "alexnet" | "vgg16"
    phase: str            # "inference_ternary" | "train_fp32"
    device: str           # key into DEVICES
    throughput: float
    throughput_unit: str  # "FPS" | "GFLOPS"
    power_w: float

    @property
    def efficiency_per_w(self) -> float:
        return self.throughput / self.power_w


TABLE3: Dict[str, WorkloadPoint] = {
    p.benchmark + "/" + p.phase + "/" + p.device: p
    for p in [
        # -- inference, ternary model reduction + PIM (Table 3, top) --
        WorkloadPoint("alexnet", "inference_ternary", "ddr3_pim", 84.8, "FPS", 2.0),
        WorkloadPoint("alexnet", "inference_ternary", "rm_pim", 490.0, "FPS", 0.93),
        # -- training, FP32 (Table 3, bottom) --
        WorkloadPoint("alexnet", "train_fp32", "gpu", 1335.0, "GFLOPS", 21.05),
        WorkloadPoint("alexnet", "train_fp32", "rm_pim", 50.72, "GFLOPS", 5.65),
        WorkloadPoint("alexnet", "train_fp32", "fpga", 34.52, "GFLOPS", 7.74),
        WorkloadPoint("vgg16", "train_fp32", "gpu", 848.0, "GFLOPS", 20.37),
        WorkloadPoint("vgg16", "train_fp32", "rm_pim", 81.95, "GFLOPS", 5.7),
        WorkloadPoint("vgg16", "train_fp32", "fpga", 46.99, "GFLOPS", 7.71),
    ]
}


def workload_points(benchmark: str, phase: str) -> Dict[str, WorkloadPoint]:
    """All Table-3 points for one (benchmark, phase), keyed by device name."""
    out = {}
    for p in TABLE3.values():
        if p.benchmark == benchmark and p.phase == phase:
            out[p.device] = p
    return out


# TPU v5e roofline constants re-exported for the roofline module.
TPU_PEAK_FLOPS = TPU_V5E.peak_flops
TPU_HBM_BW = TPU_V5E.hbm_bw
TPU_LINK_BW = TPU_V5E.link_bw
