"""Process-LCA embodied energy & carbon model (paper Table 2).

Three process life-cycle-assessment studies are encoded, exactly as the paper
uses them (and never mixed across nodes — the paper's own caveat):

* ``boyd2011``       Boyd, *Life-cycle assessment of semiconductors* [6]:
                     CMOS logic, 350 nm -> 32 nm.
* ``boyd2011_dram``  Boyd [6] DRAM line (DDR3 row of Table 2).
* ``higgs2009``      Higgs et al. [16]: a 32 nm point sitting between the two.
* ``bardon2020``     Garcia Bardon et al. (imec) PPACE [7]: 28 nm -> 3 nm,
                     DUV->EUV transition; the paper extrapolates one step to
                     32 nm for the RM comparison point.

Spintronic memories (RM, like STT-MRAM) add three mask layers on top of the
CMOS stack — three lithography, three dry-etch and one deposition step [14].
That adder is ``SPINTRONIC_EXTRA_KWH_PER_WAFER``, calibrated to the process
cost model of Bayram et al. [14] (~50 kWh/wafer per mask layer).

Validation (tests/test_lca.py): the PE (kWh/wafer), MJ/die and every
gCO2eq/die cell of paper Table 2 reproduce to <0.5 %.

Anchor values in each study table marked ``# anchor`` are the cells the paper
itself uses; other nodes are documented interpolations for design-space
exploration beyond the paper.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional

from repro.core import grid, hw

# Extra per-wafer fab energy for the 3 spintronic mask layers [14].
SPINTRONIC_EXTRA_KWH_PER_WAFER = 150.0

WAFER_DIAMETER_MM = 300.0
WAFER_EDGE_EXCLUSION_MM = 0.0  # paper counts match gross-area dies (see below)


@dataclasses.dataclass(frozen=True)
class LcaStudy:
    name: str
    # node (nm) -> per-wafer manufacturing energy (kWh / 300 mm wafer)
    kwh_per_wafer: Mapping[float, float]
    # nodes the study actually covers; outside this range is an extrapolation
    covered: tuple[float, float]   # (min_nm, max_nm)

    def energy_kwh(self, node_nm: float) -> float:
        table = dict(self.kwh_per_wafer)
        if node_nm in table:
            return table[node_nm]
        nodes = sorted(table)
        if node_nm < nodes[0] or node_nm > nodes[-1]:
            raise ValueError(
                f"node {node_nm} nm outside study {self.name} table "
                f"[{nodes[0]}, {nodes[-1]}]; studies must not be mixed")
        # log-node linear interpolation between bracketing table entries
        lo = max(n for n in nodes if n < node_nm)
        hi = min(n for n in nodes if n > node_nm)
        t = (math.log(node_nm) - math.log(lo)) / (math.log(hi) - math.log(lo))
        return table[lo] * (1 - t) + table[hi] * t

    def is_extrapolated(self, node_nm: float) -> bool:
        lo, hi = self.covered
        return not (lo <= node_nm <= hi)


STUDIES: Dict[str, LcaStudy] = {
    # Boyd 2011 [6] — CMOS logic 350->32 nm. 32 nm anchor back-solved from the
    # paper's RM PE 1626 kWh/wafer minus the spintronic adder.
    "boyd2011": LcaStudy(
        name="boyd2011",
        kwh_per_wafer={
            350.0: 610.0, 250.0: 700.0, 180.0: 790.0, 130.0: 900.0,
            90.0: 1020.0, 65.0: 1140.0, 45.0: 1290.0,
            32.0: 1476.0,   # anchor: 1626 - 150 spintronic
        },
        covered=(32.0, 350.0),
    ),
    # Boyd 2011 [6] — DRAM line. 55 nm anchor is the paper's DDR3 PE.
    "boyd2011_dram": LcaStudy(
        name="boyd2011_dram",
        kwh_per_wafer={
            90.0: 960.0, 70.0: 1090.0,
            55.0: 1200.0,   # anchor: DDR3-1600 die (Table 2)
            45.0: 1300.0,
        },
        covered=(45.0, 90.0),
    ),
    # Higgs 2009 [16] — single 32 nm point between the other two studies.
    "higgs2009": LcaStudy(
        name="higgs2009",
        kwh_per_wafer={
            32.0: 1104.0,   # anchor: 1254 - 150 spintronic
        },
        covered=(32.0, 32.0),
    ),
    # imec PPACE 2020 [7] — 28->3 nm (+ the paper's one-step 32 nm
    # extrapolation). 14 nm and 7 nm anchors are the paper's GPU/FPGA PEs.
    "bardon2020": LcaStudy(
        name="bardon2020",
        kwh_per_wafer={
            32.0: 682.0,    # anchor (extrapolated by the paper): 832 - 150
            28.0: 744.0, 20.0: 800.0, 16.0: 855.0,
            14.0: 882.0,    # anchor: Jetson NX (Table 2)
            10.0: 1120.0,
            7.0: 1482.0,    # anchor: Versal VM1802 (Table 2)
            5.0: 1840.0, 3.0: 2450.0,
        },
        covered=(3.0, 28.0),
    ),
}


# ----------------------------------------------------------------------------
# Dies per wafer
# ----------------------------------------------------------------------------

def dies_per_wafer_geometric(die_area_mm2: float,
                             wafer_diameter_mm: float = WAFER_DIAMETER_MM,
                             edge_exclusion_mm: float = WAFER_EDGE_EXCLUSION_MM,
                             yield_fraction: float = 0.993) -> int:
    """Gross-area die count with a small edge/yield derating.

    The paper's published counts (1847 @ 38 mm^2, 967 @ 73 mm^2, 217 @ 324,
    201 @ 350) sit within ~0.7 % of pi*R^2/A; we model that residual as a
    fixed derating. Published values take precedence when available.
    """
    r = wafer_diameter_mm / 2.0 - edge_exclusion_mm
    gross = math.pi * r * r / die_area_mm2
    return int(gross * yield_fraction)


def dies_per_wafer(spec: hw.DeviceSpec) -> int:
    if spec.dies_per_wafer_published is not None:
        return spec.dies_per_wafer_published
    return dies_per_wafer_geometric(spec.die_area_mm2)


# ----------------------------------------------------------------------------
# Embodied energy / carbon
# ----------------------------------------------------------------------------

def wafer_energy_kwh(spec: hw.DeviceSpec, *, study: Optional[str] = None,
                     spintronic: Optional[bool] = None) -> float:
    """Per-wafer fab energy (the PE row of Table 2)."""
    study_obj = STUDIES[study or spec.lca_study]
    if spintronic is None:
        spintronic = spec.name.startswith("rm")
    e = study_obj.energy_kwh(spec.tech_node_nm)
    if spintronic:
        e += SPINTRONIC_EXTRA_KWH_PER_WAFER
    return e


def embodied_energy_mj(spec: hw.DeviceSpec, *, study: Optional[str] = None,
                       per_module: bool = False,
                       spintronic: Optional[bool] = None) -> float:
    """Embodied manufacturing energy per die (or per module) in MJ."""
    kwh = wafer_energy_kwh(spec, study=study, spintronic=spintronic)
    per_die = kwh * 3.6 / dies_per_wafer(spec)
    return per_die * (spec.dies_per_module if per_module else 1)


def embodied_carbon_g(spec: hw.DeviceSpec, mix: str, *,
                      study: Optional[str] = None,
                      per_module: bool = False,
                      spintronic: Optional[bool] = None) -> float:
    """Embodied carbon per die (or module) for a fab grid mix, gCO2eq."""
    kwh = wafer_energy_kwh(spec, study=study, spintronic=spintronic)
    per_die_kwh = kwh / dies_per_wafer(spec)
    g = grid.kwh_to_gco2(per_die_kwh, mix)
    return g * (spec.dies_per_module if per_module else 1)


# ----------------------------------------------------------------------------
# Paper Table 2 reproduction
# ----------------------------------------------------------------------------

# (label, device, study) for each Table-2 column, in paper order.
TABLE2_COLUMNS = [
    ("RM/boyd2011", "rm_pim", "boyd2011"),
    ("DDR3/boyd2011", "ddr3_pim", "boyd2011_dram"),
    ("RM/higgs2009", "rm_pim", "higgs2009"),
    ("RM/bardon2020", "rm_pim", "bardon2020"),
    ("FPGA/bardon2020", "fpga", "bardon2020"),
    ("GPU/bardon2020", "gpu", "bardon2020"),
]

# The paper's published Table-2 numbers, used only as test oracles.
PAPER_TABLE2 = {
    "RM/boyd2011":    dict(pe_kwh=1626.0, mj_die=3.17, az=348, ca=206, tx=386, ny=166),
    "DDR3/boyd2011":  dict(pe_kwh=1200.0, mj_die=4.47, az=490, ca=291, tx=544, ny=233),
    "RM/higgs2009":   dict(pe_kwh=1254.0, mj_die=2.44, az=268, ca=159, tx=297, ny=127),
    "RM/bardon2020":  dict(pe_kwh=832.0,  mj_die=1.62, az=178, ca=105, tx=197, ny=85),
    "FPGA/bardon2020": dict(pe_kwh=1482.0, mj_die=24.59, az=2698, ca=1598, tx=2992, ny=1284),
    "GPU/bardon2020": dict(pe_kwh=882.0,  mj_die=15.80, az=1734, ca=1027, tx=1922, ny=825),
}


def table2() -> Dict[str, Dict[str, float]]:
    """Recompute paper Table 2 from first principles."""
    out: Dict[str, Dict[str, float]] = {}
    for label, dev_name, study in TABLE2_COLUMNS:
        spec = hw.DEVICES[dev_name]
        row = {
            "tech_node_nm": spec.tech_node_nm,
            "die_mm2": spec.die_area_mm2,
            "die_per_wafer": dies_per_wafer(spec),
            "pe_kwh": wafer_energy_kwh(spec, study=study),
            "mj_die": embodied_energy_mj(spec, study=study),
        }
        for state in ("AZ", "CA", "TX", "NY"):
            row[state.lower()] = embodied_carbon_g(spec, state, study=study)
        out[label] = row
    return out


# ----------------------------------------------------------------------------
# Beyond-paper: TPU v5e package embodied estimate
# ----------------------------------------------------------------------------

HBM_DIE_EQUIVALENTS = 8            # 16 GB HBM modeled as 8 DRAM-die equivalents
PACKAGING_OVERHEAD = 1.10          # interposer/substrate/assembly adder


def tpu_package_embodied_mj() -> float:
    """Embodied energy estimate for one TPU v5e package (logic + HBM).

    Logic die via the imec PPACE curve at its 5 nm-class node; HBM approximated
    with Boyd's DRAM line (cross-study, flagged in DESIGN.md §10 — estimates
    only, never compared against paper numbers).
    """
    tpu = hw.TPU_V5E
    logic = embodied_energy_mj(tpu, spintronic=False)
    dram_spec = hw.DDR3_PIM
    hbm = HBM_DIE_EQUIVALENTS * embodied_energy_mj(dram_spec, study="boyd2011_dram",
                                                   spintronic=False)
    return (logic + hbm) * PACKAGING_OVERHEAD


def tpu_package_embodied_gco2(mix: str) -> float:
    mj = tpu_package_embodied_mj()
    return grid.joules_to_gco2(mj * 1e6, mix)
