"""Three-term roofline analysis from compiled XLA artifacts.

Per the assignment:

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` in JAX 0.8 reports **per-device** FLOPs/bytes for
SPMD executables (verified empirically in tests/test_roofline.py), so the
per-chip division is already done for those two terms; collective bytes are
parsed from the optimized HLO text, which is likewise the per-device program.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (from core.hw.TPU_V5E).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Iterable, Optional

from repro.core import hw

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# collective opcodes we bill against the ICI links.  ``-start`` async forms
# are counted; ``-done`` forms are skipped (same transfer, second mention).
_COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")
_OP_LINE_RE = re.compile(
    r"=\s*(?P<out>.+?)\s+(?P<op>" + "|".join(_COLLECTIVE_OPS) + r")(?P<start>-start)?\("
)


def _shape_bytes(text: str) -> int:
    """Sum the byte sizes of every typed shape literal in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue  # e.g. token[], opaque[]
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, int]
    # top individual instructions: (op, shape_str, per_hit_bytes, mult)
    top: list = dataclasses.field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """computation name -> body text (optimized HLO dialect).

    Headers are non-indented lines ending in '{' containing '->' (param lists
    may contain nested parens — name comes from the leading token only).
    Unattributed lines land in the ``_orphan`` bucket (multiplier 1).
    """
    comps: Dict[str, list] = {"_orphan": []}
    cur = "_orphan"
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not line.startswith(" "):
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps.setdefault(cur, [])
                    continue
            if stripped == "}":
                cur = "_orphan"
                continue
        comps.setdefault(cur, []).append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _trip_count(cond_text: str) -> int:
    """Heuristic: largest integer constant in the while condition."""
    vals = [int(v) for v in _CONST_RE.findall(cond_text)]
    return max(vals) if vals else 1


def _comp_multipliers(comps: Dict[str, str], entry: str) -> Dict[str, float]:
    """Execution-count multiplier per computation (while bodies x trip count)."""
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry not in comps:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    mult["_orphan"] = 1.0
    # propagate in dependency order via simple fixpoint (call graph is a DAG)
    for _ in range(len(comps)):
        changed = False
        for name, text in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for wm in _WHILE_RE.finditer(text):
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, ""))
                for target, k in ((body, m * trips), (cond, m * (trips + 1))):
                    if target in mult and mult[target] < k:
                        mult[target] = k
                        changed = True
            for cm in _CALLS_RE.finditer(text):
                target = cm.group(1)
                if target in mult and mult[target] < m:
                    mult[target] = m
                    changed = True
        if not changed:
            break
    return {k: max(v, 0.0) for k, v in mult.items()}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective bytes from optimized HLO text.

    While-loop aware: a collective inside a scanned-layer body is multiplied
    by the loop trip count (XLA prints the body computation once — without
    this, per-layer collectives under-count by ~n_layers).

    Cost model per op (ring-algorithm constants folded into an upper-bound
    "operand size" accounting per the assignment):
      * all-reduce:       2 x size   (reduce-scatter + all-gather phases)
      * everything else:  1 x size
    where size = max(output bytes, operand bytes) on the instruction.
    """
    comps = _split_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
            break
    mults = (_comp_multipliers(comps, entry) if entry
             else {k: 1.0 for k in comps})

    bytes_by_op: Dict[str, float] = {}
    count_by_op: Dict[str, int] = {}
    top: list = []
    for comp_name, text in comps.items():
        mult = mults.get(comp_name, 1.0)
        if mult <= 0:
            mult = 1.0
        for line in text.splitlines():
            m = _OP_LINE_RE.search(line)
            if m is None:
                continue
            op = m.group("op")
            out_str = m.group("out")
            out_bytes = _shape_bytes(out_str)
            rest = line[m.end():]
            operand_str = rest.split("replica_groups")[0].split("channel_id")[0]
            in_bytes = _shape_bytes(operand_str)
            size = max(out_bytes, in_bytes)
            if op == "all-reduce":
                size *= 2
            bytes_by_op[op] = bytes_by_op.get(op, 0.0) + size * mult
            count_by_op[op] = count_by_op.get(op, 0) + 1
            top.append((op, out_str.strip()[:60], size, mult))
    top.sort(key=lambda t: -t[2] * t[3])
    return CollectiveStats(bytes_by_op, count_by_op, top[:12])


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineTerms:
    """Per-device roofline terms for one compiled step."""

    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    peak_flops: float = hw.TPU_PEAK_FLOPS
    hbm_bw: float = hw.TPU_HBM_BW
    link_bw: float = hw.TPU_LINK_BW
    # bookkeeping
    label: str = ""
    collective_detail: Optional[Dict[str, float]] = None
    memory_per_device_bytes: Optional[float] = None   # from memory_analysis()

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / self.link_bw

    @property
    def terms(self) -> Dict[str, float]:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}

    @property
    def bound(self) -> str:
        return max(self.terms, key=self.terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time under perfect overlap (max of the terms)."""
        return max(self.terms.values())

    @property
    def step_time_no_overlap_s(self) -> float:
        """Upper-bound step time with zero overlap (sum of the terms)."""
        return sum(self.terms.values())

    def roofline_fraction(self, model_flops_total: float) -> float:
        """Useful-FLOPs MFU bound: model FLOPs vs. peak over the bound time."""
        per_dev = model_flops_total / self.n_devices
        denom = self.step_time_s * self.peak_flops
        return per_dev / denom if denom > 0 else 0.0

    def useful_flops_ratio(self, model_flops_total: float) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        hlo_total = self.flops_per_device * self.n_devices
        return model_flops_total / hlo_total if hlo_total > 0 else 0.0

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bound=self.bound,
                 step_time_s=self.step_time_s)
        return d


def from_compiled(compiled, n_devices: int, label: str = "",
                  hlo_text: Optional[str] = None) -> RooflineTerms:
    """Build RooflineTerms from a jax ``Compiled`` object."""
    from repro.parallel.compat import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text)
    ma = None
    try:
        mstats = compiled.memory_analysis()
        ma = (mstats.argument_size_in_bytes + mstats.output_size_in_bytes
              + mstats.temp_size_in_bytes)
    except Exception:
        pass
    return RooflineTerms(
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        collective_bytes_per_device=colls.total_bytes,
        n_devices=n_devices,
        label=label,
        collective_detail=dict(colls.bytes_by_op),
        memory_per_device_bytes=ma,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS helpers
# ---------------------------------------------------------------------------

def model_flops_train(n_params_active: float, n_tokens: float) -> float:
    """6·N·D for a training step (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params_active * n_tokens

def model_flops_infer(n_params_active: float, n_tokens: float) -> float:
    """2·N·D for a forward/decode step."""
    return 2.0 * n_params_active * n_tokens


def format_table(rows: Iterable[RooflineTerms], model_flops: Dict[str, float]) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    hdr = ("| cell | compute (s) | memory (s) | collective (s) | bound | "
           "MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        mf = model_flops.get(r.label, 0.0)
        lines.append(
            f"| {r.label} | {r.compute_s:.4g} | {r.memory_s:.4g} | "
            f"{r.collective_s:.4g} | {r.bound} | "
            f"{r.useful_flops_ratio(mf):.3f} | {r.roofline_fraction(mf):.3f} |")
    return "\n".join(lines)


def save_json(path: str, rows: Iterable[RooflineTerms]) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=2)
