"""Indifference / break-even sustainability analysis (paper Eq. 1 + Fig. 2).

Implements the GreenChip [8] holistic-energy machinery the paper uses:

* Eq. 1:  t_I = (M1 - M0) / (P0 - P1)   and   t_B = M1 / (P0 - P1)
* the activity-ratio x sleep-ratio duty-cycle average-power model,
* *iso-throughput* normalization: when two platforms have different
  throughput on the same workload, the faster platform duty-cycles down to
  deliver the same work per unit time (this is what makes the paper's
  "GPU needs >=40 % activity to beat RM" claim come out — see
  tests/test_sustain.py::test_paper_claims_indifference_alexnet).

All energies are Joules, powers Watts, times seconds unless suffixed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import hw

SECONDS_PER_DAY = 86400.0
SECONDS_PER_YEAR = 365.0 * SECONDS_PER_DAY


# ----------------------------------------------------------------------------
# Eq. 1
# ----------------------------------------------------------------------------

def indifference_time_s(m1_j: float, m0_j: float, p0_w: float, p1_w: float) -> float:
    """t_I of Eq. 1: time at which system 1's extra embodied energy is amortized.

    System 1 has higher embodied (M1 > M0) and lower operational (P1 < P0).
    Returns +inf when system 1 never catches up (P1 >= P0), and 0 when system 1
    dominates (lower embodied AND lower operational — indifference analysis
    not needed, per the paper).
    """
    dm = m1_j - m0_j
    dp = p0_w - p1_w
    if dp <= 0.0:
        return math.inf if dm > 0 else 0.0
    return max(dm / dp, 0.0)


def breakeven_time_s(m1_j: float, p0_w: float, p1_w: float) -> float:
    """t_B of Eq. 1: replacement case (deployed incumbent => M0 = 0)."""
    return indifference_time_s(m1_j, 0.0, p0_w, p1_w)


def total_energy_j(m_j: float, p_w: float, t_s: float) -> float:
    """Holistic energy = embodied + operational over service time."""
    return m_j + p_w * t_s


# ----------------------------------------------------------------------------
# GreenChip duty-cycle model
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Duty:
    """GreenChip usage scenario.

    activity: fraction of wall-clock the *workload demand* keeps the reference
        platform busy (the paper's x-axis "activity ratio" = compute:idle).
    sleep_ratio: fraction of the non-active time spent in sleep rather than
        idle (the paper's y-axis "sleep ratio").
    """
    activity: float
    sleep_ratio: float = 0.0

    def __post_init__(self):
        if not 0.0 <= self.activity <= 1.0:
            raise ValueError(f"activity {self.activity} not in [0,1]")
        if not 0.0 <= self.sleep_ratio <= 1.0:
            raise ValueError(f"sleep_ratio {self.sleep_ratio} not in [0,1]")


def average_power_w(power: hw.PowerStates, busy_fraction: float,
                    sleep_ratio: float) -> float:
    """Average power of a device busy ``busy_fraction`` of the time."""
    idle_frac = 1.0 - busy_fraction
    return (busy_fraction * power.active_w
            + idle_frac * (sleep_ratio * power.sleep_w
                           + (1.0 - sleep_ratio) * power.idle_w))


def iso_throughput_busy_fraction(duty_activity: float, ref_throughput: float,
                                 dev_throughput: float) -> float:
    """Busy fraction of a device delivering the demand ``activity * ref_thr``.

    The reference platform defines the demand scale (activity=1 means demand
    equals the reference platform's full throughput). A faster device is busy
    a smaller fraction; a slower device saturates at 1.0 (it simply cannot
    serve more — flagged by callers via ``is_feasible``).
    """
    if dev_throughput <= 0:
        raise ValueError("device throughput must be positive")
    return min(duty_activity * ref_throughput / dev_throughput, 1.0)


@dataclasses.dataclass(frozen=True)
class Platform:
    """A candidate system for the indifference comparison."""
    name: str
    embodied_j: float
    power: hw.PowerStates
    throughput: float          # workload throughput when active (FPS/GFLOPS/...)

    def average_power_w(self, duty: Duty, ref_throughput: float) -> float:
        busy = iso_throughput_busy_fraction(duty.activity, ref_throughput,
                                            self.throughput)
        return average_power_w(self.power, busy, duty.sleep_ratio)

    def is_feasible(self, duty: Duty, ref_throughput: float) -> bool:
        return duty.activity * ref_throughput <= self.throughput * (1 + 1e-12)


def platform_from_hw(device: str, benchmark: str, phase: str, *,
                     embodied_j: Optional[float] = None,
                     per_module: bool = False) -> Platform:
    """Build a Platform from the hw/lca databases and a Table-3 point."""
    from repro.core import lca   # local import to avoid cycle at module load
    spec = hw.DEVICES[device]
    point = hw.workload_points(benchmark, phase)[device]
    if embodied_j is None:
        embodied_j = lca.embodied_energy_mj(spec, per_module=per_module) * 1e6
    # Active power is workload-dependent (Table 3 measured); idle/sleep are
    # device properties from the spec.
    power = hw.PowerStates(active_w=point.power_w, idle_w=spec.power.idle_w,
                           sleep_w=spec.power.sleep_w)
    return Platform(name=device, embodied_j=embodied_j, power=power,
                    throughput=point.throughput)


# ----------------------------------------------------------------------------
# Pairwise analysis & Fig.2 surfaces
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Comparison:
    challenger: str
    incumbent: str
    duty: Duty
    p_challenger_w: float
    p_incumbent_w: float
    indifference_s: float
    breakeven_s: float
    challenger_dominates: bool   # lower embodied AND lower operational
    feasible: bool


def compare(challenger: Platform, incumbent: Platform, duty: Duty,
            ref_throughput: Optional[float] = None) -> Comparison:
    """Full Eq.-1 comparison under a duty scenario.

    ``ref_throughput`` sets the demand scale; defaults to the slower platform
    (so activity=1 is the largest demand both can possibly serve).
    """
    ref = ref_throughput if ref_throughput is not None else min(
        challenger.throughput, incumbent.throughput)
    pc = challenger.average_power_w(duty, ref)
    pi = incumbent.average_power_w(duty, ref)
    t_i = indifference_time_s(challenger.embodied_j, incumbent.embodied_j, pi, pc)
    t_b = breakeven_time_s(challenger.embodied_j, pi, pc)
    dominates = (challenger.embodied_j <= incumbent.embodied_j) and (pc <= pi)
    feasible = challenger.is_feasible(duty, ref) and incumbent.is_feasible(duty, ref)
    return Comparison(challenger.name, incumbent.name, duty, pc, pi,
                      t_i, t_b, dominates, feasible)


def surface(challenger: Platform, incumbent: Platform,
            activities: Sequence[float], sleep_ratios: Sequence[float],
            kind: str = "breakeven",
            ref_throughput: Optional[float] = None) -> np.ndarray:
    """Fig.-2 style 2-D surface of t_B or t_I (years); inf where never."""
    if kind not in ("breakeven", "indifference"):
        raise ValueError(kind)
    out = np.empty((len(sleep_ratios), len(activities)))
    for i, s in enumerate(sleep_ratios):
        for j, a in enumerate(activities):
            c = compare(challenger, incumbent, Duty(a, s), ref_throughput)
            t = c.breakeven_s if kind == "breakeven" else c.indifference_s
            out[i, j] = t / SECONDS_PER_YEAR
    return out


def crossover_activity(challenger: Platform, incumbent: Platform,
                       sleep_ratio: float = 0.0,
                       ref_throughput: Optional[float] = None,
                       tol: float = 1e-6) -> float:
    """Smallest activity at which the challenger's operational power drops
    below the incumbent's (bisection; 1.0+ means never)."""
    def dp(a: float) -> float:
        c = compare(challenger, incumbent, Duty(a, sleep_ratio), ref_throughput)
        return c.p_incumbent_w - c.p_challenger_w
    lo, hi = 0.0, 1.0
    if dp(hi) <= 0:
        return math.inf
    if dp(lo) > 0:
        return 0.0
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if dp(mid) > 0:
            hi = mid
        else:
            lo = mid
    return hi


def decide(platforms: List[Platform], duty: Duty, service_time_s: float,
           ref_throughput: Optional[float] = None) -> Dict[str, float]:
    """Pick the min-holistic-energy platform for a service time (advisor core)."""
    ref = ref_throughput if ref_throughput is not None else min(
        p.throughput for p in platforms)
    totals = {}
    for p in platforms:
        if not p.is_feasible(duty, ref):
            totals[p.name] = math.inf
            continue
        totals[p.name] = total_energy_j(
            p.embodied_j, p.average_power_w(duty, ref), service_time_s)
    return totals
