"""Data pipeline substrate."""

from repro.data.pipeline import (  # noqa: F401
    DataConfig, TokenPipeline, make_pipeline,
)
