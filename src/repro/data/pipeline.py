"""Deterministic, shardable, resumable token pipeline.

Properties the trainer depends on (all tested):

* **Determinism**: batch at global step s is a pure function of
  (seed, step, shard) — restarts and elastic re-sharding reproduce the
  exact token stream with no iterator state beyond the step counter.
* **Sharding**: each DP rank reads only its slice (host-sharded loading);
  re-sharding to a different DP size re-slices the same global batch.
* **Resumability**: state is {step}; checkpointing it costs 8 bytes.

Sources: "synthetic" (seeded uniform tokens), "lm1b-like" Markov-chain tokens
(learnable structure — used by the loss-goes-down tests), or a binary token
file (np.memmap) for real corpora.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"      # "synthetic" | "markov" | "file"
    path: Optional[str] = None     # token file (uint16/uint32 binary)
    markov_order: int = 1
    dp_rank: int = 0
    dp_size: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.dp_size == 0, (
            self.global_batch, self.dp_size)
        return self.global_batch // self.dp_size


class TokenPipeline:
    """Stateless-per-step batch generator; ``state`` is just the step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._step = 0
        self._mm = None
        self._markov_T: Optional[np.ndarray] = None
        if cfg.source == "file":
            if not cfg.path:
                raise ValueError("source='file' needs cfg.path")
            self._mm = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        elif cfg.source == "markov":
            rng = np.random.default_rng(cfg.seed ^ 0x5EED)
            t = rng.dirichlet(np.full(cfg.vocab, 0.05), size=cfg.vocab)
            self._markov_T = np.cumsum(t, axis=1)

    # -- state ---------------------------------------------------------------

    @property
    def state(self) -> Dict[str, int]:
        return {"step": self._step}

    def restore(self, state: Dict[str, int]) -> None:
        self._step = int(state["step"])

    # -- batch synthesis -------------------------------------------------------

    def _rng_for(self, step: int, row: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 0x9E3779B1 + step) * 0x85EBCA6B + row)

    def _row(self, step: int, global_row: int) -> np.ndarray:
        cfg = self.cfg
        n = cfg.seq_len + 1
        if cfg.source == "file":
            total = len(self._mm) - n
            off = int(self._rng_for(step, global_row).integers(0, total))
            return np.asarray(self._mm[off:off + n], dtype=np.int32) % cfg.vocab
        rng = self._rng_for(step, global_row)
        if cfg.source == "markov":
            out = np.empty(n, np.int32)
            out[0] = rng.integers(0, cfg.vocab)
            u = rng.random(n - 1)
            for i in range(1, n):
                out[i] = np.searchsorted(self._markov_T[out[i - 1]], u[i - 1])
            return np.clip(out, 0, cfg.vocab - 1)
        return rng.integers(0, cfg.vocab, size=n, dtype=np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        lo = cfg.dp_rank * cfg.local_batch
        rows = np.stack([self._row(step, lo + i) for i in range(cfg.local_batch)])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self._step)
        self._step += 1
        return b

    def reshard(self, dp_rank: int, dp_size: int) -> "TokenPipeline":
        """Elastic re-sharding: same global stream, new slice, same step."""
        new = TokenPipeline(dataclasses.replace(self.cfg, dp_rank=dp_rank,
                                                dp_size=dp_size))
        new._step = self._step
        return new


def make_pipeline(cfg: DataConfig) -> TokenPipeline:
    return TokenPipeline(cfg)


def write_token_file(path: str, tokens: np.ndarray) -> None:
    tokens.astype(np.uint32).tofile(path)
