"""Decode-attention Pallas TPU kernel for the continuous-batching serve core.

One query token per sequence (the engine tick's batched decode) against the
slot-major KV cache, with **per-slot lengths**: slot b's valid cache rows are
the contiguous prefix ``[0, lengths[b])`` (its query sits at position
``lengths[b] - 1``). K blocks past a slot's length — and *every* block of a
dead slot (``lengths[b] == 0``) — are skipped with ``pl.when``, so draining
batches and short sequences cost no FLOPs instead of computing masked-out
attention the way a dense XLA decode does.

**Int8 KV mode** (the quantized serving fast path, DESIGN.md §12): when
``k_scale``/``v_scale`` are given, K/V arrive int8 with one fp32 scale per
(slot, position, kv-head) and are dequantized *inside the kernel body*,
tile by tile, right after the HBM->VMEM DMA — the full-precision cache
never exists in memory, so per-tick KV traffic drops ~4x vs fp32 (the
paper's bytes-dominate-energy argument applied to the decode hot loop).

Grid: (batch, kv_heads, Sk/bk) with the K sweep innermost; the ``rep``
query heads of one KV head are processed together as the MXU's M dimension.
Lengths ride in scalar-prefetch SMEM so the skip test is resolved before the
block's compute issues.

Supports causal semantics implicitly (the query is the newest position) and
sliding windows. Validated in interpret mode against a masked SDPA oracle
(tests/test_serve_core.py, tests/test_kernels_int8.py).

**Paged variant** (``paged_decode_attention``, DESIGN.md §14): K/V live in a
shared block *pool* of ``page_size``-token pages instead of one dense
``max_len`` region per slot; each slot's logical blocks map to physical
pages through a ``(B, NB)`` page table. The table rides in scalar-prefetch
SMEM next to the lengths, and the K/V BlockSpec ``index_map`` resolves
``page_table[b, logical_block]`` *before* the block's DMA issues — the
gather is the DMA, no materialized per-slot copy of the cache ever exists.
Everything else (grid, online softmax, per-slot length skip, int8-KV
in-kernel dequant) matches the dense kernel, so a slot whose pages happen
to be contiguous computes the identical FLOPs through either entry point.

**Multi-query verify variant** (``paged_verify_attention``, DESIGN.md §15):
the speculative-decode verification pass carries a q-block of T tokens per
slot (pending token + drafts) through the same page-table indirection; the
T lanes and the GQA ``rep`` heads flatten into one MXU M dimension, and the
causal mask becomes per-lane (lane t attends positions <= length - T + t).
One K sweep scores every draft position — the per-tick weight/KV-traffic
amortization the speculative path exists for.

**Tree speculation rides the same entry point** (DESIGN.md §18): every
per-row input (q-block, page-table row, length) is independent across the
batch dimension, so the engine folds the M branches of a token tree into
batch rows — row ``b * M + m`` carries branch m's drafts over branch m's
*forked* table (shared committed pages + COW-private divergence pages) —
and one ``pallas_call`` scores all B·M branches. No branch-aware kernel is
needed precisely because the gather is the DMA: two branches reading the
same committed page express sharing in their tables, not in extra copies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, *refs, scale: float, window: int, block_k: int,
                   n_k_blocks: int, quantized: bool):
    if quantized:
        q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    bi, ki = pl.program_id(0), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[bi]                       # valid prefix; 0 = dead slot
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)
    valid = k_pos < length
    if window > 0:
        # query position is length - 1; window masks older keys
        valid &= (length - 1 - k_pos) < window

    # dead slots and blocks past the slot's length issue no compute
    @pl.when(jnp.logical_and(length > 0, ki * block_k < length))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rep, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        if quantized:
            # in-kernel dequant: per-row fp32 scale, applied in-register
            k = k * ks_ref[0, 0]                             # (bk, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid, s, NEG_INF)                     # (rep, bk)
        m_prev = m_ref[...]                                  # (rep, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        if quantized:
            v = v * vs_ref[0, 0]                             # (bk, 1)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "block_k",
                                             "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, *, scale: float, window: int = -1,
                     block_k: int = 128, interpret: bool = False,
                     k_scale=None, v_scale=None) -> jnp.ndarray:
    """q: (B, H, D) one token per row; k/v: (B, Sk, Hkv, D); lengths: (B,).

    ``k_scale``/``v_scale`` (B, Sk, Hkv) fp32 switch on int8-KV mode: k/v are
    int8 codes dequantized inside the kernel (pass both or neither).

    Sk % block_k == 0 (ops.py pads otherwise; padded keys sit past every
    length so the length test masks them). Dead slots (length 0) return 0.
    Returns (B, H, D) in q.dtype (fp32 for int8 queries).
    """
    b, h, d = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    assert sk % block_k == 0, (sk, block_k)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"
    nk = sk // block_k

    qg = q.reshape(b, hkv, rep, d)
    kt = k.transpose(0, 2, 1, 3)               # (B, Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    kv_spec = pl.BlockSpec((1, 1, block_k, d),
                           lambda bi, hi, ki, lens: (bi, hi, ki, 0))
    in_specs = [
        pl.BlockSpec((1, 1, rep, d), lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
        kv_spec,
    ]
    operands = [qg, kt]
    if quantized:
        sc_spec = pl.BlockSpec((1, 1, block_k, 1),
                               lambda bi, hi, ki, lens: (bi, hi, ki, 0))
        kst = k_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        vst = v_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        in_specs += [sc_spec, kv_spec, sc_spec]
        operands += [kst, vt, vst]
    else:
        in_specs += [kv_spec]
        operands += [vt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),     # running max
            pltpu.VMEM((rep, 1), jnp.float32),     # running denom
            pltpu.VMEM((rep, d), jnp.float32),     # output accumulator
        ],
    )
    out_dtype = jnp.float32 if q.dtype == jnp.int8 else q.dtype
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          block_k=block_k, n_k_blocks=nk,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), out_dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), *operands)
    return out.reshape(b, h, d)


def _paged_kernel(len_ref, pt_ref, *refs, scale: float, window: int,
                  page_size: int, n_blocks: int, quantized: bool):
    """Same online-softmax body as ``_decode_kernel``; the only difference
    is upstream — each K/V block was DMA'd from ``pt_ref[bi, ki]``'s pool
    page rather than from a dense slot-major row, so ``ki`` remains the
    *logical* block index and the length/window math is unchanged."""
    del pt_ref                                   # consumed by the index_maps
    if quantized:
        q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    bi, ki = pl.program_id(0), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[bi]                         # valid prefix; 0 = dead slot
    k_pos = ki * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = k_pos < length
    if window > 0:
        valid &= (length - 1 - k_pos) < window

    @pl.when(jnp.logical_and(length > 0, ki * page_size < length))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rep, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        if quantized:
            k = k * ks_ref[0, 0]                             # (ps, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid, s, NEG_INF)                     # (rep, ps)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        if quantized:
            v = v * vs_ref[0, 0]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, scale: float,
                           window: int = -1, interpret: bool = False,
                           k_scale=None, v_scale=None) -> jnp.ndarray:
    """Decode attention through a paged KV pool.

    q: (B, H, D) one token per slot; k_pool/v_pool: (P, page_size, Hkv, D)
    the shared block pool; page_table: (B, NB) int32 mapping slot b's
    logical block j to a physical page (entries past a slot's length must
    still be in-bounds — the engine points them at the sink page);
    lengths: (B,) valid logical prefix per slot (0 = dead slot -> zeros).

    ``k_scale``/``v_scale`` (P, page_size, Hkv) fp32 switch on int8-KV
    mode (pool holds int8 codes, dequantized in the kernel body).

    The grid's K sweep runs over *logical* blocks; the page indirection is
    entirely inside the BlockSpec index_maps, which read the scalar-
    prefetched table — so a K/V tile is DMA'd straight from its pool page.
    Returns (B, H, D) in q.dtype (fp32 for int8 queries).
    """
    b, h, d = q.shape
    p_pages, page_size, hkv, _ = k_pool.shape
    nb = page_table.shape[1]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"

    qg = q.reshape(b, hkv, rep, d)
    kt = k_pool.transpose(0, 2, 1, 3)            # (P, Hkv, ps, D)
    vt = v_pool.transpose(0, 2, 1, 3)

    def kv_map(bi, hi, ki, lens, pt):
        del lens
        return (pt[bi, ki], hi, 0, 0)

    kv_spec = pl.BlockSpec((1, 1, page_size, d), kv_map)
    in_specs = [
        pl.BlockSpec((1, 1, rep, d),
                     lambda bi, hi, ki, lens, pt: (bi, hi, 0, 0)),
        kv_spec,
    ]
    operands = [qg, kt]
    if quantized:
        sc_spec = pl.BlockSpec((1, 1, page_size, 1), kv_map)
        kst = k_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        vst = v_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        in_specs += [sc_spec, kv_spec, sc_spec]
        operands += [kst, vt, vst]
    else:
        in_specs += [kv_spec]
        operands += [vt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rep, d),
                               lambda bi, hi, ki, lens, pt: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),     # running max
            pltpu.VMEM((rep, 1), jnp.float32),     # running denom
            pltpu.VMEM((rep, d), jnp.float32),     # output accumulator
        ],
    )
    out_dtype = jnp.float32 if q.dtype == jnp.int8 else q.dtype
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, window=window,
                          page_size=page_size, n_blocks=nb,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), out_dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), *operands)
    return out.reshape(b, h, d)


def _paged_verify_kernel(len_ref, pt_ref, *refs, scale: float, window: int,
                         page_size: int, n_blocks: int, n_q: int, rep: int,
                         quantized: bool):
    """Multi-query variant of ``_paged_kernel`` for speculative verification
    (DESIGN.md §15): each slot carries a q-block of ``n_q`` tokens (the
    committed pending token + the drafts), flattened with the ``rep`` GQA
    query heads into the MXU's M dimension. Query lane t of slot b sits at
    absolute position ``lengths[b] - n_q + t`` — the lengths already count
    the whole q-block — so the per-lane causal mask is
    ``k_pos <= q_pos(lane)`` instead of the single-token kernel's uniform
    ``k_pos < length``. One weight-free online-softmax sweep over the
    slot's pages scores all ``n_q`` positions at once: the k-per-tick
    weight amortization that speculative decode buys."""
    del pt_ref                                   # consumed by the index_maps
    if quantized:
        q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    bi, ki = pl.program_id(0), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[bi]                         # incl. the q-block; 0 = dead
    k_pos = ki * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                          # (1, ps)
    # lane index of each flattened q row: rows are (t, rep) row-major
    t_row = jax.lax.broadcasted_iota(jnp.int32, (n_q * rep, 1), 0) // rep
    q_pos = length - n_q + t_row                               # (T*rep, 1)
    valid = k_pos <= q_pos                                     # (T*rep, ps)
    if window > 0:
        valid &= (q_pos - k_pos) < window

    @pl.when(jnp.logical_and(length > 0, ki * page_size < length))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (T*rep, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        if quantized:
            k = k * ks_ref[0, 0]                             # (ps, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(valid, s, NEG_INF)                     # (T*rep, ps)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        if quantized:
            v = v * vs_ref[0, 0]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def paged_verify_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, scale: float,
                           window: int = -1, interpret: bool = False,
                           k_scale=None, v_scale=None) -> jnp.ndarray:
    """Multi-query decode attention through a paged KV pool.

    q: (B, T, H, D) — T query tokens per slot, already written into the
    pool at logical positions ``lengths[b] - T + t``; k_pool/v_pool:
    (P, page_size, Hkv, D); page_table: (B, NB) int32 (out-of-chain
    entries must point at the sink page); lengths: (B,) valid logical
    prefix per slot INCLUDING the T chunk tokens (0 = dead slot -> zeros).
    ``k_scale``/``v_scale`` (P, page_size, Hkv) fp32 switch on int8-KV
    mode. Causal within the chunk: lane t attends positions
    ``<= lengths - T + t``. Returns (B, T, H, D) in q.dtype (fp32 for
    int8 queries)."""
    b, t, h, d = q.shape
    p_pages, page_size, hkv, _ = k_pool.shape
    nb = page_table.shape[1]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"

    # (B, T, Hkv, rep, D) -> (B, Hkv, T*rep, D): lanes (t, rep) row-major,
    # matching the kernel's t_row = row // rep decode
    qg = q.reshape(b, t, hkv, rep, d).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(b, hkv, t * rep, d)
    kt = k_pool.transpose(0, 2, 1, 3)            # (P, Hkv, ps, D)
    vt = v_pool.transpose(0, 2, 1, 3)

    def kv_map(bi, hi, ki, lens, pt):
        del lens
        return (pt[bi, ki], hi, 0, 0)

    kv_spec = pl.BlockSpec((1, 1, page_size, d), kv_map)
    in_specs = [
        pl.BlockSpec((1, 1, t * rep, d),
                     lambda bi, hi, ki, lens, pt: (bi, hi, 0, 0)),
        kv_spec,
    ]
    operands = [qg, kt]
    if quantized:
        sc_spec = pl.BlockSpec((1, 1, page_size, 1), kv_map)
        kst = k_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        vst = v_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        in_specs += [sc_spec, kv_spec, sc_spec]
        operands += [kst, vt, vst]
    else:
        in_specs += [kv_spec]
        operands += [vt]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, t * rep, d),
                               lambda bi, hi, ki, lens, pt: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((t * rep, 1), jnp.float32),     # running max
            pltpu.VMEM((t * rep, 1), jnp.float32),     # running denom
            pltpu.VMEM((t * rep, d), jnp.float32),     # output accumulator
        ],
    )
    out_dtype = jnp.float32 if q.dtype == jnp.int8 else q.dtype
    out = pl.pallas_call(
        functools.partial(_paged_verify_kernel, scale=scale, window=window,
                          page_size=page_size, n_blocks=nb, n_q=t, rep=rep,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, t * rep, d), out_dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), *operands)
    return out.reshape(b, hkv, t, rep, d).transpose(0, 2, 1, 3, 4
                                                    ).reshape(b, t, h, d)
