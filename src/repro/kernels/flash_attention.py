"""Flash attention (online-softmax) Pallas TPU kernel.

The serving/long-context hot spot: tiled attention with O(bq*bk) VMEM working
set instead of O(Sq*Sk) HBM traffic. Supports causal masking, sliding windows
(gemma3's local layers), and GQA via the kv-head index map (no K/V
replication in memory).

Grid: (batch, q_heads, Sq/bq, Sk/bk) with the K sweep innermost; running
max/denominator/accumulator live in VMEM scratch. Block sizes MXU/VPU-aligned
(128 lanes).

Validated in interpret mode against ref.attention_ref across a shape/dtype/
mask sweep (tests/test_kernels_attention.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tile_mask(qi, ki, block_q: int, block_k: int, causal: bool, window: int):
    """Valid-position mask for one (qi, ki) tile — THE masking rule, shared
    by the forward and both backward kernels so the semantics cannot drift."""
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    diff = q_pos - k_pos
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    return mask


def _flash_kernel(*refs, scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k_blocks: int,
                  quantized: bool = False, save_lse: bool = False):
    lse_ref = None
    if quantized:
        q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    elif save_lse:
        q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mask = _tile_mask(qi, ki, block_q, block_k, causal, window)

    # skip fully-masked K blocks (the causal upper triangle / outside-window)
    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        if quantized:
            # int8-KV fast path: per-row fp32 scale applied in-register,
            # right after the narrow HBM->VMEM DMA (DESIGN.md §12)
            k = k * ks_ref[0, 0]                            # (bk, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                              # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        if quantized:
            v = v * vs_ref[0, 0]                            # (bk, 1)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        if save_lse:
            # per-row softmax normalizer, the residual the backward kernels
            # recompute p = exp(s - lse) from (no O(Sq*Sk) probs in memory)
            lse_ref[0, 0] = m_ref[...] + jnp.log(l)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    scale: float, causal: bool = True, window: int = -1,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False,
                    k_scale=None, v_scale=None) -> jnp.ndarray:
    """q: (B, Sq, H, D), k/v: (B, Sk, Hkv, D) with H % Hkv == 0.

    ``k_scale``/``v_scale`` (B, Sk, Hkv) fp32 switch on int8-KV mode: k/v
    are int8 codes dequantized tile-by-tile inside the kernel body (pass
    both or neither) — full-precision K/V never round-trip through memory.

    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads otherwise).
    Returns (B, Sq, H, D) in q.dtype.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"
    nq, nk = sq // block_q, sk // block_k

    # layout: heads-major so each (b, h) pair owns contiguous seq blocks
    qt = q.transpose(0, 2, 1, 3)       # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)       # (B, Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    kv_spec = pl.BlockSpec((1, 1, block_k, d),
                           lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0))
    in_specs = [
        pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        kv_spec,
    ]
    operands = [qt, kt]
    if quantized:
        sc_spec = pl.BlockSpec(
            (1, 1, block_k, 1),
            lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0))
        kst = k_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        vst = v_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        in_specs += [sc_spec, kv_spec, sc_spec]
        operands += [kst, vt, vst]
    else:
        in_specs += [kv_spec]
        operands += [vt]

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k_blocks=nk, quantized=quantized),
        grid=(b, h, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(*operands)
    return out.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Training fast path: custom VJP (DESIGN.md §13)
#
# Forward saves only O and the per-row softmax normalizer lse = m + log(l)
# (B, H, Sq, 1) — the backward kernels recompute p = exp(s - lse) tile by
# tile, so the O(Sq*Sk) probability matrix never exists in memory:
#
#   delta = rowsum(dO * O)                       (cheap jnp preprocess)
#   dV    = p^T @ dO
#   dS    = p * (dO @ V^T - delta)
#   dQ    = scale * dS @ K ;  dK = scale * dS^T @ Q
#
# Two kernels: dQ sweeps K blocks innermost (grid b,h,nq,nk; dq tile
# accumulates in VMEM scratch), dK/dV sweep Q blocks innermost (grid
# b,h,nk,nq; dk/dv tiles in scratch). Both skip fully-masked tiles exactly
# like the forward. GQA: dK/dV come out per *query* head and are
# sum-reduced over the head group outside the kernel (fp32).
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc_ref, *, scale: float, causal: bool,
                         window: int, block_q: int, block_k: int,
                         n_k_blocks: int):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mask = _tile_mask(qi, ki, block_q, block_k, causal, window)

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        # explicit mask (not just s=NEG_INF): rows whose every block is
        # masked have lse ~ NEG_INF and exp(s - lse) would be 1, not 0
        p = jnp.where(mask, jnp.exp(s - lse_ref[0, 0]), 0.0)   # (bq, bk)
        do = do_ref[0, 0].astype(jnp.float32)               # (bq, d)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0])                     # (bq, bk)
        acc_ref[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        dq_ref[0, 0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                          causal: bool, window: int, block_q: int,
                          block_k: int, n_q_blocks: int):
    ki, qi = pl.program_id(2), pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    mask = _tile_mask(qi, ki, block_q, block_k, causal, window)

    @pl.when(jnp.any(mask))
    def _compute():
        # q pre-scaled: dS^T @ (scale*Q) == scale * dS^T @ Q == dK directly
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0, 0]), 0.0)   # (bq, bk)
        do = do_ref[0, 0].astype(jnp.float32)               # (bq, d)
        dv_acc[...] += jax.lax.dot_general(                 # p^T @ dO
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0])                     # (bq, bk)
        dk_acc[...] += jax.lax.dot_general(                 # dS^T @ q*scale
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == n_q_blocks - 1)
    def _finish():
        # fp32 out: the GQA head-group sum happens outside the kernel
        dk_ref[0, 0] = dk_acc[...]
        dv_ref[0, 0] = dv_acc[...]


def _fwd_with_lse(q, k, v, statics):
    """Forward pass that also returns the per-row lse residual (B,H,Sq,1)."""
    scale, causal, window, block_q, block_k, interpret = statics
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    rep = h // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kv_spec = pl.BlockSpec((1, 1, block_k, d),
                           lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0))
    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k_blocks=nk, save_lse=True),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention_vjp(q, k, v, statics):
    """Differentiable flash attention. ``statics`` is the hashable tuple
    (scale, causal, window, block_q, block_k, interpret); shapes must be
    block multiples (ops.flash_attention_train pads)."""
    out, _ = _fwd_with_lse(q, k, v, statics)
    return out


def _flash_vjp_fwd(q, k, v, statics):
    out, lse = _fwd_with_lse(q, k, v, statics)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(statics, res, dout):
    scale, causal, window, block_q, block_k, interpret = statics
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    rep = h // hkv
    nq, nk = sq // block_q, sk // block_k
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = dout.transpose(0, 2, 1, 3)
    delta = jnp.sum(dot.astype(jnp.float32)
                    * out.transpose(0, 2, 1, 3).astype(jnp.float32),
                    axis=-1, keepdims=True)                # (B, H, Sq, 1)
    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    row_spec = pl.BlockSpec((1, 1, block_q, 1),
                            lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, d),
                           lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0))
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k_blocks=nk),
        grid=(b, h, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)
    # dK/dV grid: K blocks outer, Q sweep innermost
    q_spec_t = pl.BlockSpec((1, 1, block_q, d),
                            lambda bi, hi, ki, qi: (bi, hi, qi, 0))
    row_spec_t = pl.BlockSpec((1, 1, block_q, 1),
                              lambda bi, hi, ki, qi: (bi, hi, qi, 0))
    kv_spec_t = pl.BlockSpec((1, 1, block_k, d),
                             lambda bi, hi, ki, qi, rep=rep: (bi, hi // rep, ki, 0))
    kv_out_spec = pl.BlockSpec((1, 1, block_k, d),
                               lambda bi, hi, ki, qi: (bi, hi, ki, 0))
    dkh, dvh = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_q_blocks=nq),
        grid=(b, h, nk, nq),
        in_specs=[q_spec_t, kv_spec_t, kv_spec_t, q_spec_t, row_spec_t,
                  row_spec_t],
        out_specs=[kv_out_spec, kv_out_spec],
        out_shape=[jax.ShapeDtypeStruct((b, h, sk, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, sk, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)
    # GQA: per-query-head dK/dV sum over the head group (fp32), then layout
    # back to (B, Sk, Hkv, D)
    dk = dkh.reshape(b, hkv, rep, sk, d).sum(axis=2)
    dv = dvh.reshape(b, hkv, rep, sk, d).sum(axis=2)
    return (dq.transpose(0, 2, 1, 3),
            dk.transpose(0, 2, 1, 3).astype(k.dtype),
            dv.transpose(0, 2, 1, 3).astype(v.dtype))


flash_attention_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# Paged flash prefill (DESIGN.md §16)
#
# The long-context serving tier's prefill kernel: an in-flight prompt chunk
# attends over its slot's paged KV pool DIRECTLY — the per-sequence page
# table rides in scalar-prefetch SMEM (the paged_decode_attention idiom) and
# the K/V BlockSpec index_map resolves pt[b, j] per tile, so the page gather
# IS the HBM->VMEM DMA. This replaces paged_extend's XLA fallback, which
# materializes the slot's ENTIRE (NB * page_size) window per chunk — O(chunks
# x window) gather bytes on a fragmented long context, the exact DRAM term
# the paper says dominates edge energy.
#
# Numerics contract (must match the paged_extend oracle token-for-token):
# the cached prefix [0, start) is read from the pool in STORAGE dtype (int8
# codes dequantized in-kernel — decode numerics), while the chunk's own
# K/V arrive as separate full-precision operands (dense-prefill numerics).
# The K sweep therefore runs NB page steps plus ONE chunk step; pages past
# the cached window are clamped in the index_map to the last needed page,
# so the revolving-window pipeline issues no DMA for them — gather traffic
# is ceil(start/ps) pages per row, independent of fragmentation.
# ---------------------------------------------------------------------------


def _paged_prefill_kernel(start_ref, len_ref, pt_ref, *refs, scale: float,
                          window: int, page_size: int, n_blocks: int,
                          block_q: int, rep: int, quantized: bool):
    """Online-softmax body. Grid (B, Hkv, NQ, NB + 1): page steps
    ki < NB score the cached window in storage dtype; the final step
    ki == NB scores the full-precision in-flight chunk with the causal
    in-chunk mask. Query rows flatten (token, rep) row-major, ``block_q``
    chunk tokens per tile."""
    del pt_ref                                   # consumed by the index_maps
    if quantized:
        (q_ref, kp_ref, kps_ref, vp_ref, vps_ref, kc_ref, vc_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        (q_ref, kp_ref, vp_ref, kc_ref, vc_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
        kps_ref = vps_ref = None
    bi, qi, ki = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[bi]                        # cached-prefix length
    ln = len_ref[bi]                             # valid chunk tokens; 0=dead
    rows = block_q * rep
    # chunk-relative token index of each flattened q row ((t, rep) major)
    q_rel = (qi * rows + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
             ) // rep
    q_abs = start + q_rel                        # absolute position

    def _accumulate(s, valid, v):
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # -- page step: cached window [0, start), storage dtype ------------------
    k_pos = ki * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid_p = k_pos < start                      # chunk's own pages excluded
    if window > 0:
        valid_p &= (q_abs - k_pos) < window

    @pl.when(jnp.logical_and(ln > 0,
                             jnp.logical_and(ki < n_blocks,
                                             ki * page_size < start)))
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rows, d)
        k = kp_ref[0, 0].astype(jnp.float32)                 # (ps, d)
        if quantized:
            k = k * kps_ref[0, 0]                            # (ps, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        v = vp_ref[0, 0].astype(jnp.float32)
        if quantized:
            v = v * vps_ref[0, 0]
        _accumulate(s, valid_p, v)

    # -- chunk step: in-flight tokens, full precision, causal ----------------
    c = kc_ref.shape[2]
    k_rel = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)
    valid_c = (k_rel <= q_rel) & (k_rel < ln)
    if window > 0:
        valid_c &= (q_rel - k_rel) < window

    @pl.when(jnp.logical_and(ln > 0, ki == n_blocks))
    def _chunk():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rows, d)
        k = kc_ref[0, 0].astype(jnp.float32)                 # (c, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        _accumulate(s, valid_c, vc_ref[0, 0].astype(jnp.float32))

    @pl.when(ki == n_blocks)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "block_q",
                                             "interpret"))
def paged_prefill_attention(q: jnp.ndarray, k_new: jnp.ndarray,
                            v_new: jnp.ndarray, k_pool: jnp.ndarray,
                            v_pool: jnp.ndarray, page_table: jnp.ndarray,
                            starts: jnp.ndarray, lens: jnp.ndarray, *,
                            scale: float, window: int = -1,
                            block_q: int = 128, interpret: bool = False,
                            k_scale=None, v_scale=None) -> jnp.ndarray:
    """Chunk prefill attention through a paged KV pool.

    q: (B, C, H, D) chunk queries (rope applied); k_new/v_new: (B, C, Hkv,
    D) the chunk's FULL-PRECISION K/V (what the in-chunk attention sees —
    dense-prefill numerics); k_pool/v_pool: (P, page_size, Hkv, D) storage
    pools, already holding the scattered chunk (the kernel only reads pages
    covering [0, start)); page_table: (B, NB) int32 (entries past a slot's
    chain must be in-bounds — the engine points them at the sink page);
    starts: (B,) cached-prefix length per row; lens: (B,) valid chunk
    tokens (0 = dead row -> zeros). ``k_scale``/``v_scale`` (P, page_size,
    Hkv) fp32 switch on int8-KV in-kernel dequant for the cached window.
    ``block_q`` is in chunk TOKENS (C % block_q == 0; ops.py pads).
    Returns (B, C, H, D) in q.dtype; rows past ``lens`` are garbage (the
    caller's padding contract, same as paged_extend)."""
    b, c, h, d = q.shape
    p_pages, page_size, hkv, _ = k_pool.shape
    nb = page_table.shape[1]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    assert c % block_q == 0, (c, block_q)
    nq = c // block_q
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"

    # (B, C, Hkv, rep, D) -> (B, Hkv, C*rep, D): rows (t, rep) row-major,
    # matching the kernel's q_rel = row // rep decode
    qg = q.reshape(b, c, hkv, rep, d).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(b, hkv, c * rep, d)
    kpt = k_pool.transpose(0, 2, 1, 3)           # (P, Hkv, ps, D)
    vpt = v_pool.transpose(0, 2, 1, 3)
    kct = k_new.transpose(0, 2, 1, 3)            # (B, Hkv, C, D)
    vct = v_new.transpose(0, 2, 1, 3)

    def kv_map(bi, hi, qi, ki, starts, lens, pt):
        del lens
        # pages past the cached window re-map to the last needed page: the
        # revolving-window pipeline skips their DMA, so gather traffic is
        # ceil(start/ps) pages per row regardless of NB or fragmentation
        last = jnp.maximum(starts[bi] - 1, 0) // page_size
        return (pt[bi, jnp.minimum(ki, last)], hi, 0, 0)

    def chunk_map(bi, hi, qi, ki, starts, lens, pt):
        del starts, lens, pt
        return (bi, hi, 0, 0)

    rows = block_q * rep
    kv_spec = pl.BlockSpec((1, 1, page_size, d), kv_map)
    chunk_spec = pl.BlockSpec((1, 1, c, d), chunk_map)
    in_specs = [
        pl.BlockSpec((1, 1, rows, d),
                     lambda bi, hi, qi, ki, starts, lens, pt:
                     (bi, hi, qi, 0)),
        kv_spec,
    ]
    operands = [qg, kpt]
    if quantized:
        sc_spec = pl.BlockSpec((1, 1, page_size, 1), kv_map)
        kst = k_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        vst = v_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        in_specs += [sc_spec, kv_spec, sc_spec]
        operands += [kst, vpt, vst]
    else:
        in_specs += [kv_spec]
        operands += [vpt]
    in_specs += [chunk_spec, chunk_spec]
    operands += [kct, vct]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, nq, nb + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, rows, d),
                               lambda bi, hi, qi, ki, starts, lens, pt:
                               (bi, hi, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),     # running max
            pltpu.VMEM((rows, 1), jnp.float32),     # running denom
            pltpu.VMEM((rows, d), jnp.float32),     # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, scale=scale, window=window,
                          page_size=page_size, n_blocks=nb, block_q=block_q,
                          rep=rep, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, c * rep, d), q.dtype),
        interpret=interpret,
    )(starts.astype(jnp.int32), lens.astype(jnp.int32),
      page_table.astype(jnp.int32), *operands)
    return out.reshape(b, hkv, c, rep, d).transpose(0, 2, 1, 3, 4
                                                    ).reshape(b, c, h, d)
