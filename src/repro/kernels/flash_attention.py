"""Flash attention (online-softmax) Pallas TPU kernel.

The serving/long-context hot spot: tiled attention with O(bq*bk) VMEM working
set instead of O(Sq*Sk) HBM traffic. Supports causal masking, sliding windows
(gemma3's local layers), and GQA via the kv-head index map (no K/V
replication in memory).

Grid: (batch, q_heads, Sq/bq, Sk/bk) with the K sweep innermost; running
max/denominator/accumulator live in VMEM scratch. Block sizes MXU/VPU-aligned
(128 lanes).

Validated in interpret mode against ref.attention_ref across a shape/dtype/
mask sweep (tests/test_kernels_attention.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(*refs, scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k_blocks: int,
                  quantized: bool = False):
    if quantized:
        q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
        ks_ref = vs_ref = None
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    diff = q_pos - k_pos
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window

    # skip fully-masked K blocks (the causal upper triangle / outside-window)
    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        if quantized:
            # int8-KV fast path: per-row fp32 scale applied in-register,
            # right after the narrow HBM->VMEM DMA (DESIGN.md §12)
            k = k * ks_ref[0, 0]                            # (bk, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                 # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                              # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        if quantized:
            v = v * vs_ref[0, 0]                            # (bk, 1)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    scale: float, causal: bool = True, window: int = -1,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False,
                    k_scale=None, v_scale=None) -> jnp.ndarray:
    """q: (B, Sq, H, D), k/v: (B, Sk, Hkv, D) with H % Hkv == 0.

    ``k_scale``/``v_scale`` (B, Sk, Hkv) fp32 switch on int8-KV mode: k/v
    are int8 codes dequantized tile-by-tile inside the kernel body (pass
    both or neither) — full-precision K/V never round-trip through memory.

    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads otherwise).
    Returns (B, Sq, H, D) in q.dtype.
    """
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "pass both scales or neither"
    nq, nk = sq // block_q, sk // block_k

    # layout: heads-major so each (b, h) pair owns contiguous seq blocks
    qt = q.transpose(0, 2, 1, 3)       # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)       # (B, Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    kv_spec = pl.BlockSpec((1, 1, block_k, d),
                           lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0))
    in_specs = [
        pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        kv_spec,
    ]
    operands = [qt, kt]
    if quantized:
        sc_spec = pl.BlockSpec(
            (1, 1, block_k, 1),
            lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0))
        kst = k_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        vst = v_scale.astype(jnp.float32).transpose(0, 2, 1)[..., None]
        in_specs += [sc_spec, kv_spec, sc_spec]
        operands += [kst, vt, vst]
    else:
        in_specs += [kv_spec]
        operands += [vt]

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k_blocks=nk, quantized=quantized),
        grid=(b, h, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(*operands)
    return out.transpose(0, 2, 1, 3)
