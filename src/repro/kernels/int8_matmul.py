"""Fused int8-weight matmul Pallas TPU kernel (quantized serving fast path).

The paper's PIM argument — per-byte data movement, not FLOPs, bounds edge
inference — maps onto a TPU as: keep weights **int8 in HBM** (4x less DMA
traffic than fp32, 2x less than bf16), widen to the compute dtype
*in-register* after the HBM->VMEM pipe, and apply the per-output-channel
fp32 scale once per (bm, bn) output tile on the VPU. Full-precision weights
never exist in memory; the only wide tensor is the fp32 accumulator tile in
VMEM scratch. Structure mirrors ternary_matmul.py (DESIGN.md §2/§12) with
the sign-plane select generalized to the full int8 code range.

Grid: (M/bm, N/bn, K/bk), K innermost; fp32 accumulator lives in VMEM
scratch across the K sweep. Block sizes default to MXU-aligned 128/256/512.

Validated in interpret mode against a dequantize->matmul oracle
(tests/test_kernels_int8.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_matmul_kernel(x_ref, q_ref, scale_ref, o_ref, acc_ref, *,
                        n_k_blocks: int):
    """One (bm, bn) output tile; program_id(2) sweeps K blocks."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    # in-register dequant: the int8 tile widens to x.dtype on the VPU after
    # the (narrow) HBM->VMEM DMA, then feeds a fp32-accumulating MXU dot.
    q = q_ref[...].astype(x.dtype)
    acc_ref[...] += jax.lax.dot(x, q, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k_blocks - 1)
    def _finish():
        scale = scale_ref[...].astype(jnp.float32)          # (1, bn)
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "out_dtype"))
def int8_matmul(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                block_m: int = 128, block_n: int = 128, block_k: int = 512,
                interpret: bool = False,
                out_dtype=None) -> jnp.ndarray:
    """y[m,n] = (sum_k x[m,k] * q[k,n]) * scale[n], q int8, scale fp32.

    Shapes must be multiples of the block sizes (ops.py pads otherwise).
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2 and scale.shape == (n,), (x.shape, q.shape, scale.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert q.dtype == jnp.int8, q.dtype
    out_dtype = out_dtype or x.dtype
    nk = k // block_k

    return pl.pallas_call(
        functools.partial(_int8_matmul_kernel, n_k_blocks=nk),
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, q, scale.reshape(1, n))
