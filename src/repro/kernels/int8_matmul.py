"""Fused int8-weight matmul Pallas TPU kernel (quantized serving fast path).

The paper's PIM argument — per-byte data movement, not FLOPs, bounds edge
inference — maps onto a TPU as: keep weights **int8 in HBM** (4x less DMA
traffic than fp32, 2x less than bf16), widen to the compute dtype
*in-register* after the HBM->VMEM pipe, and apply the per-output-channel
fp32 scale once per (bm, bn) output tile on the VPU. Full-precision weights
never exist in memory; the only wide tensor is the fp32 accumulator tile in
VMEM scratch. Structure mirrors ternary_matmul.py (DESIGN.md §2/§12) with
the sign-plane select generalized to the full int8 code range.

Grid: (M/bm, N/bn, K/bk), K innermost; fp32 accumulator lives in VMEM
scratch across the K sweep. Block sizes default to MXU-aligned 128/256/512.

Validated in interpret mode against a dequantize->matmul oracle
(tests/test_kernels_int8.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_matmul_kernel(x_ref, q_ref, scale_ref, o_ref, acc_ref, *,
                        n_k_blocks: int):
    """One (bm, bn) output tile; program_id(2) sweeps K blocks."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    # in-register dequant: the int8 tile widens to x.dtype on the VPU after
    # the (narrow) HBM->VMEM DMA, then feeds a fp32-accumulating MXU dot.
    q = q_ref[...].astype(x.dtype)
    acc_ref[...] += jax.lax.dot(x, q, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k_blocks - 1)
    def _finish():
        scale = scale_ref[...].astype(jnp.float32)          # (1, bn)
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "out_dtype"))
def int8_matmul(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                block_m: int = 128, block_n: int = 128, block_k: int = 512,
                interpret: bool = False,
                out_dtype=None) -> jnp.ndarray:
    """y[m,n] = (sum_k x[m,k] * q[k,n]) * scale[n], q int8, scale fp32.

    Shapes must be multiples of the block sizes (ops.py pads otherwise).
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2 and scale.shape == (n,), (x.shape, q.shape, scale.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert q.dtype == jnp.int8, q.dtype
    out_dtype = out_dtype or x.dtype
    nk = k // block_k

    return pl.pallas_call(
        functools.partial(_int8_matmul_kernel, n_k_blocks=nk),
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, q, scale.reshape(1, n))


# ---------------------------------------------------------------------------
# Training fast path: custom VJP (DESIGN.md §13)
#
# The backward wrt the activations is itself a fused kernel: dx = dy' @ q^T
# with the per-channel scale folded into dy in-register (dy' = dy * scale)
# and the int8 weight tile dequantized *inside the kernel body* — the
# transposed weight never exists in fp in memory, the only narrow->wide
# widening happens after the HBM->VMEM DMA, exactly like the forward.
#
# q is frozen int8 (its cotangent is float0 — quantized-weight training
# updates the fp32 master copy through the straight-through estimator at the
# call site); scale gets a real gradient, recovered from the saved forward
# output: dscale[n] = sum_m dy[m,n] * y[m,n] / scale[n].
# ---------------------------------------------------------------------------


def _int8_bwd_dx_kernel(dy_ref, q_ref, scale_ref, dx_ref, acc_ref, *,
                        n_n_blocks: int):
    """One (bm, bk) dx tile; program_id(2) sweeps N blocks."""
    n_idx = pl.program_id(2)

    @pl.when(n_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # fold the per-channel scale into the cotangent (VPU), dequantize the
    # int8 weight tile in-register, contract over the shared N axis (MXU)
    g = dy_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    w = q_ref[...].astype(jnp.float32)                       # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        g, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(n_idx == n_n_blocks - 1)
    def _finish():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "out_dtype"))
def int8_matmul_dx(dy: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                   block_m: int = 128, block_n: int = 128, block_k: int = 512,
                   interpret: bool = False, out_dtype=None) -> jnp.ndarray:
    """dx[m,k] = sum_n dy[m,n] * scale[n] * q[k,n] — the int8 backward."""
    m, n = dy.shape
    k, n2 = q.shape
    assert n == n2 and scale.shape == (n,), (dy.shape, q.shape, scale.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    out_dtype = out_dtype or dy.dtype
    nn = n // block_n

    return pl.pallas_call(
        functools.partial(_int8_bwd_dx_kernel, n_n_blocks=nn),
        grid=(m // block_m, k // block_k, nn),
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, ni: (i, ni)),
            pl.BlockSpec((block_k, block_n), lambda i, j, ni: (j, ni)),
            pl.BlockSpec((1, block_n), lambda i, j, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_k), lambda i, j, ni: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_k), jnp.float32)],
        interpret=interpret,
    )(dy, q, scale.reshape(1, n))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def int8_matmul_vjp(x, q, scale, statics):
    """Differentiable int8 matmul. ``statics`` is the hashable tuple
    (block_m, block_n, block_k, interpret, x_dtype_name); shapes must be
    block multiples (ops.int8_matmul_train pads). The forward output is
    fp32 so the dscale residual stays exact."""
    block_m, block_n, block_k, interpret, _ = statics
    return int8_matmul(x, q, scale, block_m=block_m, block_n=block_n,
                       block_k=block_k, interpret=interpret,
                       out_dtype=jnp.float32)


def _int8_vjp_fwd(x, q, scale, statics):
    y = int8_matmul_vjp(x, q, scale, statics)
    return y, (q, scale, y)


def _int8_vjp_bwd(statics, res, dy):
    block_m, block_n, block_k, interpret, x_dtype = statics
    q, scale, y = res
    dy32 = dy.astype(jnp.float32)
    dx = int8_matmul_dx(dy32, q, scale, block_m=block_m, block_n=block_n,
                        block_k=block_k, interpret=interpret,
                        out_dtype=jnp.dtype(x_dtype))
    # y = acc * scale  =>  dscale[n] = sum_m dy[m,n] * acc[m,n]
    #                               = sum_m dy[m,n] * y[m,n] / scale[n]
    safe = jnp.where(scale == 0, 1.0, scale)
    dscale = (jnp.sum(dy32 * y, axis=0) / safe).astype(scale.dtype)
    dq = np.zeros(q.shape, dtype=jax.dtypes.float0)   # frozen int8 codes
    return dx, dq, dscale


int8_matmul_vjp.defvjp(_int8_vjp_fwd, _int8_vjp_bwd)
