"""Jit'd public wrappers for the Pallas kernels.

Handle padding to block multiples, GQA head grouping, dtype policy, and the
CPU fallback: on non-TPU backends the kernels execute in Pallas interpret
mode (bit-accurate kernel-body semantics, Python-speed) — use
``force_interpret=False`` + a TPU runtime for production.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import int8_matmul as _im
from repro.kernels import ternary_matmul as _tm
from repro.kernels import ref as _ref
from repro.quant.ternary import TernaryWeight


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _tiled_matmul_call(kernel, x: jnp.ndarray, q: jnp.ndarray,
                       scale: jnp.ndarray, block_m: int, block_n: int,
                       block_k: int, interpret: bool) -> jnp.ndarray:
    """Shared pad-and-launch wrapper for the quantized matmul kernels:
    flattens leading dims, derives a sublane-aligned small-batch M tile,
    pads every operand to block multiples, and slices the result back."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = q.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    # small-batch inference tiles, kept sublane-aligned (multiples of 8)
    bm = min(block_m, max(8, -(-m // 8) * 8))
    x2 = _pad_to(_pad_to(x2, 0, bm), 1, block_k)
    qp = _pad_to(_pad_to(q, 0, block_k), 1, block_n)
    sp = _pad_to(scale, 0, block_n)
    y = kernel(x2, qp, sp, block_m=bm, block_n=block_n, block_k=block_k,
               interpret=interpret, out_dtype=x.dtype)
    return y[:m, :n].reshape(*lead, n)


def ternary_matmul(x: jnp.ndarray, w: TernaryWeight, *,
                   block_m: int = 128, block_n: int = 128, block_k: int = 512,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """x: (..., K) @ ternary weight (K, N) -> (..., N)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _tiled_matmul_call(_tm.ternary_matmul, x, w.q,
                              w.scale.reshape(-1), block_m, block_n,
                              block_k, interpret)


def ternary_dense(x: jnp.ndarray, w: TernaryWeight, bias=None, **kw) -> jnp.ndarray:
    y = ternary_matmul(x, w, **kw)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def int8_matmul(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                block_m: int = 128, block_n: int = 128, block_k: int = 512,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """x: (..., K) @ int8 weight (K, N) with per-channel scale -> (..., N).

    ``scale`` may be () per-tensor, (N,) per-channel, or any keepdims shape
    broadcastable to (1, N) (quant.int8.quantize_weight's ``s8``).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    n = q.shape[1]
    sc = jnp.broadcast_to(scale.astype(jnp.float32).reshape(-1, n)
                          if scale.ndim else scale.astype(jnp.float32),
                          (1, n)).reshape(n)
    return _tiled_matmul_call(_im.int8_matmul, x, q, sc, block_m, block_n,
                              block_k, interpret)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    scale: Optional[float] = None, causal: bool = True,
                    window: int = -1, block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None,
                    k_scale: Optional[jnp.ndarray] = None,
                    v_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Padded/GQA-aware flash attention. q (B,Sq,H,D), k/v (B,Sk,Hkv,D).

    ``k_scale``/``v_scale`` (B, Sk, Hkv) enable int8-KV mode (k/v int8
    codes, dequantized inside the kernel body).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    # block sizes: the requested block, shrunk to the (pow2, <=128) envelope
    # of the actual sequence so short sequences get one small block
    bq = min(block_q, _round_up_pow2(sq))
    bk = min(block_k, _round_up_pow2(sk))
    # Padded keys sit at positions >= sk. Causal masking hides them from
    # every real query iff sq <= sk; otherwise (non-causal, or causal with
    # q positions past sk) they would be attended — dispatch to the reference
    # path BEFORE launching the kernel (these ragged encoder shapes are small).
    assert (k_scale is None) == (v_scale is None), \
        "pass both KV scales or neither"
    if (-sk) % bk != 0 and (not causal or sq > sk):
        if k_scale is not None:
            from repro.quant.int8 import dequantize_rowwise
            k = dequantize_rowwise(k, k_scale, dtype=q.dtype)
            v = dequantize_rowwise(v, v_scale, dtype=q.dtype)
        return _ref.attention_ref(q, k, v, scale=scale, causal=causal,
                                  window=window)
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    if k_scale is not None:
        k_scale = _pad_to(k_scale, 1, bk)
        v_scale = _pad_to(v_scale, 1, bk)
    out = _fa.flash_attention(qp, kp, vp, scale=scale, causal=causal,
                              window=window, block_q=bq, block_k=bk,
                              interpret=interpret,
                              k_scale=k_scale, v_scale=v_scale)
    return out[:, :sq]


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, *, scale: Optional[float] = None,
                     window: int = -1, block_k: int = 128,
                     interpret: Optional[bool] = None,
                     k_scale: Optional[jnp.ndarray] = None,
                     v_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Serve-core decode attention with per-slot lengths.

    q: (B, H, D) — the one new token per slot; k/v: (B, Sk, Hkv, D) slot-major
    KV cache; lengths: (B,) valid prefix per slot (0 = dead slot -> zeros).
    ``k_scale``/``v_scale`` (B, Sk, Hkv) enable the int8-KV cache mode: k/v
    are int8 codes dequantized inside the kernel body (DESIGN.md §12).
    Pads Sk up to a block multiple; padded keys sit past every length so the
    kernel's length test masks them.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    d = q.shape[-1]
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    bk = min(block_k, _round_up_pow2(sk))
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    assert (k_scale is None) == (v_scale is None), \
        "pass both KV scales or neither"
    if k_scale is not None:
        k_scale = _pad_to(k_scale, 1, bk)
        v_scale = _pad_to(v_scale, 1, bk)
    return _da.decode_attention(q, kp, vp, lengths, scale=scale,
                                window=window, block_k=bk,
                                interpret=interpret,
                                k_scale=k_scale, v_scale=v_scale)


def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *,
                           scale: Optional[float] = None, window: int = -1,
                           interpret: Optional[bool] = None,
                           k_scale: Optional[jnp.ndarray] = None,
                           v_scale: Optional[jnp.ndarray] = None
                           ) -> jnp.ndarray:
    """Serve-core decode attention through a paged KV pool (DESIGN.md §14).

    q: (B, H, D) — the one new token per slot; k_pool/v_pool:
    (P, page_size, Hkv, D) shared block pool; page_table: (B, NB) int32
    (entries past a slot's length must be in-bounds — the engine points
    them at the sink page); lengths: (B,) valid logical prefix per slot.
    ``k_scale``/``v_scale`` (P, page_size, Hkv) enable the int8-KV mode.

    No padding is needed: the pool's page dimension is the block unit, and
    the table indirection replaces the dense kernel's contiguous K sweep.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    assert (k_scale is None) == (v_scale is None), \
        "pass both KV scales or neither"
    return _da.paged_decode_attention(q, k_pool, v_pool, page_table, lengths,
                                      scale=scale, window=window,
                                      interpret=interpret,
                                      k_scale=k_scale, v_scale=v_scale)


def paged_verify_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *,
                           scale: Optional[float] = None, window: int = -1,
                           interpret: Optional[bool] = None,
                           k_scale: Optional[jnp.ndarray] = None,
                           v_scale: Optional[jnp.ndarray] = None
                           ) -> jnp.ndarray:
    """Speculative-verify attention through a paged KV pool (DESIGN.md §15).

    q: (B, T, H, D) — T query tokens per slot (the committed pending token
    + the drafts), already written into the pool at logical positions
    ``lengths - T + t``; lengths: (B,) valid prefix per slot INCLUDING the
    T chunk tokens. Causal within the chunk: lane t attends positions
    ``<= lengths - T + t``. ``k_scale``/``v_scale`` (P, page_size, Hkv)
    enable the int8-KV mode. Like the single-token paged kernel, no
    padding is needed — pages are the block unit.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    assert (k_scale is None) == (v_scale is None), \
        "pass both KV scales or neither"
    return _da.paged_verify_attention(q, k_pool, v_pool, page_table, lengths,
                                      scale=scale, window=window,
                                      interpret=interpret,
                                      k_scale=k_scale, v_scale=v_scale)


def paged_prefill_attention(q: jnp.ndarray, k_new: jnp.ndarray,
                            v_new: jnp.ndarray, k_pool: jnp.ndarray,
                            v_pool: jnp.ndarray, page_table: jnp.ndarray,
                            starts: jnp.ndarray, lens: jnp.ndarray, *,
                            scale: Optional[float] = None, window: int = -1,
                            block_q: int = 128,
                            interpret: Optional[bool] = None,
                            k_scale: Optional[jnp.ndarray] = None,
                            v_scale: Optional[jnp.ndarray] = None
                            ) -> jnp.ndarray:
    """Chunk-prefill attention through a paged KV pool (DESIGN.md §16).

    q: (B, C, H, D) chunk queries; k_new/v_new: (B, C, Hkv, D) the chunk's
    full-precision K/V (in-chunk attention sees these — dense-prefill
    numerics); k_pool/v_pool: (P, page_size, Hkv, D) the shared block pool
    holding the cached prefix [0, starts[b]) (read in storage dtype —
    decode numerics); page_table: (B, NB) int32 (out-of-chain entries must
    point at the sink page); starts/lens: (B,) cached-prefix length and
    valid chunk tokens per row (lens 0 = dead row). ``k_scale``/``v_scale``
    (P, page_size, Hkv) enable int8-KV in-kernel dequant.

    The page gather is the DMA: the scalar-prefetched table resolves each
    K/V tile's pool page in the BlockSpec index_map, and pages past the
    cached window collapse onto the last needed one — per-row gather
    traffic is ceil(start/page_size) pages, independent of how fragmented
    the chain is. Pads C up to a ``block_q`` multiple; rows past ``lens``
    return garbage (the caller's padding contract, same as paged_extend).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, c, h, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    assert (k_scale is None) == (v_scale is None), \
        "pass both KV scales or neither"
    bq = min(block_q, _round_up_pow2(c))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k_new, 1, bq)
    vp = _pad_to(v_new, 1, bq)
    out = _fa.paged_prefill_attention(qp, kp, vp, k_pool, v_pool, page_table,
                                      starts, lens, scale=scale,
                                      window=window, block_q=bq,
                                      interpret=interpret,
                                      k_scale=k_scale, v_scale=v_scale)
    return out[:, :c]


def _round_up_pow2(n: int) -> int:
    p = 8
    while p < n and p < 128:
        p *= 2
    return p


# -----------------------------------------------------------------------------
# Training fast path: differentiable wrappers (custom-VJP kernels, §13)
# -----------------------------------------------------------------------------

def flash_attention_train(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                          scale: Optional[float] = None, causal: bool = True,
                          window: int = -1, block_q: int = 128,
                          block_k: int = 128,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Differentiable flash attention (custom-VJP Pallas kernels).

    Same shapes/semantics as :func:`flash_attention`, but ``jax.grad``
    through it runs the fused backward kernels (recompute-from-lse; no
    O(Sq*Sk) probability tensor) instead of failing on the pallas_call.
    Padding/slicing here is plain jnp, so its VJP composes with the kernel's.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, _round_up_pow2(sq))
    bk = min(block_k, _round_up_pow2(sk))
    # same ragged-shape escape as the inference wrapper: padded keys are
    # only hidden by causal masking when sq <= sk
    if (-sk) % bk != 0 and (not causal or sq > sk):
        return _ref.attention_ref(q, k, v, scale=scale, causal=causal,
                                  window=window)
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    statics = (float(scale), bool(causal), int(window), bq, bk,
               bool(interpret))
    out = _fa.flash_attention_vjp(qp, kp, vp, statics)
    return out[:, :sq]


def int8_matmul_train(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 512,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Differentiable int8 matmul: dx runs the fused in-kernel-dequant
    backward, dscale is recovered from the saved fp32 forward output, and
    the int8 codes are frozen (float0 cotangent — pair with an STE at the
    call site for quantization-aware training). Returns x.dtype."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = q.shape[-1]
    sc = jnp.broadcast_to(scale.astype(jnp.float32).reshape(-1, n)
                          if scale.ndim else scale.astype(jnp.float32),
                          (1, n)).reshape(n)
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    bm = min(block_m, max(8, -(-m // 8) * 8))
    x2 = _pad_to(_pad_to(x2, 0, bm), 1, block_k)
    qp = _pad_to(_pad_to(q, 0, block_k), 1, block_n)
    # pad scale with ones, not zeros: the dscale residual divides by it
    sp = _pad_to(sc, 0, block_n, value=1.0)
    statics = (bm, block_n, block_k, bool(interpret), jnp.dtype(x.dtype).name)
    y = _im.int8_matmul_vjp(x2, qp, sp, statics)
    return y[:m, :n].reshape(*lead, n).astype(x.dtype)


def attention_auto(q, k, v, *, scale=None, causal=True, window=-1,
                   use_flash: bool = True):
    """Dispatch: flash kernel on TPU / interpret-validated path, else oracle."""
    if use_flash:
        return flash_attention(q, k, v, scale=scale, causal=causal, window=window)
    return _ref.attention_ref(q, k, v, scale=scale or q.shape[-1] ** -0.5,
                              causal=causal, window=window)
