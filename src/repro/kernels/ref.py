"""Pure-jnp oracles for the Pallas kernels (the correctness contracts)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ternary_matmul_ref(x: jnp.ndarray, q: jnp.ndarray,
                       scale: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """y = (x @ q) * scale with q int8 {-1,0,1}, fp32 accumulation."""
    out_dtype = out_dtype or x.dtype
    acc = jnp.dot(x.astype(jnp.float32), q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * scale.astype(jnp.float32)).astype(out_dtype)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  scale: float, causal: bool = True,
                  window: int = -1) -> jnp.ndarray:
    """Naive softmax attention with GQA/causal/window semantics matching the
    flash kernel. q: (B,Sq,H,D), k/v: (B,Sk,Hkv,D)."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bqhrd,bnhd->bhrqn", qg * scale, kf)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    diff = q_pos - k_pos
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqn,bnhd->bqhrd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
