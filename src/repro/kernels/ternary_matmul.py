"""PIM-adapted ternary matmul Pallas TPU kernel.

The paper's inference engine (PIRM/ELP^2IM) computes ternary CNN inference
multiplication-free with bulk bit-line operations inside the memory array.
The TPU-native adaptation (DESIGN.md §2):

* weights stay **int8 {-1,0,+1}** in HBM — 2x less DMA traffic than bf16 and
  4x less than fp32: the PIM "compute where the data lives" insight becomes
  "move 4x fewer bytes through the HBM->VMEM pipe" on a TPU, which is exactly
  what bounds batch-1..32 inference;
* the multiply-free accumulation maps onto the MXU with an in-VMEM sign-plane
  dequant (a select, not a multiply) feeding a fp32-accumulating dot — on a
  systolic array the ±1 dot *is* the add/subtract network PIM builds on
  bit-lines;
* per-output-channel scales are applied once per (bm, bn) tile on the VPU.

Grid: (M/bm, N/bn, K/bk), K innermost; fp32 accumulator lives in VMEM scratch
across the K sweep. Block sizes default to MXU-aligned 128/256/512.

Validated in interpret mode against ref.ternary_matmul_ref over a
shape x dtype sweep (tests/test_kernels_ternary.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ternary_matmul_kernel(x_ref, q_ref, scale_ref, o_ref, acc_ref, *,
                           n_k_blocks: int):
    """One (bm, bn) output tile; program_id(2) sweeps K blocks."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    # sign-plane dequant: int8 {-1,0,1} -> x.dtype via select network (VPU),
    # then a fp32-accumulating MXU dot.
    q = q_ref[...].astype(x.dtype)
    acc_ref[...] += jax.lax.dot(x, q, preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k_blocks - 1)
    def _finish():
        scale = scale_ref[...].astype(jnp.float32)          # (1, bn)
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "out_dtype"))
def ternary_matmul(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray, *,
                   block_m: int = 128, block_n: int = 128, block_k: int = 512,
                   interpret: bool = False,
                   out_dtype=None) -> jnp.ndarray:
    """y[m,n] = (sum_k x[m,k] * q[k,n]) * scale[n], q in int8 {-1,0,1}.

    Shapes must be multiples of the block sizes (ops.py pads otherwise).
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2 and scale.shape == (n,), (x.shape, q.shape, scale.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    out_dtype = out_dtype or x.dtype
    nk = k // block_k

    return pl.pallas_call(
        functools.partial(_ternary_matmul_kernel, n_k_blocks=nk),
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, q, scale.reshape(1, n))
