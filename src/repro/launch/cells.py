"""Cell builder: (architecture x input-shape x mesh) -> lowerable step.

A *cell* packages everything the dry-run and the roofline need:
  * the step function (train_step / prefill_step / decode_step),
  * ShapeDtypeStruct stand-ins for every input (weak-type-correct, shardable,
    zero allocation),
  * in/out shardings derived from the logical-axes trees via
    parallel.sharding (with the long-context rule override for batch=1),
  * MODEL_FLOPS accounting inputs (param counts, tokens/step).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib
from repro.models import common as mcommon
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.optim.adamw import opt_state_axes
from repro.parallel import sharding as sh
from repro.parallel.ctx import activation_sharding

PyTree = Any

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16

_KV_DTYPES = {"bf16": jnp.bfloat16, "fp8": jnp.float8_e4m3fn}


def _cache_dtype(cfg) -> Any:
    return _KV_DTYPES[getattr(cfg, "kv_cache_dtype", "bf16")]


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str                        # train | prefill | decode
    step_fn: Callable
    args_sds: Tuple                  # ShapeDtypeStructs, positional
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    n_params_total: float
    n_params_active: float
    tokens_per_step: float
    rules: Any = None
    notes: str = ""
    # analytic live-HBM estimate (bytes/device): args + remat-saved carries +
    # workspace. The CPU-backend temp_size stores scan saves in fp32 (a
    # layout artifact the TPU pipeline elides — EXPERIMENTS.md §Dry-run), so
    # fits-HBM is judged on this as well as the raw CPU temp.
    analytic_live_bytes: float = 0.0

    def lower(self, mesh: Mesh):
        rules = self.rules or sh.DEFAULT_RULES
        with mesh, activation_sharding(mesh, rules):
            jitted = jax.jit(self.step_fn,
                             in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings,
                             donate_argnums=self.donate_argnums)
            return jitted.lower(*self.args_sds)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shardify(tree_sds: PyTree, axes_tree: PyTree, mesh: Mesh, rules) -> PyTree:
    return sh.shardings_for_tree(tree_sds, axes_tree, mesh, rules)


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _live_bytes_estimate(mesh: Mesh, *, kind: str, n_params: float,
                         n_layers: int, d_model: int, tokens: float,
                         opt_bytes_per_param: float = 4.0,
                         cache_bytes: float = 0.0) -> float:
    """Per-device live-HBM estimate: params(+grads) + optimizer + bf16
    remat-saved carries + 2 GB workspace."""
    n_model = mesh.shape.get("model", 1)
    n_dev = int(np.prod(list(mesh.shape.values())))
    params_dev = n_params * 2.0 / n_model            # bf16, TP-sharded
    if kind == "train":
        opt_dev = n_params * opt_bytes_per_param / n_model
        grads_dev = params_dev                        # bf16 grads
        tokens_dev = tokens / max(n_dev // n_model, 1)
        carries_dev = n_layers * tokens_dev * d_model * 2.0
        return params_dev + opt_dev + grads_dev + carries_dev + 2e9
    return params_dev + cache_bytes / n_dev + 2e9


def _adamw_for(arch: cfgbase.ArchSpec) -> AdamWConfig:
    # memory-lean fleet default: bf16 moments, no fp32 master. kimi-k2 (1T)
    # additionally drops to int8 moments (DESIGN.md §10 / configs note).
    state = "int8" if arch.params_nominal >= 5e11 else "bf16"
    return AdamWConfig(lr=3e-4, state_dtype=state, use_master=False,
                       grad_clip=1.0)


# -----------------------------------------------------------------------------
# LM cells
# -----------------------------------------------------------------------------

def _lm_batch_sds(cfg: tf_lib.LMConfig, shape: cfgbase.ShapeSpec,
                  for_train: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if for_train:
        batch["labels"] = _sds((b, s), jnp.int32)
    if cfg.pos_emb == "mrope":
        batch["positions"] = _sds((b, s, 3), jnp.int32)
    if cfg.vision_tokens > 0:
        batch["vision_embeds"] = _sds((b, cfg.vision_tokens, cfg.d_model),
                                      PARAM_DTYPE)
    return batch


def _lm_batch_axes(cfg: tf_lib.LMConfig, for_train: bool) -> Dict[str, tuple]:
    axes = {"tokens": ("batch", "seq")}
    if for_train:
        axes["labels"] = ("batch", "seq")
    if cfg.pos_emb == "mrope":
        axes["positions"] = ("batch", "seq", None)
    if cfg.vision_tokens > 0:
        axes["vision_embeds"] = ("batch", None, "embed")
    return axes


def build_lm_cell(arch: cfgbase.ArchSpec, shape: cfgbase.ShapeSpec,
                  mesh: Mesh, *, overrides: Optional[dict] = None) -> Cell:
    cfg: tf_lib.LMConfig = arch.make_config()
    overrides = dict(overrides or {})
    # "_fsdp": ZeRO-3-style weight/optimizer sharding over the DP axes in
    # ADDITION to TP (per-layer all-gathers traded for fitting HBM) — §Perf
    fsdp = overrides.pop("_fsdp", False)
    # "_weights_int8": serve linear weights int8 (paper C5; §Perf HC-C iter 3)
    w8 = overrides.pop("_weights_int8", False)
    overrides_flags = {"kv_seq_shard": overrides.pop("_kv_seq_shard", False)}
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rules = (sh.LONG_CONTEXT_RULES if shape.global_batch == 1
             else sh.DEFAULT_RULES)
    param_rules = dict(rules)
    if fsdp:
        param_rules["embed"] = ("pod", "data")

    params_ax = jax.eval_shape(partial(tf_lib.init_lm, cfg=cfg,
                                       dtype=PARAM_DTYPE),
                               jax.random.PRNGKey(0))
    params_sds, params_axes = params_ax.params, params_ax.axes
    n_params = sum(float(np.prod(x.shape)) for x in jax.tree.leaves(params_sds))
    if w8:
        from repro.quant.int8 import quantize_params_for_serving
        params_sds, params_axes = quantize_params_for_serving(
            params_sds, params_axes)
    param_shardings = _shardify(params_sds, params_axes, mesh, param_rules)
    n_active = _active_params(arch, cfg, n_params)

    if shape.kind == "train":
        opt_cfg = _adamw_for(arch)
        opt_sds = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg),
                                 params_sds)
        opt_axes = opt_state_axes(params_axes, opt_cfg)
        opt_shardings = _shardify(opt_sds, opt_axes, mesh, param_rules)
        batch_sds = _lm_batch_sds(cfg, shape, True)
        batch_ax = _lm_batch_axes(cfg, True)
        batch_shardings = _shardify(batch_sds, batch_ax, mesh, rules)

        def train_step(params, opt_state, batch):
            def loss(p):
                return tf_lib.loss_fn(p, cfg, batch)
            (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
            # pin gradients to the PARAM shardings in the param dtype —
            # without this the partitioner reshards fp32 grad accumulations
            # inside the backward loop (measured ~0.9 TB/dev of fp32 grad
            # AR/AG on qwen1.5-110b; §Perf HC-B iter 4)
            grads = jax.tree.map(
                lambda g, pa, sh_: jax.lax.with_sharding_constraint(
                    g.astype(pa.dtype), sh_),
                grads, params, param_shardings)
            new_p, new_s, om = apply_updates(params, grads, opt_state, opt_cfg)
            return new_p, new_s, {"loss": l, **om}

        return Cell(
            arch_id=arch.arch_id, shape_name=shape.name, kind="train",
            step_fn=train_step,
            args_sds=(params_sds, opt_sds, batch_sds),
            in_shardings=(param_shardings, opt_shardings, batch_shardings),
            out_shardings=(param_shardings, opt_shardings, None),
            donate_argnums=(0, 1),
            n_params_total=n_params, n_params_active=n_active,
            tokens_per_step=shape.global_batch * shape.seq_len,
            rules=rules,
            analytic_live_bytes=_live_bytes_estimate(
                mesh, kind="train", n_params=n_params,
                n_layers=cfg.n_layers, d_model=cfg.d_model,
                tokens=shape.global_batch * shape.seq_len,
                opt_bytes_per_param=(2.0 if opt_cfg.state_dtype == "int8"
                                     else 4.0)),
        )

    if shape.kind == "prefill":
        batch_sds = _lm_batch_sds(cfg, shape, False)
        batch_ax = _lm_batch_axes(cfg, False)
        batch_shardings = _shardify(batch_sds, batch_ax, mesh, rules)
        kv_dtype = _cache_dtype(cfg)
        caches_sds = jax.eval_shape(
            partial(tf_lib.init_caches, cfg, shape.global_batch,
                    shape.seq_len, kv_dtype))
        cache_shardings = _shardify(caches_sds, tf_lib.caches_axes(cfg),
                                    mesh, rules)

        def prefill_step(params, batch):
            logits, caches = tf_lib.prefill(
                params, cfg, batch["tokens"],
                max_len=shape.seq_len,
                vision_embeds=batch.get("vision_embeds"),
                cache_dtype=kv_dtype)
            return logits, caches

        return Cell(
            arch_id=arch.arch_id, shape_name=shape.name, kind="prefill",
            step_fn=prefill_step,
            args_sds=(params_sds, batch_sds),
            in_shardings=(param_shardings, batch_shardings),
            out_shardings=(None, cache_shardings),
            donate_argnums=(),
            n_params_total=n_params, n_params_active=n_active,
            tokens_per_step=shape.global_batch * shape.seq_len,
            rules=rules,
            analytic_live_bytes=_live_bytes_estimate(
                mesh, kind="prefill", n_params=n_params,
                n_layers=cfg.n_layers, d_model=cfg.d_model,
                tokens=shape.global_batch * shape.seq_len,
                cache_bytes=sum(float(np.prod(x.shape)) * x.dtype.itemsize
                                for x in jax.tree.leaves(caches_sds))),
        )

    # decode
    # "_kv_seq_shard": flash-decoding style — shard KV caches on SEQ over the
    # model axis (softmax reductions psum tiny partials) instead of head_dim
    # (which psums full per-layer logits for MQA/low-kv archs); §Perf extra
    kv_seq = overrides_flags.get("kv_seq_shard", False)
    cache_rules = dict(rules, seq="model") if kv_seq else rules
    caches_sds = jax.eval_shape(
        partial(tf_lib.init_caches, cfg, shape.global_batch, shape.seq_len,
                _cache_dtype(cfg)))
    cache_shardings = _shardify(caches_sds, tf_lib.caches_axes(cfg), mesh,
                                cache_rules)
    token_sds = _sds((shape.global_batch, 1), jnp.int32)
    pos_sds = _sds((), jnp.int32)
    tok_spec = sh.spec_for((shape.global_batch, 1), ("batch", "seq"), mesh, rules)

    def decode(params, token, pos, caches):
        return tf_lib.decode_step(params, cfg, token, pos, caches)

    return Cell(
        arch_id=arch.arch_id, shape_name=shape.name, kind="decode",
        step_fn=decode,
        args_sds=(params_sds, token_sds, pos_sds, caches_sds),
        in_shardings=(param_shardings, _ns(mesh, tok_spec), _ns(mesh, P()),
                      cache_shardings),
        out_shardings=(None, cache_shardings),
        donate_argnums=(3,),
        n_params_total=n_params, n_params_active=n_active,
        tokens_per_step=shape.global_batch,
        rules=rules,
    )


def _active_params(arch: cfgbase.ArchSpec, cfg, n_params: float) -> float:
    if arch.family != "moe":
        return n_params
    # experts contribute active_fraction; everything else fully active
    moe = cfg.moe_cfg
    expert_params = (cfg.repeats * len(cfg.pattern) * moe.n_experts
                     * 3 * moe.d_model * moe.d_ff)
    return n_params - expert_params * (1.0 - arch.active_fraction)


# -----------------------------------------------------------------------------
# Enc-dec (whisper) cells
# -----------------------------------------------------------------------------

_ENC_CACHE_AXES = {
    "self": {"k": ("stack", "batch", "seq", "heads", "head_dim"),
             "v": ("stack", "batch", "seq", "heads", "head_dim")},
    "cross": {"k": ("stack", "batch", "seq", "heads", "head_dim"),
              "v": ("stack", "batch", "seq", "heads", "head_dim")},
}


def build_encdec_cell(arch: cfgbase.ArchSpec, shape: cfgbase.ShapeSpec,
                      mesh: Mesh, *, overrides: Optional[dict] = None) -> Cell:
    cfg: encdec_lib.EncDecConfig = arch.make_config()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rules = (sh.LONG_CONTEXT_RULES if shape.global_batch == 1
             else sh.DEFAULT_RULES)
    params_ax = jax.eval_shape(
        partial(encdec_lib.init_encdec, cfg=cfg, dtype=PARAM_DTYPE),
        jax.random.PRNGKey(0))
    params_sds, params_axes = params_ax.params, params_ax.axes
    param_shardings = _shardify(params_sds, params_axes, mesh, rules)
    n_params = sum(float(np.prod(x.shape)) for x in jax.tree.leaves(params_sds))
    b, s = shape.global_batch, shape.seq_len

    frames_sds = _sds((b, cfg.n_audio_ctx, cfg.d_model), PARAM_DTYPE)
    frames_spec = sh.spec_for((b, cfg.n_audio_ctx, cfg.d_model),
                              ("batch", None, "embed"), mesh, rules)

    if shape.kind == "train":
        opt_cfg = _adamw_for(arch)
        opt_sds = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), params_sds)
        opt_shardings = _shardify(opt_sds, opt_state_axes(params_axes, opt_cfg),
                                  mesh, rules)
        batch_sds = {"frames": frames_sds,
                     "tokens": _sds((b, s), jnp.int32),
                     "labels": _sds((b, s), jnp.int32)}
        tok_spec = sh.spec_for((b, s), ("batch", "seq"), mesh, rules)
        batch_shardings = {"frames": _ns(mesh, frames_spec),
                           "tokens": _ns(mesh, tok_spec),
                           "labels": _ns(mesh, tok_spec)}

        def train_step(params, opt_state, batch):
            def loss(p):
                return encdec_lib.loss_fn(p, cfg, batch)
            (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
            new_p, new_s, om = apply_updates(params, grads, opt_state, opt_cfg)
            return new_p, new_s, {"loss": l, **om}

        return Cell(arch.arch_id, shape.name, "train", train_step,
                    (params_sds, opt_sds, batch_sds),
                    (param_shardings, opt_shardings, batch_shardings),
                    (param_shardings, opt_shardings, None), (0, 1),
                    n_params, n_params, b * s, rules=rules)

    if shape.kind == "prefill":
        batch_sds = {"frames": frames_sds, "tokens": _sds((b, s), jnp.int32)}
        tok_spec = sh.spec_for((b, s), ("batch", "seq"), mesh, rules)
        batch_shardings = {"frames": _ns(mesh, frames_spec),
                           "tokens": _ns(mesh, tok_spec)}

        def prefill_step(params, batch):
            enc_out = encdec_lib.encode(params, cfg, batch["frames"])
            logits = encdec_lib.decode_train(params, cfg, batch["tokens"],
                                             enc_out)
            return logits[:, -1:]

        return Cell(arch.arch_id, shape.name, "prefill", prefill_step,
                    (params_sds, batch_sds),
                    (param_shardings, batch_shardings), None, (),
                    n_params, n_params, b * s, rules=rules)

    # decode
    caches_sds = jax.eval_shape(
        partial(encdec_lib.init_dec_caches, cfg, b, s, CACHE_DTYPE))
    cache_shardings = _shardify(caches_sds, _ENC_CACHE_AXES, mesh, rules)
    token_sds = _sds((b, 1), jnp.int32)
    tok_spec = sh.spec_for((b, 1), ("batch", "seq"), mesh, rules)

    def decode(params, token, pos, caches):
        return encdec_lib.decode_step(params, cfg, token, pos, caches)

    return Cell(arch.arch_id, shape.name, "decode", decode,
                (params_sds, token_sds, _sds((), jnp.int32), caches_sds),
                (param_shardings, _ns(mesh, tok_spec), _ns(mesh, P()),
                 cache_shardings),
                (None, cache_shardings), (3,),
                n_params, n_params, b, rules=rules,
                analytic_live_bytes=_live_bytes_estimate(
                    mesh, kind="decode", n_params=n_params,
                    n_layers=cfg.n_layers, d_model=cfg.d_model,
                    tokens=shape.global_batch,
                    cache_bytes=sum(float(np.prod(x.shape)) * x.dtype.itemsize
                                    for x in jax.tree.leaves(caches_sds))))


# -----------------------------------------------------------------------------
# public entry
# -----------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               overrides: Optional[dict] = None) -> Cell:
    arch = cfgbase.get(arch_id)
    shape = cfgbase.SHAPES[shape_name]
    if shape_name not in arch.shapes:
        raise ValueError(
            f"{arch_id} skips {shape_name} (see DESIGN.md §8): {arch.notes}")
    if arch.kind == "lm":
        return build_lm_cell(arch, shape, mesh, overrides=overrides)
    if arch.kind == "encdec":
        return build_encdec_cell(arch, shape, mesh, overrides=overrides)
    raise ValueError(f"{arch_id} ({arch.kind}) has no mesh cells")
