import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * .lower().compile() must succeed on the 16x16 single-pod mesh AND the
    2x16x16 multi-pod mesh for every assigned cell;
  * memory_analysis() -> per-device bytes (does it fit 16 GB HBM?);
  * cost_analysis()  -> per-device FLOPs/bytes for the §Roofline terms;
  * HLO text         -> collective bytes (core.roofline parser).

Results append to a JSON file consumed by benchmarks/roofline_table.py and
EXPERIMENTS.md. One cell per process by default (isolation + parallel fan-out
from the orchestrator); ``--arch all`` loops in-process when asked.

NOTE: the two lines above MUST stay the first statements in this module —
jax locks the device count on first init.
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import base as cfgbase
from repro.core import flops as flops_lib
from repro.core import roofline as rl
from repro.launch import cells as cells_lib
from repro.launch.mesh import make_production_mesh


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             overrides: Optional[dict] = None) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(len(mesh.devices.reshape(-1)))
    label = f"{arch_id}/{shape_name}/{'multi' if multi_pod else 'single'}"
    rec: Dict = {"arch": arch_id, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "n_devices": n_dev, "label": label,
                 "overrides": overrides or {}}
    t0 = time.time()
    try:
        cell = cells_lib.build_cell(arch_id, shape_name, mesh, overrides)
        lowered = cell.lower(mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        terms = rl.from_compiled(compiled, n_dev, label=label)
        # XLA cost_analysis counts while bodies ONCE (scanned layers!) — the
        # jaxpr-walk gives exact semantic flops & a fusion-aware traffic
        # estimate (core.flops). XLA numbers kept for reference.
        analytic = flops_lib.cost_of_fn(cell.step_fn, *cell.args_sds,
                                        n_devices=n_dev)
        xla_flops_dev = terms.flops_per_device
        xla_bytes_dev = terms.bytes_per_device
        terms.flops_per_device = analytic["flops_per_device"]
        terms.bytes_per_device = analytic["traffic_per_device"]
        model_flops = (
            rl.model_flops_train(cell.n_params_active, cell.tokens_per_step)
            if cell.kind == "train" else
            rl.model_flops_infer(cell.n_params_active, cell.tokens_per_step))

        hbm = 16 * 1024**3
        per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                         + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        rec.update(
            ok=True,
            kind=cell.kind,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_params_total=cell.n_params_total,
            n_params_active=cell.n_params_active,
            tokens_per_step=cell.tokens_per_step,
            model_flops=model_flops,
            memory=dict(
                argument=mem.argument_size_in_bytes,
                output=mem.output_size_in_bytes,
                temp=mem.temp_size_in_bytes,
                alias=mem.alias_size_in_bytes,
                per_device_live=per_dev_bytes,
                fits_hbm=bool(per_dev_bytes <= hbm),
                analytic_live=cell.analytic_live_bytes,
                fits_hbm_analytic=bool(cell.analytic_live_bytes <= hbm),
            ),
            flops_per_device=terms.flops_per_device,
            bytes_per_device=terms.bytes_per_device,
            xla_flops_per_device=xla_flops_dev,
            xla_bytes_per_device=xla_bytes_dev,
            flops_by_prim=analytic["by_prim"],
            collective_bytes_per_device=terms.collective_bytes_per_device,
            collective_detail=terms.collective_detail,
            compute_s=terms.compute_s,
            memory_s=terms.memory_s,
            collective_s=terms.collective_s,
            bound=terms.bound,
            step_time_s=terms.step_time_s,
            useful_flops_ratio=terms.useful_flops_ratio(model_flops),
            roofline_fraction=terms.roofline_fraction(model_flops),
        )
    except Exception as e:  # recorded, not raised: the table shows the bug
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True,
                    help="arch id or 'all' (LM/enc-dec archs)")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all' (arch-applicable shapes)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="append-JSONL output path")
    ap.add_argument("--override", default=None,
                    help="JSON dict of LMConfig overrides (perf experiments)")
    args = ap.parse_args()

    arch_ids = (cfgbase.all_arch_ids(lm_only=True) if args.arch == "all"
                else [args.arch])
    overrides = json.loads(args.override) if args.override else None
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch_id in arch_ids:
        arch = cfgbase.get(arch_id)
        shapes = arch.shapes if args.shape == "all" else [args.shape]
        for shape_name in shapes:
            for multi in meshes:
                rec = run_cell(arch_id, shape_name, multi, overrides)
                results.append(rec)
                status = "OK " if rec.get("ok") else "FAIL"
                extra = (f"bound={rec.get('bound')} "
                         f"t={rec.get('step_time_s', 0):.4f}s "
                         f"fit={rec.get('memory', {}).get('fits_hbm')}"
                         if rec.get("ok") else rec.get("error"))
                print(f"[{status}] {rec['label']:45s} "
                      f"wall={rec['wall_s']:6.1f}s {extra}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
