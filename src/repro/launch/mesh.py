"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""

from __future__ import annotations

from repro.parallel import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests, elastic replanning)."""
    return compat.make_mesh(shape, axes)
