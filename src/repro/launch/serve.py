"""Serving launcher: batched request serving with carbon accounting.

CPU-runnable with --smoke (reduced configs); production decode shapes are
proven via launch.dryrun (decode_32k / long_500k cells).
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.core import accounting
from repro.models import transformer as tf_lib
from repro.serve import (FAULT_KINDS, FaultPlan, ProcessKilled, Scheduler,
                         SchedulerConfig, ServeConfig, ServeEngine)


def validate_args(ap: argparse.ArgumentParser,
                  args: argparse.Namespace) -> None:
    """Reject nonsensical flag combinations with actionable messages BEFORE
    any device work — the engine would also raise, but deep in __init__
    with a traceback instead of a usage line (DESIGN.md §17 satellite)."""
    if args.spec_k < 0:
        ap.error(f"--spec-k must be >= 0, got {args.spec_k}")
    if args.page_size <= 0:
        ap.error(f"--page-size must be > 0, got {args.page_size}")
    if args.prefill_chunk < 0:
        ap.error(f"--prefill-chunk must be >= 0, got {args.prefill_chunk}")
    if (args.paged and args.prefill_chunk > 0
            and args.prefill_chunk % args.page_size != 0):
        ap.error(f"--prefill-chunk ({args.prefill_chunk}) must be a "
                 f"multiple of --page-size ({args.page_size}) in paged "
                 f"mode: chunk boundaries must land on page boundaries")
    if not (0.0 <= args.compact_threshold <= 1.0):
        ap.error(f"--compact-threshold must be in [0, 1], got "
                 f"{args.compact_threshold}")
    if args.num_pages is not None and args.num_pages <= 0:
        ap.error(f"--num-pages must be > 0, got {args.num_pages}")
    if args.spec_k > 0 and not args.paged:
        ap.error("--spec-k requires --paged (speculative decode runs on "
                 "the paged path only)")
    if args.fault_kind is not None and args.fault_tick < 0:
        ap.error(f"--fault-tick must be >= 0, got {args.fault_tick}")
    if args.deadline_ticks is not None and args.deadline_ticks <= 0:
        ap.error(f"--deadline-ticks must be > 0, got {args.deadline_ticks}")
    if args.nbest < 1:
        ap.error(f"--nbest must be >= 1, got {args.nbest}")
    if args.nbest > 1 and not args.paged:
        ap.error("--nbest requires --paged (n-best sampling forks the "
                 "paged KV cache, DESIGN.md §18)")
    if args.nbest > args.slots:
        ap.error(f"--nbest ({args.nbest}) cannot exceed --slots "
                 f"({args.slots}): every fork decodes concurrently")
    if args.spec_tree_m < 1:
        ap.error(f"--spec-tree-m must be >= 1, got {args.spec_tree_m}")
    if args.spec_tree_m > 1 and args.spec_k <= 0:
        ap.error("--spec-tree-m > 1 requires --spec-k > 0 (tree "
                 "speculation rides the speculative verify pass)")
    if args.spec_tree_m > 1 and args.spec_drafter != "ngram":
        ap.error("--spec-tree-m > 1 drafts with the ngram drafter only")
    if args.checkpoint_interval < 0:
        ap.error(f"--checkpoint-interval must be >= 0, got "
                 f"{args.checkpoint_interval}")
    if args.checkpoint_interval > 0 and args.checkpoint_dir is None:
        ap.error("--checkpoint-interval requires --checkpoint-dir "
                 "(snapshots need somewhere durable to land, "
                 "DESIGN.md §19)")
    if args.resume and args.checkpoint_dir is None:
        ap.error("--resume requires --checkpoint-dir (restore loads the "
                 "snapshot + journal written there)")
    if args.fault_kind == "process_kill" and args.checkpoint_dir is None:
        ap.error("--fault-kind process_kill requires --checkpoint-dir: "
                 "the kill is only survivable with a snapshot + journal "
                 "to restart from (DESIGN.md §19)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--grid-mix", default="NY")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--policy", default="fifo",
                    choices=("fifo", "longest_prompt"))
    ap.add_argument("--quant", default="none", choices=("none", "int8"),
                    help="int8: serve through the quantized fast path "
                         "(int8 weights + int8 KV cache, DESIGN.md §12)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache with prefix reuse (DESIGN.md §14)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool capacity in pages (default: dense-equivalent)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable content-hash prefix block reuse")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="admit prompts in chunks of this many tokens, "
                         "interleaved with decode ticks (0 = whole prompt)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode: draft this many tokens per "
                         "tick and verify them in one multi-query pass "
                         "(paged mode only, DESIGN.md §15; 0 = off)")
    ap.add_argument("--spec-drafter", default="ngram",
                    choices=("ngram", "oracle"),
                    help="ngram: prompt-lookup self-drafting (near-free); "
                         "oracle: the target model drafts itself (parity "
                         "harness)")
    ap.add_argument("--spec-tree-m", type=int, default=1,
                    help="tree speculation: verify this many independent "
                         "draft branches per slot in the one multi-query "
                         "pass and commit the longest-accepted branch "
                         "(requires --spec-k, ngram drafter; DESIGN.md "
                         "§18; 1 = linear)")
    ap.add_argument("--nbest", type=int, default=1,
                    help="fork each request into this many decode streams "
                         "sharing prompt KV pages copy-on-write; stream 0 "
                         "is the canonical greedy stream (paged mode, "
                         "DESIGN.md §18; 1 = off)")
    ap.add_argument("--compact-threshold", type=float, default=0.0,
                    help="compact a slot's private page suffix into a "
                         "contiguous run when its page-table fragmentation "
                         "reaches this score in [0, 1] (paged mode, "
                         "DESIGN.md §16; 0 = compaction off)")
    ap.add_argument("--evict-policy", default="lru",
                    choices=("lru", "cost"),
                    help="parked-prefix reclamation: lru evicts the least-"
                         "recently-parked block; cost evicts the cheapest-"
                         "to-recompute block first (recompute FLOPs per "
                         "byte, DESIGN.md §16)")
    ap.add_argument("--fault-kind", default=None, choices=FAULT_KINDS,
                    help="chaos tier (DESIGN.md §17): inject one seeded "
                         "fault of this kind and exercise the degradation "
                         "ladder (default: no injection)")
    ap.add_argument("--fault-tick", type=int, default=2,
                    help="engine tick at which the fault fires")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault payload (reproducible chaos)")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="per-request deadline in ticks; overdue queued "
                         "requests are shed, not served late")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="durability tier (DESIGN.md §19): journal every "
                         "admission (fsync'd) and snapshot engine state "
                         "here; a killed engine warm-restarts "
                         "token-identically via --resume")
    ap.add_argument("--checkpoint-interval", type=int, default=0,
                    help="snapshot every N ticks (0 = journal only; "
                         "requires --checkpoint-dir). Smaller = less "
                         "replay after a crash, more write energy")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --checkpoint-dir before serving: "
                         "load the latest snapshot, replay the journal "
                         "tail, resume mid-stream requests exactly")
    args = ap.parse_args()
    validate_args(ap, args)

    if not args.smoke:
        raise SystemExit("full-scale serving needs a TPU fleet; use --smoke "
                         "or `python -m repro.launch.dryrun` for the decode "
                         "cells.")
    arch = cfgbase.get(args.arch)
    if arch.kind != "lm":
        raise SystemExit(f"serve launcher supports LM archs; {args.arch} is "
                         f"{arch.kind}")
    cfg = arch.make_smoke()
    params = tf_lib.init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32).params
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=jax.device_count(), grid_mix=args.grid_mix))
    scfg = ServeConfig(max_slots=args.slots, max_len=256,
                       temperature=args.temperature,
                       quant=args.quant, paged=args.paged,
                       page_size=args.page_size,
                       num_pages=args.num_pages,
                       prefix_cache=not args.no_prefix_cache,
                       prefill_chunk=args.prefill_chunk,
                       spec_k=args.spec_k,
                       spec_drafter=args.spec_drafter,
                       spec_tree_m=args.spec_tree_m,
                       compact_threshold=args.compact_threshold,
                       evict_policy=args.evict_policy,
                       faults=(FaultPlan.single(
                           args.fault_kind, tick=args.fault_tick,
                           seed=args.fault_seed)
                           if args.fault_kind else None),
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_interval=args.checkpoint_interval)

    def build() -> ServeEngine:
        return ServeEngine(params, cfg, scfg, accountant=acct,
                           scheduler=Scheduler(
                               SchedulerConfig(policy=args.policy)))

    eng = build()
    done = []
    if args.resume:
        done.extend(eng.restore())
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12))
        eng.submit(prompt, max_tokens=args.max_tokens,
                   deadline_ticks=args.deadline_ticks,
                   n_best=args.nbest)
    while True:
        try:
            done.extend(eng.run_until_drained())
            break
        except ProcessKilled as e:
            # simulated crash (DESIGN.md §19): the old engine object is
            # dead — restart purely from disk and keep serving
            print(f"engine killed ({e}); warm-restarting from "
                  f"{args.checkpoint_dir}")
            eng = build()
            done.extend(eng.restore())
    # restore delivery is at-least-once: dedupe by uid, keep stream order
    done = sorted({r.uid: r for r in done}.values(), key=lambda r: r.uid)
    for r in done:
        print(f"req {r.uid}: prompt_len={len(r.prompt)} -> {r.generated}")
        if r.nbest is not None:
            for i, alt in enumerate(r.nbest[1:], start=1):
                print(f"  nbest[{i}]: {alt}")
    s = eng.summary()
    rep = acct.report()
    print(f"serve: {s['ticks']} ticks, {s['decode_tokens']:.0f} decode toks "
          f"({s['decode_tokens_per_s']:.1f} tok/s), "
          f"{s['prefill_tokens']:.0f} prefill toks")
    jpt = rep.get("j_per_token")
    if jpt is not None:
        print(f"live J/token: {jpt:.3f}")
    mjpt = rep.get("modeled_j_per_token")
    if mjpt is not None:
        print(f"modeled (FLOPs+DRAM) J/token: {mjpt:.3e} "
              f"({rep['bytes_moved']:.3g} bytes moved)")
    if args.paged:
        print(f"prefix cache: {rep['prefix_hit_rate']:.1%} hit rate "
              f"({rep['prefix_hit_tokens']:.0f} prompt tokens reused), "
              f"saved {rep['saved_bytes']:.3g} KV bytes "
              f"= {rep['saved_dram_j']:.3e} J DRAM")
        print(f"long-context: {rep['prefill_gather_bytes']:.3g} prefill "
              f"gather bytes = {rep['prefill_gather_dram_j']:.3e} J DRAM, "
              f"{rep['compaction_moves']:.0f} pages compacted")
    if args.paged and (args.nbest > 1 or s["cow_copies"] > 0):
        print(f"copy-on-write: {s['forks']:.0f} forks, "
              f"{s['cow_copies']:.0f} page copies "
              f"({rep.get('cow_bytes', 0.0):.3g} bytes = "
              f"{rep.get('cow_dram_j', 0.0):.3e} J DRAM), saved "
              f"{rep.get('fork_saved_bytes', 0.0):.3g} duplicate KV bytes "
              f"= {rep.get('fork_saved_dram_j', 0.0):.3e} J DRAM")
    if args.fault_kind is not None:
        print(f"chaos ({args.fault_kind}@{args.fault_tick}): "
              f"{s['faults_injected']} injected, {s['quarantined']} "
              f"quarantined, {s['shed']} shed, recovery "
              f"{s['recovery_j']:.3e} J ({s['recovery_tokens']} toks), "
              f"{s['degraded_ticks']} degraded ticks")
    if args.checkpoint_dir is not None:
        print(f"durability: {s['snapshots_taken']:.0f} snapshots "
              f"({s['snapshot_bytes']:.3g} B) + journal "
              f"{s['journal_bytes']:.3g} B = "
              f"{s['durability_write_j']:.3e} J writes; replayed "
              f"{s['replayed_ticks']:.0f} ticks on restore "
              f"({s['restore_j']:.3e} J)")
    if args.spec_k > 0:
        print(f"speculative decode (k={args.spec_k}, "
              f"{args.spec_drafter}): {s['accept_rate']:.1%} accept rate, "
              f"{s['accepted_tokens_per_tick']:.2f} emitted "
              f"tokens/slot-tick, J/accepted-token "
              f"{rep['spec']['j_per_accepted_token']:.3e}")
    print("carbon report:", json.dumps(rep, default=float))


if __name__ == "__main__":
    main()
