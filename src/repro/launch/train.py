"""Training launcher: full FT loop on a (possibly multi-pod) mesh.

CPU-friendly path: ``--smoke`` runs the arch's reduced config end-to-end
(real steps, real checkpoints, real accounting). The production path takes
``--mesh single|multi`` and shards params/optimizer/data exactly as the
dry-run proves out; on this CPU container the full configs are exercised via
``launch.dryrun`` instead.

Example (the (b) end-to-end driver uses this):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-27b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt --grid-mix NY
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.core import accounting
from repro.data import DataConfig, make_pipeline
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib
from repro.optim import AdamWConfig
from repro.optim.schedules import warmup_cosine
from repro.checkpoint import CheckpointConfig
from repro.train import (TrainConfig, Trainer, TrainEngine,
                         TrainEngineConfig)
from repro.train.ft import HeartbeatWriter


def build_smoke_trainer(arch_id: str, *, steps: int, ckpt_dir: Optional[str],
                        grid_mix: str = "NY", seed: int = 0,
                        global_batch: int = 8, seq_len: int = 64,
                        heartbeat_dir: Optional[str] = None,
                        lr: float = 3e-3) -> Trainer:
    arch = cfgbase.get(arch_id)
    cfg = arch.make_smoke()
    key = jax.random.PRNGKey(seed)
    if arch.kind == "encdec":
        params = encdec_lib.init_encdec(key, cfg, dtype=jnp.float32).params
        frames = np.zeros((global_batch, cfg.n_audio_ctx, cfg.d_model),
                          np.float32)

        def loss_fn(p, batch):
            b = dict(batch)
            b["frames"] = jnp.asarray(frames)
            return encdec_lib.loss_fn(p, cfg, b)
        vocab = cfg.vocab
    else:
        params = tf_lib.init_lm(key, cfg, dtype=jnp.float32).params
        vision = (np.zeros((global_batch, cfg.vision_tokens, cfg.d_model),
                           np.float32) if cfg.vision_tokens else None)

        def loss_fn(p, batch):
            b = dict(batch)
            if vision is not None:
                b["vision_embeds"] = jnp.asarray(vision)
            return tf_lib.loss_fn(p, cfg, b)
        vocab = cfg.vocab

    pipeline = make_pipeline(DataConfig(
        vocab=vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed, source="markov"))
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=jax.device_count(), grid_mix=grid_mix))
    hb = (HeartbeatWriter(heartbeat_dir, host_id="host0")
          if heartbeat_dir else None)
    trainer = Trainer(
        loss_fn=loss_fn, params=params,
        opt_cfg=AdamWConfig(lr=warmup_cosine(lr, max(steps // 10, 1), steps)),
        train_cfg=TrainConfig(num_steps=steps, log_every=max(steps // 10, 1),
                              checkpoint_every=max(steps // 4, 1)),
        pipeline=pipeline,
        ckpt_cfg=(CheckpointConfig(directory=ckpt_dir) if ckpt_dir else None),
        accountant=acct, heartbeat=hb)
    return trainer


def build_smoke_engine(arch_id: str, *, steps: int, grid_mix: str = "NY",
                       seed: int = 0, global_batch: int = 8,
                       seq_len: int = 64, steps_per_tick: int = 8,
                       lr: float = 3e-3) -> TrainEngine:
    """Fused-engine variant of build_smoke_trainer (DESIGN.md §13): same
    arch smoke config, data stream, and AdamW schedule, but the steps run
    through the device-resident TrainEngine tick with per-phase energy
    accounting. Decoder-only archs only (the engine's cost model and the
    flash-VJP routing are LM-shaped; encdec smokes stay on the Trainer)."""
    arch = cfgbase.get(arch_id)
    if arch.kind == "encdec":
        raise SystemExit(f"{arch_id}: encdec smoke runs use --engine loop")
    cfg = arch.make_smoke()
    params = tf_lib.init_lm(jax.random.PRNGKey(seed), cfg,
                            dtype=jnp.float32).params
    pipeline = make_pipeline(DataConfig(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed, source="markov"))
    acct = accounting.CarbonAccountant(accounting.AccountantConfig(
        device="tpu_v5e", n_devices=jax.device_count(), grid_mix=grid_mix))
    return TrainEngine.for_lm(
        params, cfg,
        opt_cfg=AdamWConfig(lr=warmup_cosine(lr, max(steps // 10, 1), steps)),
        pipeline=pipeline,
        engine_cfg=TrainEngineConfig(steps_per_tick=steps_per_tick),
        accountant=acct)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grid-mix", default="NY")
    ap.add_argument("--report", default=None, help="write accounting JSON")
    ap.add_argument("--engine", choices=("loop", "fused"), default="loop",
                    help="loop: host-loop Trainer (checkpoint/FT path); "
                         "fused: device-resident TrainEngine tick with "
                         "per-phase energy accounting (DESIGN.md §13)")
    ap.add_argument("--steps-per-tick", type=int, default=8,
                    help="fused engine: optimizer steps per jitted tick")
    args = ap.parse_args()

    if not args.smoke:
        raise SystemExit(
            "full-scale training needs a TPU fleet; on this container use "
            "`python -m repro.launch.dryrun` (the compile-time proof) or "
            "--smoke (the runnable reduced config).")

    if args.engine == "fused":
        eng = build_smoke_engine(args.arch, steps=args.steps,
                                 grid_mix=args.grid_mix,
                                 steps_per_tick=args.steps_per_tick)
        metrics = eng.run(args.steps)
        print("final metrics:", json.dumps(metrics))
        print("engine summary:", json.dumps(eng.summary()))
        rep = eng.accountant.report()
        print("carbon report:", json.dumps(rep, default=float))
        if args.report:
            with open(args.report, "w") as f:
                json.dump({"metrics": metrics, "summary": eng.summary(),
                           "carbon": rep}, f, default=float)
        return

    tr = build_smoke_trainer(args.arch, steps=args.steps,
                             ckpt_dir=args.ckpt_dir, grid_mix=args.grid_mix)
    tr.install_preemption_handler()
    if args.resume:
        restored = tr.maybe_restore()
        print(f"resume: {'restored step ' + str(tr.step_num) if restored else 'fresh'}")
    metrics = tr.run()
    print("final metrics:", json.dumps(metrics))
    if tr.accountant:
        rep = tr.accountant.report()
        print("carbon report:", json.dumps(rep, default=float))
        if args.report:
            with open(args.report, "w") as f:
                json.dump({"metrics": metrics, "carbon": rep}, f, default=float)


if __name__ == "__main__":
    main()
