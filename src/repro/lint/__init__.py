"""repro-lint: AST-based invariant linter for the repro codebase.

Five composable passes turn DESIGN.md §20's load-bearing invariants into
machine-checked contracts (run via ``tools/repro_lint.py`` / ``make
lint``):

========================  =====  =========================================
pass                      rules  contract
========================  =====  =========================================
trace-purity              L10x   no host syncs reachable from jax.jit
readback-budget           L20x   ONE compact readback per engine tick
replay-determinism        L30x   replay = pure function of journal bytes
accounting-completeness   L40x   every metrics channel billed + guarded
donation-safety           L50x   donated buffers never read after donate
========================  =====  =========================================
"""

from __future__ import annotations

from typing import Callable, Dict, List

from . import accounting, determinism, donation, purity, readback
from .base import (Context, Finding, RULES, load_baseline, split_by_baseline,
                   write_baseline)

#: registration order == report order
PASSES: Dict[str, Callable[[Context], List[Finding]]] = {
    purity.NAME: purity.run,
    readback.NAME: readback.run,
    determinism.NAME: determinism.run,
    accounting.NAME: accounting.run,
    donation.NAME: donation.run,
}


def run_passes(ctx: Context, names: List[str] = None) -> List[Finding]:
    findings: List[Finding] = []
    for name, fn in PASSES.items():
        if names and name not in names:
            continue
        findings.extend(fn(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.detail))
    return findings


__all__ = [
    "Context", "Finding", "PASSES", "RULES", "load_baseline",
    "run_passes", "split_by_baseline", "write_baseline",
]
