"""accounting-completeness pass (L401-L402): every metrics channel is
billed, every summary ratio is zero-guarded.

The paper's J/token claims are only as trustworthy as the accountant's
coverage: a StepMetrics field that never reaches a CarbonAccountant bill
site is a silently-uncounted energy channel (L401), and an unguarded
division in a ``summary()``/``*report()`` is exactly the zero-div
regression class PRs 5/7 shipped fixes for (L402).

* L401 — introspects the metrics dataclass fields (AnnAssign entries) and
  cross-checks each against the billing method's reads — both
  ``metrics.<field>`` attribute access and ``getattr(metrics, "<field>",
  ...)`` string constants. Fields that are intentionally observability-
  only (not energy channels) must be listed in an ``ACCOUNTING_EXEMPT``
  frozenset next to the dataclass; everything else must be billed.
* L402 — flags ``a / b`` in summary/report functions unless the
  denominator is a literal, wrapped in ``max(...)``, covered by the
  enclosing ``IfExp`` test, or dominated by an early-return guard that
  mentions the denominator (through one level of local aliasing, e.g.
  ``n = self._train_steps`` after ``if self._train_steps == 0: return``).
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

from .base import Context, Finding, Module

NAME = "accounting-completeness"


@dataclasses.dataclass(frozen=True)
class BillingPair:
    metrics_path: str       # module holding the metrics dataclass
    metrics_class: str
    exempt_const: str       # name of the ACCOUNTING_EXEMPT frozenset
    bill_path: str          # module holding the accountant
    bill_qual: str          # billing method qualname
    bill_param: str = "metrics"


BILLING_PAIRS: Tuple[BillingPair, ...] = (
    BillingPair("src/repro/serve/engine.py", "StepMetrics",
                "ACCOUNTING_EXEMPT",
                "src/repro/core/accounting.py",
                "CarbonAccountant.observe_serve"),
    BillingPair("src/repro/train/engine.py", "TrainStepMetrics",
                "TRAIN_ACCOUNTING_EXEMPT",
                "src/repro/core/accounting.py",
                "CarbonAccountant.observe_train"),
)

#: functions whose ratios must be zero-guarded
SUMMARY_FN_RE = re.compile(r"(^summary$|^report$|_report$|^hit_rate$)")


def _dataclass_fields(mod: Module, cls_name: str) -> List[Tuple[str, int]]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            out = []
            for st in node.body:
                if isinstance(st, ast.AnnAssign) and \
                        isinstance(st.target, ast.Name):
                    out.append((st.target.id, st.lineno))
            return out
    return []


def _exempt_fields(mod: Module, const: str) -> Set[str]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == const:
                    return {n.value for n in ast.walk(node.value)
                            if isinstance(n, ast.Constant) and
                            isinstance(n.value, str)}
    return set()


def _billed_fields(fn: ast.AST, param: str) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == param:
            out.add(node.attr)
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Name) and \
                node.func.id == "getattr" and len(node.args) >= 2 and \
                isinstance(node.args[0], ast.Name) and \
                node.args[0].id == param and \
                isinstance(node.args[1], ast.Constant):
            out.add(node.args[1].value)
    return out


def _check_billing(ctx: Context, pair: BillingPair) -> List[Finding]:
    mmod = ctx.modules.get(pair.metrics_path)
    bmod = ctx.modules.get(pair.bill_path)
    if mmod is None or bmod is None:
        return []
    fields = _dataclass_fields(mmod, pair.metrics_class)
    if not fields:
        return []
    bill_fn = ctx.lookup_function(pair.bill_path, pair.bill_qual)
    if bill_fn is None:
        return [Finding("L401", pair.bill_path, 0, pair.bill_qual,
                        f"billing method {pair.bill_qual} not found for "
                        f"{pair.metrics_class}")]
    billed = _billed_fields(bill_fn, pair.bill_param)
    exempt = _exempt_fields(mmod, pair.exempt_const)
    out: List[Finding] = []
    for name, line in fields:
        if name in billed or name in exempt:
            continue
        out.append(Finding(
            "L401", mmod.path, line, pair.metrics_class,
            f"field `{name}` has no bill site in {pair.bill_qual} and is "
            f"not listed in {pair.exempt_const}"))
    return out


# -- L402: unguarded divisions in summaries ----------------------------------


def _names_in(node: ast.expr) -> Set[str]:
    """All Name/Attribute spellings inside an expression."""
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)):
            try:
                out.add(ast.unparse(n))
            except Exception:       # pragma: no cover - defensive
                pass
    return out


def _literal_denominator(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float)) and node.value != 0
    if isinstance(node, ast.UnaryOp):
        return _literal_denominator(node.operand)
    if isinstance(node, ast.BinOp):
        return _literal_denominator(node.left) and \
            _literal_denominator(node.right)
    return False


def _guarded_by_max(node: ast.expr) -> bool:
    return isinstance(node, ast.Call) and (
        (isinstance(node.func, ast.Name) and node.func.id == "max") or
        (isinstance(node.func, ast.Attribute) and
         node.func.attr in ("maximum", "clip")))


class _DivChecker:
    def __init__(self, mod: Module, qual: str):
        self.mod = mod
        self.qual = qual
        self.findings: List[Finding] = []

    def check(self, fn: ast.AST) -> List[Finding]:
        aliases = self._local_aliases(fn)
        guards = self._early_guards(fn, aliases)
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
                self._check_div(node, fn, aliases, guards)
        return self.findings

    def _local_aliases(self, fn: ast.AST) -> Dict[str, str]:
        """name -> unparse(value) for simple top-level assignments."""
        out: Dict[str, str] = {}
        for st in getattr(fn, "body", []):
            if isinstance(st, ast.Assign) and len(st.targets) == 1 and \
                    isinstance(st.targets[0], ast.Name):
                try:
                    out[st.targets[0].id] = ast.unparse(st.value)
                except Exception:   # pragma: no cover - defensive
                    pass
        return out

    def _early_guards(self, fn: ast.AST,
                      aliases: Dict[str, str]) -> Set[str]:
        """Names covered by `if <test mentioning name>: return ...` at the
        top level of the function body."""
        covered: Set[str] = set()
        for st in getattr(fn, "body", []):
            if isinstance(st, ast.If) and st.body and \
                    isinstance(st.body[0], (ast.Return, ast.Raise)):
                covered |= _names_in(st.test)
        return covered

    def _expand(self, names: Set[str], aliases: Dict[str, str]) -> Set[str]:
        out = set(names)
        for n in names:
            if n in aliases:
                out.add(aliases[n])
            for k, v in aliases.items():
                if v == n or n in _names_in_str(v):
                    out.add(k)
        return out

    def _check_div(self, div: ast.BinOp, fn: ast.AST,
                   aliases: Dict[str, str], guards: Set[str]) -> None:
        den = div.right
        if _literal_denominator(den) or _guarded_by_max(den):
            return
        den_names = self._expand(_names_in(den), aliases)
        if not den_names:
            return      # e.g. dividing by len(...) of a literal — rare
        # (1) enclosing IfExp whose test mentions the denominator
        for node in ast.walk(fn):
            if isinstance(node, ast.IfExp):
                inside = any(sub is div for sub in ast.walk(node.body)) or \
                    any(sub is div for sub in ast.walk(node.orelse))
                if inside and den_names & self._expand(
                        _names_in(node.test), aliases):
                    return
            # plain `if den: x = a / den` statement guards count too
            if isinstance(node, ast.If):
                inside = any(sub is div for st in node.body
                             for sub in ast.walk(st))
                if inside and den_names & self._expand(
                        _names_in(node.test), aliases):
                    return
        # (2) early-return guard mentioning the denominator
        if den_names & self._expand(guards, aliases):
            return
        self.findings.append(Finding(
            "L402", self.mod.path, div.lineno, self.qual,
            f"unguarded division `{self.mod.segment(div)}` in a "
            f"summary/report (guard the denominator against zero)"))


def _names_in_str(expr_src: str) -> Set[str]:
    try:
        return _names_in(ast.parse(expr_src, mode="eval").body)
    except SyntaxError:             # pragma: no cover - defensive
        return set()


#: modules whose summary/report functions are in scope
SUMMARY_SCOPE = (
    "src/repro/serve/engine.py",
    "src/repro/serve/pages.py",
    "src/repro/train/engine.py",
    "src/repro/core/accounting.py",
)


def run(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for pair in BILLING_PAIRS:
        out.extend(_check_billing(ctx, pair))
    for path in SUMMARY_SCOPE:
        mod = ctx.modules.get(path)
        if mod is None:
            continue
        for qual, fn in ctx.functions[mod.path].items():
            if SUMMARY_FN_RE.search(qual.split(".")[-1]):
                out.extend(_DivChecker(mod, qual).check(fn))
    return out
