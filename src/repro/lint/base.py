"""repro-lint core: parsed-repo context, findings, baseline semantics.

The linter turns the invariants that nine PRs of engine growth left as
prose in DESIGN.md into machine-checked contracts (DESIGN.md §20): each
pass walks the repo's ASTs and emits :class:`Finding`s carrying an
invariant ID + file:line. A checked-in baseline (tools/lint_baseline.txt,
modeled on tools/check_skips.py) holds *justified* suppressions keyed by a
line-number-free fingerprint, so refactors don't churn it; any finding not
in the baseline is NEW and fails CI before the test suite even runs.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Tuple

#: rule id -> (invariant slug, one-line contract) — the §20 catalog, in code
RULES: Dict[str, Tuple[str, str]] = {
    # trace purity (PRs 1/3/15: the jitted tick must stay on device)
    "L101": ("trace-purity", "host sync (.item()/.tolist()) on a traced value"),
    "L102": ("trace-purity", "host cast (float/int/bool) on a traced value"),
    "L103": ("trace-purity", "host-library call (np./math.) on a traced value"),
    "L104": ("trace-purity", "Python control flow on a traced value"),
    "L105": ("trace-purity", "host print of a traced value inside jit"),
    # readback budget (PRs 1/5/7: ONE compact readback per tick)
    "L201": ("readback-budget", "more than one readback on a tick path"),
    "L202": ("readback-budget", "readback inside a nested loop of the tick"),
    "L203": ("readback-budget", "raw device transfer outside the counted funnel"),
    # replay determinism (PR 9: token-identical warm restart)
    "L301": ("replay-determinism", "wall-clock time in a replayed/serialized path"),
    "L302": ("replay-determinism", "unseeded RNG in a replayed/serialized path"),
    "L303": ("replay-determinism", "unordered iteration feeding a serialized record"),
    # accounting completeness (PRs 2/5/7/9: every channel billed + guarded)
    "L401": ("accounting-completeness", "metrics field with no accountant bill site"),
    "L402": ("accounting-completeness", "unguarded division in a summary/report"),
    # donation safety (PRs 1/3: donated buffers die at the call)
    "L501": ("donation-safety", "donated argument read after the donating call"),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str           # e.g. "L301"
    path: str           # repo-relative posix path
    line: int           # 1-based
    func: str           # enclosing qualname ("" = module level)
    detail: str         # human-readable description of THIS occurrence

    @property
    def invariant(self) -> str:
        return RULES[self.rule][0]

    @property
    def fingerprint(self) -> str:
        """Stable suppression key: no line numbers (they drift), just
        rule + file + enclosing function + a slug of the detail."""
        slug = re.sub(r"[^a-z0-9]+", "-", self.detail.lower()).strip("-")
        return f"{self.rule}:{self.path}:{self.func}:{slug[:80]}"

    def render(self) -> str:
        where = f"{self.path}:{self.line}"
        fn = f" [{self.func}]" if self.func else ""
        return f"{where}: {self.rule} ({self.invariant}){fn}: {self.detail}"


# -- parsed-repo context ------------------------------------------------------


@dataclasses.dataclass
class Module:
    path: str               # repo-relative posix path
    dotted: str             # import path ("repro.serve.engine"; "" if none)
    tree: ast.Module
    source: str

    def segment(self, node: ast.AST) -> str:
        try:
            return ast.unparse(node)
        except Exception:           # pragma: no cover - defensive
            return "<unparseable>"


def _dotted_for(rel: str) -> str:
    parts = rel.replace("\\", "/").split("/")
    if parts[0] == "src":
        parts = parts[1:]
    if not parts or not parts[-1].endswith(".py"):
        return ""
    parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class Context:
    """Every scanned module parsed once, plus the cross-module indexes the
    passes share: function defs by (path, qualname), import alias maps, and
    module lookup by dotted import path."""

    def __init__(self, root: str, rel_paths: Iterable[str]):
        self.root = root
        self.modules: Dict[str, Module] = {}
        self.by_dotted: Dict[str, Module] = {}
        for rel in sorted(set(rel_paths)):
            full = os.path.join(root, rel)
            try:
                with open(full, "r", encoding="utf-8") as f:
                    src = f.read()
                tree = ast.parse(src, filename=rel)
            except (OSError, SyntaxError):
                continue
            mod = Module(rel.replace(os.sep, "/"), _dotted_for(rel), tree, src)
            self.modules[mod.path] = mod
            if mod.dotted:
                self.by_dotted[mod.dotted] = mod
        # (module path -> qualname -> def node); parent links for lookups
        self.functions: Dict[str, Dict[str, ast.AST]] = {}
        self.imports: Dict[str, Dict[str, str]] = {}        # alias -> module
        self.from_imports: Dict[str, Dict[str, Tuple[str, str]]] = {}
        for mod in self.modules.values():
            self.functions[mod.path] = index_functions(mod.tree)
            self.imports[mod.path], self.from_imports[mod.path] = \
                index_imports(mod.tree)

    @classmethod
    def for_root(cls, root: str,
                 subdirs: Tuple[str, ...] = ("src",)) -> "Context":
        rels: List[str] = []
        for sub in subdirs:
            base = os.path.join(root, sub)
            for dirpath, _dirs, files in os.walk(base):
                for f in files:
                    if f.endswith(".py"):
                        rels.append(os.path.relpath(
                            os.path.join(dirpath, f), root))
        return cls(root, rels)

    # -- lookups --------------------------------------------------------------

    def module_for_dotted(self, dotted: str) -> Optional[Module]:
        return self.by_dotted.get(dotted)

    def lookup_function(self, path: str, qualname: str) -> Optional[ast.AST]:
        return self.functions.get(path, {}).get(qualname)


def index_functions(tree: ast.Module) -> Dict[str, ast.AST]:
    """Map dotted qualnames (Class.method, func.nested) to def nodes."""
    out: Dict[str, ast.AST] = {}

    def walk(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                if not isinstance(child, ast.ClassDef):
                    out[qual] = child
                walk(child, qual)
            else:
                walk(child, prefix)

    walk(tree, "")
    return out


def index_imports(tree: ast.Module) -> Tuple[Dict[str, str],
                                             Dict[str, Tuple[str, str]]]:
    """(alias -> module dotted path, name -> (module, attr)) maps."""
    mods: Dict[str, str] = {}
    names: Dict[str, Tuple[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mods[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                names[a.asname or a.name] = (node.module, a.name)
    return mods, names


def attr_chain(node: ast.AST) -> Optional[List[str]]:
    """['jax', 'device_get'] for jax.device_get; None for non-chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def enclosing_qualname(tree: ast.Module, target: ast.AST) -> str:
    """Qualname of the innermost def/class containing ``target``."""
    best = ""

    def walk(node: ast.AST, prefix: str) -> None:
        nonlocal best
        for child in ast.iter_child_nodes(node):
            qual = prefix
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
            if child is target or any(n is target for n in ast.walk(child)):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    best = qual
                walk(child, qual)
                return

    walk(tree, "")
    return best


# -- baseline semantics -------------------------------------------------------


def load_baseline(path: str) -> Dict[str, str]:
    """fingerprint -> justification. Lines are ``<fingerprint>  # why``;
    blank lines and full-line comments are skipped."""
    out: Dict[str, str] = {}
    if not os.path.exists(path):
        return out
    with open(path, "r", encoding="utf-8") as f:
        for raw in f:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            fp, _, why = line.partition("#")
            out[fp.strip()] = why.strip()
    return out


def split_by_baseline(findings: List[Finding], baseline: Dict[str, str]
                      ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """(new, suppressed, stale-baseline-fingerprints). A baseline entry
    that no longer matches any finding is *stale* — the violation was
    fixed; the entry should be removed in the same PR (expire semantics)."""
    fps = {f.fingerprint for f in findings}
    new = [f for f in findings if f.fingerprint not in baseline]
    supp = [f for f in findings if f.fingerprint in baseline]
    stale = [fp for fp in baseline if fp not in fps]
    return new, supp, stale


def write_baseline(path: str, findings: List[Finding]) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write("# repro-lint baseline: one fingerprint per line; trailing "
                "'# why' is the justification.\n"
                "# New findings (not listed here) fail CI. Stale entries "
                "should be deleted in the same PR.\n")
        for fp in sorted({x.fingerprint for x in findings}):
            f.write(fp + "  # TODO: justify or fix\n")
