"""replay-determinism pass (L301-L303): replayed paths must be pure
functions of journal + snapshot content.

PR 9's durability contract is *token-identical* warm restart: replaying
the journal against a snapshot must reproduce every stream bit-for-bit.
Three hazard classes break that silently:

* L301 — wall-clock reads (``time.time``/``time_ns``, ``datetime.now``)
  anywhere in the replay-scope modules. ``time.monotonic`` /
  ``perf_counter`` stay legal: they only feed wall-second *measurement*
  channels that are never replayed.
* L302 — unseeded RNG: argless ``np.random.default_rng()``, the global
  ``np.random.*`` draw functions, stdlib ``random.*`` draws.
* L303 — unordered iteration feeding a serialized record: a ``for`` or
  comprehension over a ``set``-typed value, or a list/tuple
  materialization of dict ``.items()/.keys()/.values()``, inside a
  serialization function (``state_dict``/``to_dict``/``append*``/
  ``*fingerprint*``/``*snapshot*``/``*journal*``) without ``sorted()``.
  Dict comprehensions are exempt (JSON objects are key-addressed), and
  iterations consumed by order-insensitive reducers (``sorted``, ``sum``,
  ``min``, ``max``, ``any``, ``all``, ``len``, ``set``, ``frozenset``)
  are fine.
"""

from __future__ import annotations

import ast
import re
from typing import List, Optional, Set

from .base import Context, Finding, Module, attr_chain, enclosing_qualname

NAME = "replay-determinism"

#: modules on the replay path or serialized into snapshots/journals
SCOPE = (
    "src/repro/serve/engine.py",
    "src/repro/serve/snapshot.py",
    "src/repro/serve/pages.py",
    "src/repro/serve/scheduler.py",
    "src/repro/serve/faults.py",
    "src/repro/serve/spec.py",
    "src/repro/checkpoint/manager.py",
    "src/repro/core/accounting.py",
    "src/repro/train/ft.py",
)

WALL_CLOCK = {("time", "time"), ("time", "time_ns"),
              ("datetime", "now"), ("datetime", "utcnow"),
              ("datetime", "today")}
GLOBAL_NP_DRAWS = {"rand", "randn", "randint", "random", "choice",
                   "shuffle", "permutation", "uniform", "normal"}
STDLIB_RANDOM = "random"
SERIAL_FN_RE = re.compile(
    r"(state_dict|to_dict|fingerprint|append|snapshot|journal)")
ORDER_INSENSITIVE = {"sorted", "sum", "min", "max", "any", "all", "len",
                     "set", "frozenset", "dict"}
DICT_VIEWS = {"items", "keys", "values"}


def _wall_clock_call(node: ast.Call) -> bool:
    chain = attr_chain(node.func)
    return bool(chain) and len(chain) >= 2 and \
        tuple(chain[-2:]) in WALL_CLOCK


def _unseeded_rng(node: ast.Call, mod: Module, ctx: Context) -> Optional[str]:
    chain = attr_chain(node.func)
    if not chain:
        return None
    imports = ctx.imports[mod.path]
    base = imports.get(chain[0], "")
    # np.random.default_rng() with no seed
    if chain[-1] == "default_rng" and not node.args and not node.keywords:
        return "argless default_rng() (unseeded)"
    # global numpy draws: np.random.rand / randint / ...
    if base.startswith("numpy") and len(chain) >= 2 and \
            "random" in chain[1:-1] + [chain[1]] and \
            chain[-1] in GLOBAL_NP_DRAWS:
        return f"global numpy RNG `{'.'.join(chain)}`"
    # stdlib random module draws
    if base == STDLIB_RANDOM and chain[-1] not in ("Random", "SystemRandom",
                                                   "seed"):
        return f"stdlib `{'.'.join(chain)}` (process-global RNG)"
    return None


def _collect_set_typed(ctx: Context) -> Set[str]:
    """Names (attributes or locals) assigned set-like values anywhere in
    the scope modules — the index L303 uses to type iteration targets."""
    names: Set[str] = set()
    for path in SCOPE:
        mod = ctx.modules.get(path)
        if mod is None:
            continue
        for node in ast.walk(mod.tree):
            val = None
            tgts: List[ast.expr] = []
            if isinstance(node, ast.Assign):
                val, tgts = node.value, node.targets
            elif isinstance(node, ast.AnnAssign):
                ann = ast.unparse(node.annotation) if node.annotation else ""
                if "set" in ann.lower():
                    tgts = [node.target]
                    val = node.value or ast.Constant(None)
            if val is None:
                continue
            is_setty = isinstance(val, (ast.Set, ast.SetComp)) or (
                isinstance(val, ast.Call) and
                isinstance(val.func, ast.Name) and
                val.func.id in ("set", "frozenset"))
            if not (is_setty or isinstance(node, ast.AnnAssign)):
                continue
            for t in tgts:
                if isinstance(t, ast.Name):
                    names.add(t.id)
                elif isinstance(t, ast.Attribute):
                    names.add(t.attr)
    return names


def _terminal_name(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class _SerialVisitor:
    """L303 inside one serialization function."""

    def __init__(self, mod: Module, qual: str, set_typed: Set[str]):
        self.mod = mod
        self.qual = qual
        self.set_typed = set_typed
        self.findings: List[Finding] = []
        # comprehensions/calls sitting directly under an order-insensitive
        # reducer are fine: sorted(x for x in some_set)
        self.absorbed: Set[int] = set()

    def visit(self, fn: ast.AST) -> List[Finding]:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in ORDER_INSENSITIVE:
                for a in node.args:
                    for sub in ast.walk(a):
                        self.absorbed.add(id(sub))
        for node in ast.walk(fn):
            if isinstance(node, ast.For):
                self._check_iter(node.iter, node)
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                                   ast.SetComp)):
                if id(node) in self.absorbed:
                    continue
                for gen in node.generators:
                    self._check_iter(gen.iter, node)
                    self._check_dict_view(gen.iter, node)
        return self.findings

    def _check_iter(self, it: ast.expr, site: ast.AST) -> None:
        if id(it) in self.absorbed:
            return
        if isinstance(it, ast.Call):
            chain = attr_chain(it.func)
            if chain and chain[-1] == "sorted":
                return
            if isinstance(it.func, ast.Name) and \
                    it.func.id in ORDER_INSENSITIVE:
                return
            return      # other calls: unknown type, stay quiet
        name = _terminal_name(it)
        if name is not None and name in self.set_typed:
            self.findings.append(Finding(
                "L303", self.mod.path, getattr(site, "lineno", 0),
                self.qual,
                f"iteration over set-typed `{self.mod.segment(it)}` "
                f"feeds a serialized record (wrap in sorted())"))

    def _check_dict_view(self, it: ast.expr, site: ast.AST) -> None:
        if id(it) in self.absorbed:
            return
        if isinstance(it, ast.Call) and \
                isinstance(it.func, ast.Attribute) and \
                it.func.attr in DICT_VIEWS:
            self.findings.append(Finding(
                "L303", self.mod.path, getattr(site, "lineno", 0),
                self.qual,
                f"list-materialized dict view "
                f"`{self.mod.segment(it)}` feeds a serialized record "
                f"(sort or emit a dict)"))


def run(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    set_typed = _collect_set_typed(ctx)
    for path in SCOPE:
        mod = ctx.modules.get(path)
        if mod is None:
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if _wall_clock_call(node):
                qual = enclosing_qualname(mod.tree, node)
                out.append(Finding(
                    "L301", mod.path, node.lineno, qual,
                    f"wall-clock `{mod.segment(node.func)}` on the "
                    f"replay path (use time.monotonic or inject `now`)"))
            why = _unseeded_rng(node, mod, ctx)
            if why:
                qual = enclosing_qualname(mod.tree, node)
                out.append(Finding("L302", mod.path, node.lineno, qual,
                                   f"{why} on the replay path"))
        for qual, fn in ctx.functions[mod.path].items():
            if SERIAL_FN_RE.search(qual.split(".")[-1]):
                out.extend(_SerialVisitor(mod, qual, set_typed).visit(fn))
    return out
