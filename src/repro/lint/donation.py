"""donation-safety pass (L501): donated buffers die at the call.

``jax.jit(..., donate_argnums=...)`` hands the argument's device buffer
to XLA for reuse; touching that Python reference afterwards reads freed
memory (JAX raises on CPU, silently corrupts on some backends). The
engines' convention is to *rebind in the same statement* —
``self.state, packed = self._tick(self.params, self.state, poison)`` —
so the dead reference is unreachable by construction.

The pass resolves donating callables three ways:

* direct bindings: ``f = jax.jit(impl, donate_argnums=(0,))`` (local
  name) or ``self._x = jax.jit(...)`` (attribute),
* factory methods whose returned value is such a jit (the engine's
  ``_tick_for``/``_admit_exe`` pattern, including ``donate_argnums=
  self._donate()`` resolved through the ``_donate`` method's literal
  return), bound via ``self._tick = self._tick_for(k)`` or called
  inline as ``self._admit_exe(b)(args...)``.

At each call site, every donated positional argument must either be
rebound by the enclosing assignment's targets or never be read again in
the enclosing function after the call statement.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .base import Context, Finding, Module, attr_chain, enclosing_qualname

NAME = "donation-safety"


def _is_jit_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = attr_chain(node.func)
    return bool(chain) and chain[-1] == "jit"


def _literal_ints(node: ast.expr) -> Optional[Tuple[int, ...]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    return None


def _donate_positions(jit_call: ast.Call, mod: Module,
                      funcs: Dict[str, ast.AST],
                      enclosing: str) -> Optional[Tuple[int, ...]]:
    for kw in jit_call.keywords:
        if kw.arg != "donate_argnums":
            continue
        lit = _literal_ints(kw.value)
        if lit is not None:
            return lit
        # self._donate()-style indirection: resolve the method's literal
        # returns (the engine centralizes its donation policy there)
        if isinstance(kw.value, ast.Call):
            chain = attr_chain(kw.value.func)
            if chain and chain[0] == "self" and len(chain) == 2:
                segs = enclosing.split(".")
                for n in range(len(segs), 0, -1):
                    cand = ".".join(segs[:n - 1] + [chain[1]]) \
                        if n > 1 else chain[1]
                    fn = funcs.get(cand)
                    if fn is not None:
                        for ret in ast.walk(fn):
                            if isinstance(ret, ast.Return) and \
                                    ret.value is not None:
                                lit = _literal_ints(ret.value)
                                if lit is not None:
                                    return lit
        return None
    return None


class _Donors:
    """Resolved donating callables for one module."""

    def __init__(self) -> None:
        self.attrs: Dict[str, Tuple[int, ...]] = {}     # self.X(...)
        self.locals: Dict[str, Tuple[int, ...]] = {}    # f(...)
        self.factories: Dict[str, Tuple[int, ...]] = {}  # self.F(...)(...)


def _collect_donors(ctx: Context, mod: Module) -> _Donors:
    donors = _Donors()
    funcs = ctx.functions[mod.path]

    # factories: methods any of whose returns is/aliases a donating jit
    for qual, fn in funcs.items():
        jit_by_name: Dict[str, Tuple[int, ...]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _is_jit_call(node.value):
                pos = _donate_positions(node.value, mod, funcs, qual)
                if pos:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            jit_by_name[t.id] = pos
                        elif isinstance(t, ast.Attribute):
                            donors.attrs[t.attr] = pos
        for node in ast.walk(fn):
            if not isinstance(node, ast.Return) or node.value is None:
                continue
            if _is_jit_call(node.value):
                pos = _donate_positions(node.value, mod, funcs, qual)
                if pos:
                    donors.factories[qual.split(".")[-1]] = pos
            elif isinstance(node.value, ast.Name) and \
                    node.value.id in jit_by_name:
                donors.factories[qual.split(".")[-1]] = \
                    jit_by_name[node.value.id]

    # attribute/local bindings at any scope (incl. module level)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and _is_jit_call(node.value):
            qual = enclosing_qualname(mod.tree, node)
            pos = _donate_positions(node.value, mod, funcs, qual)
            if not pos:
                continue
            for t in node.targets:
                if isinstance(t, ast.Attribute):
                    donors.attrs[t.attr] = pos
                elif isinstance(t, ast.Name):
                    donors.locals[t.id] = pos
        # self.X = self.<factory>(...): X donates like the factory
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            chain = attr_chain(node.value.func)
            if chain and chain[0] == "self" and len(chain) == 2 and \
                    chain[1] in donors.factories:
                for t in node.targets:
                    if isinstance(t, ast.Attribute):
                        donors.attrs[t.attr] = donors.factories[chain[1]]
                    elif isinstance(t, ast.Name):
                        donors.locals[t.id] = donors.factories[chain[1]]
    return donors


def _donated_call(node: ast.Call, donors: _Donors
                  ) -> Optional[Tuple[int, ...]]:
    """Donation positions if this call invokes a donating callable."""
    f = node.func
    if isinstance(f, ast.Attribute):
        chain = attr_chain(f)
        if chain and chain[0] == "self" and len(chain) == 2 and \
                chain[1] in donors.attrs:
            return donors.attrs[chain[1]]
    if isinstance(f, ast.Name) and f.id in donors.locals:
        return donors.locals[f.id]
    # factory-call-call: self._admit_exe(b)(params, state, ...)
    if isinstance(f, ast.Call):
        fchain = attr_chain(f.func)
        if fchain and fchain[0] == "self" and len(fchain) == 2 and \
                fchain[1] in donors.factories:
            return donors.factories[fchain[1]]
    return None


def _stmt_of(fn: ast.AST, call: ast.Call) -> Optional[ast.stmt]:
    for st in ast.walk(fn):
        if isinstance(st, ast.stmt) and \
                any(sub is call for sub in ast.walk(st)) and \
                not isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # innermost simple statement containing the call
            inner = [s for s in ast.walk(st)
                     if isinstance(s, ast.stmt) and s is not st and
                     any(sub is call for sub in ast.walk(s))]
            if not inner:
                return st
    return None


def _reads_of(nodes: List[ast.stmt], spelling: str) -> List[ast.AST]:
    hits: List[ast.AST] = []
    for st in nodes:
        for sub in ast.walk(st):
            if isinstance(sub, (ast.Name, ast.Attribute)):
                try:
                    if ast.unparse(sub) == spelling:
                        hits.append(sub)
                except Exception:   # pragma: no cover - defensive
                    pass
    return hits


def _check_function(mod: Module, qual: str, fn: ast.AST,
                    donors: _Donors) -> List[Finding]:
    out: List[Finding] = []
    body: List[ast.stmt] = list(getattr(fn, "body", []))
    for call in ast.walk(fn):
        if not isinstance(call, ast.Call):
            continue
        pos = _donated_call(call, donors)
        if pos is None:
            continue
        stmt = _stmt_of(fn, call)
        if stmt is None:
            continue
        targets: Set[str] = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                for sub in ast.walk(t):
                    if isinstance(sub, (ast.Name, ast.Attribute)):
                        try:
                            targets.add(ast.unparse(sub))
                        except Exception:  # pragma: no cover
                            pass
        for p in pos:
            if p >= len(call.args):
                continue
            arg = call.args[p]
            if not isinstance(arg, (ast.Name, ast.Attribute)):
                continue        # fresh temporaries can't be reused
            spelling = ast.unparse(arg)
            if spelling in targets:
                continue        # rebound in the same statement: safe
            # scan the remainder of the function for reads
            later = _later_stmts(fn, stmt)
            hits = _reads_of(later, spelling)
            if hits:
                out.append(Finding(
                    "L501", mod.path, hits[0].lineno, qual,
                    f"`{spelling}` donated at line {call.lineno} "
                    f"(donate_argnums position {p}) is read again "
                    f"afterwards"))
    return out


def _later_stmts(fn: ast.AST, stmt: ast.stmt) -> List[ast.stmt]:
    """Statements that can execute after ``stmt`` in ``fn``: siblings
    after it at every nesting level, plus the bodies of enclosing loops
    (the next iteration re-reads)."""
    out: List[ast.stmt] = []

    def walk(body: List[ast.stmt], in_loop: bool) -> bool:
        found = False
        for i, st in enumerate(body):
            contains = any(sub is stmt for sub in ast.walk(st))
            if st is stmt or contains:
                found = True
                if st is not stmt:
                    for blk, looped in _blocks(st):
                        if walk(blk, looped or in_loop) and looped:
                            out.extend(blk)
                out.extend(body[i + 1:])
                return found
        return found

    def _blocks(st: ast.stmt):
        if isinstance(st, (ast.For, ast.While)):
            yield st.body, True
            yield st.orelse, False
        elif isinstance(st, ast.If):
            yield st.body, False
            yield st.orelse, False
        elif isinstance(st, ast.With):
            yield st.body, False
        elif isinstance(st, ast.Try):
            yield st.body, False
            for h in st.handlers:
                yield h.body, False
            yield st.orelse, False
            yield st.finalbody, False

    walk(list(getattr(fn, "body", [])), False)
    # dedupe while keeping order
    seen: Set[int] = set()
    uniq: List[ast.stmt] = []
    for st in out:
        if id(st) not in seen and st is not stmt:
            seen.add(id(st))
            uniq.append(st)
    return uniq


def run(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for mod in ctx.modules.values():
        donors = _collect_donors(ctx, mod)
        if not (donors.attrs or donors.locals or donors.factories):
            continue
        for qual, fn in ctx.functions[mod.path].items():
            out.extend(_check_function(mod, qual, fn, donors))
    return out
