"""trace-purity pass (L101-L105): no host syncs reachable from jit.

Discovers every jit root in the repo — ``@jax.jit`` /
``@functools.partial(jax.jit, static_argnames=...)`` decorators,
``jax.jit(fn, ...)`` call sites (including the engine's factory pattern,
where ``jax.jit(self._make_tick_impl(k))`` wraps functions *returned* by
the factory) — then walks the call graph from each root with a simple
per-argument taint: a root's non-static parameters are traced values, and
anything computed from a traced value is traced. Host-sync constructs on
traced values (``.item()``, ``float()/int()/bool()``, ``np.*``/``math.*``
calls, Python ``if``/``while``/``assert``, ``print``) would silently add
device→host transfers inside the tick, so they are findings.

Deliberately NOT findings: ``.shape``/``.dtype``/``.ndim``/``.size``
chains (static under trace), ``len()``, ``x is None`` checks (static),
and anything inside ``pl.pallas_call`` kernel bodies (Refs can't sync).
"""

from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .base import Context, Finding, Module, attr_chain

NAME = "trace-purity"

HOST_SYNC_ATTRS = {"item", "tolist", "to_py", "__array__"}
HOST_CASTS = {"float", "int", "bool", "complex"}
HOST_MODULE_PREFIXES = ("numpy", "math")
DEVICE_MODULE_PREFIXES = ("jax", "jax.numpy", "jax.lax")
SHAPE_ATTRS = {"shape", "dtype", "ndim", "size", "weak_type", "sharding",
               "itemsize", "nbytes"}
UNTAINTED_BUILTINS = {"len", "range", "enumerate", "isinstance", "type",
                      "hasattr", "getattr", "zip", "slice", "id", "repr",
                      "str"}
MAX_DEPTH = 40


def _is_jit_chain(chain: Optional[List[str]]) -> bool:
    return bool(chain) and chain[-1] == "jit"


def _static_names(call: ast.Call) -> Set[str]:
    """Parameter names marked static via static_argnames=..."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
    return out


def _static_nums(call: ast.Call) -> Set[int]:
    out: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    out.add(n.value)
    return out


class _Root:
    def __init__(self, module: Module, fn: ast.AST, qual: str,
                 static_names: Set[str], static_nums: Set[int]):
        self.module = module
        self.fn = fn
        self.qual = qual
        self.static_names = static_names
        self.static_nums = static_nums


def _find_roots(ctx: Context) -> List[_Root]:
    roots: List[_Root] = []
    for mod in ctx.modules.values():
        funcs = ctx.functions[mod.path]
        qual_of = {id(fn): q for q, fn in funcs.items()}

        def local_def(name: str, near_qual: str) -> Optional[Tuple[str, ast.AST]]:
            # prefer the candidate sharing the longest qualname prefix with
            # the jit call's own scope (nested defs shadow module-level)
            cands = [(q, f) for q, f in funcs.items()
                     if q.split(".")[-1] == name]
            if not cands:
                return None
            def score(q: str) -> int:
                a, b = q.split("."), near_qual.split(".")
                n = 0
                while n < min(len(a), len(b)) and a[n] == b[n]:
                    n += 1
                return n
            return max(cands, key=lambda qf: score(qf[0]))

        for node in ast.walk(mod.tree):
            # decorator form
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    sn: Set[str] = set()
                    nums: Set[int] = set()
                    hit = False
                    if _is_jit_chain(attr_chain(dec)):
                        hit = True
                    elif isinstance(dec, ast.Call):
                        dchain = attr_chain(dec.func)
                        if _is_jit_chain(dchain):
                            hit = True
                            sn, nums = _static_names(dec), _static_nums(dec)
                        elif dchain and dchain[-1] == "partial" and dec.args \
                                and _is_jit_chain(attr_chain(dec.args[0])):
                            hit = True
                            sn, nums = _static_names(dec), _static_nums(dec)
                    if hit:
                        roots.append(_Root(mod, node,
                                           qual_of.get(id(node), node.name),
                                           sn, nums))
                        break
            # call form: jax.jit(target, ...)
            if isinstance(node, ast.Call) and _is_jit_chain(
                    attr_chain(node.func)) and node.args:
                target = node.args[0]
                sn, nums = _static_names(node), _static_nums(node)
                from .base import enclosing_qualname
                here = enclosing_qualname(mod.tree, node)
                if isinstance(target, ast.Lambda):
                    roots.append(_Root(mod, target, f"{here}.<lambda>"
                                       if here else "<lambda>", sn, nums))
                elif isinstance(target, ast.Name):
                    got = local_def(target.id, here)
                    if got:
                        roots.append(_Root(mod, got[1], got[0], sn, nums))
                elif isinstance(target, ast.Call):
                    # factory indirection: jit(self._make_tick_impl(k)) —
                    # the functions the factory RETURNS are the real roots
                    fchain = attr_chain(target.func)
                    fname = fchain[-1] if fchain else None
                    fac = local_def(fname, here) if fname else None
                    if fac and isinstance(fac[1], (ast.FunctionDef,
                                                   ast.AsyncFunctionDef)):
                        for ret in ast.walk(fac[1]):
                            if not isinstance(ret, ast.Return):
                                continue
                            # unwrap `return a if cond else b` too
                            vals = [ret.value]
                            if isinstance(ret.value, ast.IfExp):
                                vals = [ret.value.body, ret.value.orelse]
                            for v in vals:
                                if isinstance(v, ast.Name):
                                    got = local_def(v.id, fac[0])
                                    if got:
                                        roots.append(_Root(
                                            mod, got[1], got[0], sn, nums))
    return roots


class _Scope:
    """Mutable per-function analysis state."""

    def __init__(self, tainted: Set[str]):
        self.taint = set(tainted)
        self.local_funcs: Dict[str, ast.AST] = {}


class _Analyzer:
    def __init__(self, ctx: Context):
        self.ctx = ctx
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, str, FrozenSet[str], FrozenSet[str]]] = set()

    # -- entry ---------------------------------------------------------------

    def analyze(self, mod: Module, fn: ast.AST, qual: str,
                tainted_params: Set[str],
                closure_taint: FrozenSet[str] = frozenset(),
                depth: int = 0) -> None:
        if depth > MAX_DEPTH:
            return
        key = (mod.path, qual, frozenset(tainted_params), closure_taint)
        if key in self._seen:
            return
        self._seen.add(key)
        scope = _Scope(tainted_params | set(closure_taint))
        body = fn.body if isinstance(fn.body, list) else [ast.Expr(fn.body)]
        # pre-register nested defs so forward calls resolve
        for st in body:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope.local_funcs[st.name] = st
        for st in body:
            self._stmt(st, mod, qual, scope, depth)

    # -- statements ----------------------------------------------------------

    def _stmt(self, node: ast.stmt, mod: Module, qual: str, scope: _Scope,
              depth: int) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scope.local_funcs[node.name] = node
            return
        if isinstance(node, ast.Assign):
            t = self._expr(node.value, mod, qual, scope, depth)
            if isinstance(node.value, ast.Lambda) and \
                    len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                scope.local_funcs[node.targets[0].id] = node.value
            for tgt in node.targets:
                self._bind(tgt, t, node.value, scope)
            return
        if isinstance(node, ast.AnnAssign) and node.value is not None:
            t = self._expr(node.value, mod, qual, scope, depth)
            self._bind(node.target, t, node.value, scope)
            return
        if isinstance(node, ast.AugAssign):
            t = self._expr(node.value, mod, qual, scope, depth)
            if isinstance(node.target, ast.Name) and t:
                scope.taint.add(node.target.id)
            return
        if isinstance(node, (ast.If, ast.While)):
            if self._expr(node.test, mod, qual, scope, depth):
                self._emit("L104", mod, qual, node.test,
                           f"branch on traced value "
                           f"`{mod.segment(node.test)}`")
            for st in node.body + node.orelse:
                self._stmt(st, mod, qual, scope, depth)
            return
        if isinstance(node, ast.Assert):
            if self._expr(node.test, mod, qual, scope, depth):
                self._emit("L104", mod, qual, node.test,
                           f"assert on traced value "
                           f"`{mod.segment(node.test)}`")
            return
        if isinstance(node, ast.For):
            it = self._expr(node.iter, mod, qual, scope, depth)
            self._bind(node.target, it, None, scope)
            for st in node.body + node.orelse:
                self._stmt(st, mod, qual, scope, depth)
            return
        if isinstance(node, ast.With):
            for item in node.items:
                t = self._expr(item.context_expr, mod, qual, scope, depth)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, t, None, scope)
            for st in node.body:
                self._stmt(st, mod, qual, scope, depth)
            return
        if isinstance(node, ast.Try):
            for st in node.body + node.orelse + node.finalbody:
                self._stmt(st, mod, qual, scope, depth)
            for h in node.handlers:
                for st in h.body:
                    self._stmt(st, mod, qual, scope, depth)
            return
        if isinstance(node, ast.Return) and node.value is not None:
            self._expr(node.value, mod, qual, scope, depth)
            return
        if isinstance(node, ast.Expr):
            self._expr(node.value, mod, qual, scope, depth)
            return
        # anything else: visit contained expressions generically
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._expr(child, mod, qual, scope, depth)
            elif isinstance(child, ast.stmt):
                self._stmt(child, mod, qual, scope, depth)

    def _bind(self, target: ast.expr, tainted: bool,
              value: Optional[ast.expr], scope: _Scope) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                scope.taint.add(target.id)
            else:
                scope.taint.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) and \
                    len(value.elts) == len(target.elts):
                for t, v in zip(target.elts, value.elts):
                    self._bind(t, self._quick_taint(v, scope), v, scope)
            else:
                for t in target.elts:
                    self._bind(t, tainted, None, scope)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted, None, scope)
        # Subscript/Attribute targets introduce no new local names

    def _quick_taint(self, node: ast.expr, scope: _Scope) -> bool:
        """Taint of an expr without emitting findings (for tuple unpack)."""
        if isinstance(node, ast.Name):
            return node.id in scope.taint
        if isinstance(node, ast.Attribute):
            if node.attr in SHAPE_ATTRS:
                return False
            return self._quick_taint(node.value, scope)
        return any(self._quick_taint(c, scope)
                   for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    # -- expressions: returns "is this value traced?" ------------------------

    def _expr(self, node: ast.expr, mod: Module, qual: str, scope: _Scope,
              depth: int) -> bool:
        if isinstance(node, ast.Name):
            return node.id in scope.taint
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in SHAPE_ATTRS:
                self._expr(node.value, mod, qual, scope, depth)
                return False
            return self._expr(node.value, mod, qual, scope, depth)
        if isinstance(node, ast.Call):
            return self._call(node, mod, qual, scope, depth)
        if isinstance(node, ast.Compare):
            left = self._expr(node.left, mod, qual, scope, depth)
            rest = [self._expr(c, mod, qual, scope, depth)
                    for c in node.comparators]
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False        # identity checks are static under trace
            return left or any(rest)
        if isinstance(node, ast.IfExp):
            if self._expr(node.test, mod, qual, scope, depth):
                self._emit("L104", mod, qual, node.test,
                           f"conditional expression on traced value "
                           f"`{mod.segment(node.test)}`")
            a = self._expr(node.body, mod, qual, scope, depth)
            b = self._expr(node.orelse, mod, qual, scope, depth)
            return a or b
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            added: Set[str] = set()
            for gen in node.generators:
                it = self._expr(gen.iter, mod, qual, scope, depth)
                if it:
                    for n in ast.walk(gen.target):
                        if isinstance(n, ast.Name) and \
                                n.id not in scope.taint:
                            scope.taint.add(n.id)
                            added.add(n.id)
                for cond in gen.ifs:
                    self._expr(cond, mod, qual, scope, depth)
            if isinstance(node, ast.DictComp):
                out = self._expr(node.key, mod, qual, scope, depth) or \
                    self._expr(node.value, mod, qual, scope, depth)
            else:
                out = self._expr(node.elt, mod, qual, scope, depth)
            scope.taint -= added
            return out
        if isinstance(node, ast.Lambda):
            return False            # analyzed only when called / passed
        if isinstance(node, ast.NamedExpr):
            t = self._expr(node.value, mod, qual, scope, depth)
            self._bind(node.target, t, node.value, scope)
            return t
        # BinOp / BoolOp / UnaryOp / Subscript / Tuple / List / Dict / etc.
        out = False
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                out = self._expr(child, mod, qual, scope, depth) or out
        return out

    # -- calls ---------------------------------------------------------------

    def _call(self, node: ast.Call, mod: Module, qual: str, scope: _Scope,
              depth: int) -> bool:
        arg_taints = [self._expr(a.value if isinstance(a, ast.Starred) else a,
                                 mod, qual, scope, depth)
                      for a in node.args]
        kw_taints = {kw.arg: self._expr(kw.value, mod, qual, scope, depth)
                     for kw in node.keywords}
        any_tainted = any(arg_taints) or any(kw_taints.values())
        chain = attr_chain(node.func)

        # method-style host syncs: x.item(), x.tolist()
        if isinstance(node.func, ast.Attribute):
            recv_taint = self._quick_taint(node.func.value, scope)
            if node.func.attr in HOST_SYNC_ATTRS and recv_taint:
                self._emit("L101", mod, qual, node,
                           f"`.{node.func.attr}()` on traced value "
                           f"`{mod.segment(node.func.value)}`")
                return False

        if isinstance(node.func, ast.Name):
            name = node.func.id
            if name in HOST_CASTS and any_tainted:
                self._emit("L102", mod, qual, node,
                           f"host cast `{name}(...)` on traced value "
                           f"`{mod.segment(node)}`")
                return False
            if name == "print" and any_tainted:
                self._emit("L105", mod, qual, node,
                           f"host print of traced value "
                           f"`{mod.segment(node)}`")
                return False
            if name in UNTAINTED_BUILTINS:
                return False

        # module-qualified calls: host libs flag, device libs taint
        dotted = self._resolve_module(chain, mod)
        if dotted is not None:
            if dotted.startswith(HOST_MODULE_PREFIXES):
                if any_tainted:
                    self._emit("L103", mod, qual, node,
                               f"host-library call "
                               f"`{'.'.join(chain)}` on traced value")
                return any_tainted
            if dotted.startswith(DEVICE_MODULE_PREFIXES):
                if not (chain and chain[0] in ("pl", "pltpu")):
                    self._descend_hofs(node, mod, qual, scope, depth)
                return True

        # repo-internal callee: map taint onto its params and recurse
        target = self._resolve_callee(node, chain, mod, qual, scope)
        if target is not None:
            tmod, tqual, tfn, is_method, closure = target
            params = [a.arg for a in tfn.args.args] \
                if not isinstance(tfn, ast.Lambda) else \
                [a.arg for a in tfn.args.args]
            if is_method and params and params[0] in ("self", "cls"):
                params = params[1:]
            tainted_params: Set[str] = set()
            for i, t in enumerate(arg_taints):
                if isinstance(node.args[i], ast.Starred):
                    if t:
                        tainted_params.update(params[i:])
                elif t and i < len(params):
                    tainted_params.add(params[i])
            for k, t in kw_taints.items():
                if t and k in params:
                    tainted_params.add(k)
            self.analyze(tmod, tfn, tqual, tainted_params, closure,
                         depth + 1)
            return any_tainted

        # unresolved external HOF carrying a local function/lambda argument:
        # analyze that function with all params traced (conservative)
        self._descend_hofs(node, mod, qual, scope, depth)
        # a method call on a traced receiver yields a traced value
        # (st.sum(), x.astype(...), hist.at[i].set(...))
        if isinstance(node.func, ast.Attribute) and \
                self._quick_taint(node.func.value, scope):
            return True
        return any_tainted

    def _descend_hofs(self, node: ast.Call, mod: Module, qual: str,
                      scope: _Scope, depth: int) -> None:
        for a in list(node.args) + [kw.value for kw in node.keywords]:
            fn: Optional[ast.AST] = None
            fq = qual
            if isinstance(a, ast.Lambda):
                fn, fq = a, f"{qual}.<lambda>"
            elif isinstance(a, ast.Name) and a.id in scope.local_funcs:
                fn, fq = scope.local_funcs[a.id], f"{qual}.{a.id}"
            if fn is not None:
                params = {p.arg for p in fn.args.args}
                self.analyze(mod, fn, fq, params,
                             frozenset(scope.taint), depth + 1)

    def _resolve_module(self, chain: Optional[List[str]],
                        mod: Module) -> Optional[str]:
        if not chain or len(chain) < 2:
            return None
        imports = self.ctx.imports[mod.path]
        base = imports.get(chain[0])
        if base is None:
            froms = self.ctx.from_imports[mod.path]
            if chain[0] in froms:
                m, attr = froms[chain[0]]
                return f"{m}.{attr}"
            return None
        return base

    def _resolve_callee(self, node: ast.Call, chain: Optional[List[str]],
                        mod: Module, qual: str, scope: _Scope
                        ) -> Optional[Tuple[Module, str, ast.AST, bool,
                                            FrozenSet[str]]]:
        funcs = self.ctx.functions[mod.path]
        # local nested function (closure taint flows in)
        if isinstance(node.func, ast.Name):
            name = node.func.id
            if name in scope.local_funcs:
                return (mod, f"{qual}.{name}", scope.local_funcs[name],
                        False, frozenset(scope.taint))
            if name in funcs:
                return (mod, name, funcs[name], False, frozenset())
            froms = self.ctx.from_imports[mod.path]
            if name in froms:
                dotted, attr = froms[name]
                other = self.ctx.module_for_dotted(dotted)
                if other is not None and attr in \
                        self.ctx.functions[other.path]:
                    return (other, attr,
                            self.ctx.functions[other.path][attr],
                            False, frozenset())
            return None
        # self.method: try each enclosing qual prefix as the class
        if chain and chain[0] == "self" and len(chain) == 2:
            segs = qual.split(".")
            for n in range(len(segs) - 1, 0, -1):
                cand = ".".join(segs[:n] + [chain[1]])
                if cand in funcs:
                    return (mod, cand, funcs[cand], True, frozenset())
            return None
        # alias.func in another repo module
        if chain and len(chain) == 2:
            dotted = self.ctx.imports[mod.path].get(chain[0])
            if dotted:
                other = self.ctx.module_for_dotted(dotted)
                if other is not None and chain[1] in \
                        self.ctx.functions[other.path]:
                    return (other, chain[1],
                            self.ctx.functions[other.path][chain[1]],
                            False, frozenset())
        return None

    def _emit(self, rule: str, mod: Module, qual: str, node: ast.AST,
              detail: str) -> None:
        self.findings.append(Finding(rule, mod.path,
                                     getattr(node, "lineno", 0), qual,
                                     detail))


def run(ctx: Context) -> List[Finding]:
    an = _Analyzer(ctx)
    for root in _find_roots(ctx):
        fn = root.fn
        args = fn.args.args
        tainted = {a.arg for a in args if a.arg not in ("self", "cls")}
        tainted -= root.static_names
        for i, a in enumerate(args):
            if i in root.static_nums:
                tainted.discard(a.arg)
        an.analyze(root.module, fn, root.qual, tainted)
    # dedupe (same violation reachable from several roots)
    seen: Set[Tuple[str, str, int, str]] = set()
    out: List[Finding] = []
    for f in an.findings:
        k = (f.rule, f.path, f.line, f.detail)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
