"""readback-budget pass (L201-L203): ONE compact readback per tick.

The engines' hot loops are contractually allowed exactly one device→host
transfer per tick, and it must go through the counted funnel
(``ServeEngine._readback`` / ``_checked_readback``, which increment
``host_readbacks`` and validate torn transfers). This pass:

* L201 — counts funnel calls + raw ``jax.device_get`` along every control
  path of each *tick scope* (``ServeEngine.step``, ``TrainEngine.run``)
  with branch-aware max: ``if/elif/else`` arms take the max, sequential
  statements sum, and a loop body counts once (the budget is per tick,
  and ``TrainEngine.run``'s per-tick readback lives in its step loop).
* L202 — flags a readback nested deeper in loops than the scope allows
  (a per-slot readback inside the tick loop is the classic regression).
* L203 — flags raw ``jax.device_get``/``np.asarray``-style transfers in
  the engine modules *outside* the funnel helpers, which would escape the
  ``host_readbacks`` counter and the chaos tier's torn-readback checks.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .base import Context, Finding, Module, attr_chain, enclosing_qualname

NAME = "readback-budget"


@dataclasses.dataclass(frozen=True)
class TickScope:
    path: str               # module repo-relative path
    qualname: str           # tick function
    budget: int = 1         # max readbacks on any one control path
    loop_depth_allowed: int = 0   # loops the per-tick readback may sit in


#: the engines' hot loops and their counted funnels
TICK_SCOPES: Tuple[TickScope, ...] = (
    TickScope("src/repro/serve/engine.py", "ServeEngine.step",
              budget=1, loop_depth_allowed=0),
    TickScope("src/repro/train/engine.py", "TrainEngine.run",
              budget=1, loop_depth_allowed=1),
)

FUNNELS: Dict[str, Set[str]] = {
    "src/repro/serve/engine.py": {"_readback", "_checked_readback"},
    "src/repro/train/engine.py": set(),
}

RAW_TRANSFER_CHAINS = {("jax", "device_get")}


def _is_raw_transfer(node: ast.Call) -> bool:
    chain = attr_chain(node.func)
    return bool(chain) and tuple(chain[-2:]) in RAW_TRANSFER_CHAINS


def _is_funnel_call(node: ast.Call, funnel: Set[str]) -> bool:
    chain = attr_chain(node.func)
    return bool(chain) and chain[-1] in funnel


class _PathCounter:
    """Max readbacks along any single control path through a statement
    list, plus the loop depth of every readback site found."""

    def __init__(self, funnel: Set[str]):
        self.funnel = funnel
        self.sites: List[Tuple[ast.Call, int]] = []   # (call, loop depth)

    def count_body(self, body: List[ast.stmt], loop_depth: int) -> int:
        return sum(self.count_stmt(s, loop_depth) for s in body)

    def count_stmt(self, node: ast.stmt, loop_depth: int) -> int:
        if isinstance(node, ast.If):
            t = self._count_expr(node.test, loop_depth)
            return t + max(self.count_body(node.body, loop_depth),
                           self.count_body(node.orelse, loop_depth))
        if isinstance(node, (ast.For, ast.While)):
            head = self._count_expr(node.iter, loop_depth) \
                if isinstance(node, ast.For) else \
                self._count_expr(node.test, loop_depth)
            # the budget is per tick: a loop body's readbacks count once
            return head + self.count_body(node.body, loop_depth + 1) + \
                self.count_body(node.orelse, loop_depth)
        if isinstance(node, ast.Try):
            return max(self.count_body(node.body, loop_depth),
                       max((self.count_body(h.body, loop_depth)
                            for h in node.handlers), default=0)) + \
                self.count_body(node.orelse, loop_depth) + \
                self.count_body(node.finalbody, loop_depth)
        if isinstance(node, ast.With):
            return sum(self._count_expr(i.context_expr, loop_depth)
                       for i in node.items) + \
                self.count_body(node.body, loop_depth)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return 0        # nested defs are separate call sites
        n = 0
        for child in ast.walk(node):
            if isinstance(child, ast.Call) and (
                    _is_funnel_call(child, self.funnel) or
                    _is_raw_transfer(child)):
                self.sites.append((child, loop_depth))
                n += 1
        return n

    def _count_expr(self, node: Optional[ast.expr], loop_depth: int) -> int:
        if node is None:
            return 0
        n = 0
        for child in ast.walk(node):
            if isinstance(child, ast.Call) and (
                    _is_funnel_call(child, self.funnel) or
                    _is_raw_transfer(child)):
                self.sites.append((child, loop_depth))
                n += 1
        return n


def run(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for scope in TICK_SCOPES:
        mod = ctx.modules.get(scope.path)
        if mod is None:
            continue
        fn = ctx.lookup_function(scope.path, scope.qualname)
        if fn is None:
            continue
        counter = _PathCounter(FUNNELS.get(scope.path, set()))
        worst = counter.count_body(fn.body, 0)
        if worst > scope.budget:
            out.append(Finding(
                "L201", mod.path, fn.lineno, scope.qualname,
                f"{worst} readback sites on a single tick path "
                f"(budget {scope.budget})"))
        for call, depth in counter.sites:
            if depth > scope.loop_depth_allowed:
                out.append(Finding(
                    "L202", mod.path, call.lineno, scope.qualname,
                    f"readback `{mod.segment(call.func)}` at loop depth "
                    f"{depth} (allowed {scope.loop_depth_allowed})"))

    # L203: raw transfers escaping the funnel anywhere in engine modules
    for path, funnel in FUNNELS.items():
        mod = ctx.modules.get(path)
        if mod is None or not funnel:
            continue
        tick_quals = {s.qualname for s in TICK_SCOPES if s.path == path}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and _is_raw_transfer(node):
                qual = enclosing_qualname(mod.tree, node)
                leaf = qual.split(".")[-1] if qual else ""
                if leaf in funnel or qual in tick_quals:
                    continue
                out.append(Finding(
                    "L203", mod.path, node.lineno, qual,
                    f"raw `{mod.segment(node.func)}` outside the counted "
                    f"readback funnel"))
    return out
