"""Pure-JAX functional model zoo.

Every architecture is a (init, apply) pair over plain-dict pytrees; logical
sharding axes are carried in a parallel "axes" pytree produced at init time
(see models.common.Axed). The 10 assigned architectures are all expressible
through models.transformer.LMConfig block schedules (+ encdec for Whisper);
the paper's own CNNs live in models.cnn.
"""

from repro.models import common  # noqa: F401
