"""The paper's own CNN benchmarks: AlexNet and VGG-16.

These are the workloads behind Table 3 / Fig. 2: ternary-quantized inference
(PIM execution model, ELP^2IM/PIRM) and FP32 training (FPIRM / ref [1]).
Implemented NHWC with jax.lax convolutions; FC layers route through the
quantized-matmul path when a quant spec is given (see repro.quant and the
PIM-adapted Pallas kernel in repro.kernels).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Axed, group_dict, leaf


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    features: int
    kernel: int
    stride: int = 1
    padding: str = "SAME"
    pool: int = 0          # maxpool window (0 = none)
    pool_stride: int = 0


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    convs: Tuple[ConvSpec, ...]
    fcs: Tuple[int, ...]
    num_classes: int = 1000
    image_size: int = 224
    in_channels: int = 3
    dropout: float = 0.5   # inference path ignores; train uses rng


ALEXNET = CNNConfig(
    name="alexnet",
    convs=(
        ConvSpec(64, 11, 4, "SAME", pool=3, pool_stride=2),
        ConvSpec(192, 5, 1, "SAME", pool=3, pool_stride=2),
        ConvSpec(384, 3), ConvSpec(256, 3),
        ConvSpec(256, 3, pool=3, pool_stride=2),
    ),
    fcs=(4096, 4096),
)

VGG16 = CNNConfig(
    name="vgg16",
    convs=(
        ConvSpec(64, 3), ConvSpec(64, 3, pool=2, pool_stride=2),
        ConvSpec(128, 3), ConvSpec(128, 3, pool=2, pool_stride=2),
        ConvSpec(256, 3), ConvSpec(256, 3), ConvSpec(256, 3, pool=2, pool_stride=2),
        ConvSpec(512, 3), ConvSpec(512, 3), ConvSpec(512, 3, pool=2, pool_stride=2),
        ConvSpec(512, 3), ConvSpec(512, 3), ConvSpec(512, 3, pool=2, pool_stride=2),
    ),
    fcs=(4096, 4096),
)


def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32) -> Axed:
    parts: Dict[str, Axed] = {}
    c_in = cfg.in_channels
    for i, cs in enumerate(cfg.convs):
        k1, key = jax.random.split(key)
        w = common.fan_in_init(k1, (cs.kernel, cs.kernel, c_in, cs.features),
                               fan_in=cs.kernel * cs.kernel * c_in, dtype=dtype)
        parts[f"conv{i}"] = group_dict({
            "w": leaf(w, "spatial", "spatial", "channels", "channels"),
            "b": leaf(jnp.zeros((cs.features,), dtype), "channels")})
        c_in = cs.features
    # flatten size: run shapes forward
    hw = cfg.image_size
    for cs in cfg.convs:
        hw = -(-hw // cs.stride)
        if cs.pool:
            hw = max((hw - cs.pool) // cs.pool_stride + 1, 1)
    flat = hw * hw * c_in
    dims = (flat,) + tuple(cfg.fcs) + (cfg.num_classes,)
    for i in range(len(dims) - 1):
        k1, key = jax.random.split(key)
        w = common.fan_in_init(k1, (dims[i], dims[i + 1]), dtype=dtype)
        parts[f"fc{i}"] = group_dict({
            "w": leaf(w, "ffn", "ffn"),
            "b": leaf(jnp.zeros((dims[i + 1],), dtype), "ffn")})
    return group_dict(parts)


def _conv_block(p, cs: ConvSpec, x: jnp.ndarray) -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (cs.stride, cs.stride), cs.padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b"].astype(y.dtype))
    if cs.pool:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, cs.pool, cs.pool, 1),
            (1, cs.pool_stride, cs.pool_stride, 1), "VALID")
    return y


def forward(params, cfg: CNNConfig, images: jnp.ndarray, *,
            train: bool = False, rng: Optional[jax.Array] = None,
            matmul_fn=None) -> jnp.ndarray:
    """images: (B,H,W,C) -> logits (B,num_classes).

    ``matmul_fn(x, w) -> y`` overrides FC matmuls (quantized / Pallas path).
    """
    mm = matmul_fn or (lambda a, w: a @ w.astype(a.dtype))
    x = images
    for i, cs in enumerate(cfg.convs):
        x = _conv_block(params[f"conv{i}"], cs, x)
    x = x.reshape(x.shape[0], -1)
    n_fc = len(cfg.fcs) + 1
    for i in range(n_fc):
        p = params[f"fc{i}"]
        x = mm(x, p["w"]) + p["b"].astype(x.dtype)
        if i < n_fc - 1:
            x = jax.nn.relu(x)
            if train and rng is not None and cfg.dropout > 0:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1 - cfg.dropout, x.shape)
                x = jnp.where(keep, x / (1 - cfg.dropout), 0.0)
    return x


def loss_fn(params, cfg: CNNConfig, batch: Dict[str, jnp.ndarray],
            rng: Optional[jax.Array] = None) -> Tuple[jnp.ndarray, Dict]:
    logits = forward(params, cfg, batch["images"], train=True, rng=rng)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = jnp.mean(logz - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return ce, {"ce": ce, "acc": acc}


def flops_per_image(cfg: CNNConfig) -> float:
    """Analytic MACs*2 per image (for GFLOPS-style throughput accounting)."""
    fl = 0.0
    hw = cfg.image_size
    c_in = cfg.in_channels
    for cs in cfg.convs:
        hw_out = -(-hw // cs.stride)
        fl += 2.0 * hw_out * hw_out * cs.kernel * cs.kernel * c_in * cs.features
        hw = hw_out
        if cs.pool:
            hw = max((hw - cs.pool) // cs.pool_stride + 1, 1)
        c_in = cs.features
    flat = hw * hw * c_in
    dims = (flat,) + tuple(cfg.fcs) + (cfg.num_classes,)
    for i in range(len(dims) - 1):
        fl += 2.0 * dims[i] * dims[i + 1]
    return fl
