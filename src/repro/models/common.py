"""Functional-module substrate: params as dict pytrees + logical-axis trees.

No flax/haiku on this box — we roll a minimal, explicit system:

* a module's ``init(key, cfg) -> Axed`` returns ``Axed(params, axes)`` where
  ``axes`` mirrors ``params`` with a tuple of logical axis names per leaf
  (``None`` entries for never-sharded dims).
* ``apply(params, ...)`` is a plain function.
* ``parallel.sharding`` maps logical axes -> mesh axes with divisibility
  fallbacks to produce PartitionSpec trees.

Logical axis vocabulary (single source of truth: AXES):
  batch seq vocab embed heads kv_heads head_dim ffn experts stack
  ssm_inner ssm_state ssm_group conv spatial channels
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

AXES = frozenset({
    "batch", "seq", "seq_tp", "vocab", "embed", "heads", "kv_heads", "head_dim",
    "ffn", "experts", "stack", "ssm_inner", "ssm_state", "ssm_group",
    "conv", "spatial", "channels", None,
})


def _freeze_axes(x):
    """Axes tree (nested dicts of axis-name tuples) -> hashable static form."""
    if isinstance(x, dict):
        return ("d", tuple(sorted((k, _freeze_axes(v)) for k, v in x.items())))
    if isinstance(x, tuple):
        return ("t", tuple(_freeze_axes(v) if isinstance(v, (dict, tuple)) else v
                           for v in x))
    return x


def _thaw_axes(x):
    if isinstance(x, tuple) and len(x) == 2 and x[0] == "d":
        return {k: _thaw_axes(v) for k, v in x[1]}
    if isinstance(x, tuple) and len(x) == 2 and x[0] == "t":
        return tuple(_thaw_axes(v) if isinstance(v, tuple) else v for v in x[1])
    return x


@dataclasses.dataclass
class Axed:
    """A params pytree together with its logical-axes pytree (same structure).

    Registered as a JAX pytree: ``params`` are the children, ``axes`` ride
    along as hashable static aux data — so init functions stay traceable
    (eval_shape / vmap / jit all work on functions returning Axed).
    """
    params: PyTree
    axes: PyTree

    def map_params(self, fn: Callable[[jnp.ndarray], jnp.ndarray]) -> "Axed":
        return Axed(jax.tree.map(fn, self.params), self.axes)


jax.tree_util.register_pytree_node(
    Axed,
    lambda a: ((a.params,), _freeze_axes(a.axes)),
    lambda aux, children: Axed(children[0], _thaw_axes(aux)),
)


def leaf(value: jnp.ndarray, *axes: Optional[str]) -> Axed:
    if len(axes) != value.ndim:
        raise ValueError(f"{len(axes)} axes for rank-{value.ndim} param")
    for a in axes:
        if a not in AXES:
            raise ValueError(f"unknown logical axis {a!r}")
    return Axed(value, tuple(axes))


def group(**kv: Axed) -> Axed:
    """Combine child Axed values into a dict node."""
    return Axed({k: v.params for k, v in kv.items()},
                {k: v.axes for k, v in kv.items()})


def group_dict(kv: Dict[str, Axed]) -> Axed:
    return Axed({k: v.params for k, v in kv.items()},
                {k: v.axes for k, v in kv.items()})


def stack_axed(items: Sequence[Axed]) -> Axed:
    """Stack identically-structured Axed pytrees along a new leading 'stack'
    dim (the scan-over-layers layout)."""
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[i.params for i in items])
    axes = jax.tree.map(
        lambda a: ("stack",) + a if isinstance(a, tuple) else a,
        items[0].axes, is_leaf=lambda x: isinstance(x, tuple))
    return Axed(params, axes)


def vmap_init(init_fn: Callable[[jax.Array], Axed], key: jax.Array,
              n: int) -> Axed:
    """Initialize ``n`` stacked copies of a module (scan layout) via vmap."""
    keys = jax.random.split(key, n)
    example = jax.eval_shape(init_fn, keys[0])
    params = jax.vmap(lambda k: init_fn(k).params)(keys)
    axes = jax.tree.map(
        lambda a: ("stack",) + a if isinstance(a, tuple) else a,
        example.axes, is_leaf=lambda x: isinstance(x, tuple))
    return Axed(params, axes)


# -----------------------------------------------------------------------------
# Initializers
# -----------------------------------------------------------------------------

def trunc_normal(key: jax.Array, shape: Sequence[int], stddev: float,
                 dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def fan_in_init(key: jax.Array, shape: Sequence[int], fan_in: Optional[int] = None,
                dtype=jnp.float32) -> jnp.ndarray:
    fi = fan_in if fan_in is not None else int(np.prod(shape[:-1])) or 1
    return trunc_normal(key, shape, 1.0 / math.sqrt(fi), dtype)


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Mixed-precision policy: bf16 params/compute, fp32 reductions/master."""
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32

    def cast_compute(self, x: jnp.ndarray) -> jnp.ndarray:
        return x.astype(self.compute_dtype)


FP32 = DTypePolicy(jnp.float32, jnp.float32, jnp.float32)
BF16 = DTypePolicy(jnp.bfloat16, jnp.bfloat16, jnp.float32)


# -----------------------------------------------------------------------------
# Pytree utilities
# -----------------------------------------------------------------------------

def count_params(params: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def param_bytes(params: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(params))


def tree_paths(params: PyTree) -> Dict[str, Tuple[int, ...]]:
    out = {}
    for path, x in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[name] = tuple(x.shape)
    return out


def assert_finite(tree: PyTree, what: str = "tree") -> None:
    for path, x in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if not bool(jnp.isfinite(x).all()):
            name = "/".join(str(getattr(p, "key", p)) for p in path)
            raise AssertionError(f"non-finite values in {what}:{name}")
