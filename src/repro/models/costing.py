"""Dtype-aware modeled traffic/compute for LM workloads (DESIGN.md §12/§13).

The paper's core claim — per-byte data movement, not FLOPs, bounds edge
energy — needs the runtime to *bill* bytes and FLOPs from the actual
resident arrays. This module is the shared cost model: the serve engine
bills its per-tick decode/prefill traffic through it, the train engine its
per-step forward/backward/optimizer phases. Formulas are deliberately
simple enough to recompute by hand (tests/test_train_accounting.py pins
them):

* a weight of E elements costs 2E FLOPs per token regardless of storage
  dtype (int8 changes bytes, not FLOPs);
* causal full-sequence attention costs 2 * n_attn * (H*Dh) * S FLOPs per
  token (the causal half of the 4x qk+pv term);
* the backward costs 2x the forward's FLOPs (grad-wrt-input + grad-wrt-
  weight matmuls per forward matmul);
* forward streams the weight tree once; backward streams it again (dx
  needs W^T) and writes fp32 grads; the optimizer reads grads, reads+
  writes its state, and reads+writes params.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.core import energy
from repro.models import transformer as tf_lib

PyTree = Any


def tree_bytes(tree: PyTree) -> int:
    """Resident bytes of a pytree — dtype-aware (int8 leaves bill 1 byte)."""
    return sum(int(leaf.nbytes) for leaf in jax.tree.leaves(tree))


def kv_bytes(caches: PyTree) -> int:
    """Bytes of the K/V payload (codes + scales; excludes position tags)."""
    total = 0
    for entry in caches.values():
        for key in ("kv", "kv_scale"):
            if key in entry:
                total += tree_bytes(entry[key])
    return total


def matmul_weight_elems(params: PyTree, cfg: tf_lib.LMConfig) -> float:
    """Logical matmul-weight elements executed per token (a weight of E
    elements costs 2E FLOPs/token regardless of storage dtype — int8
    changes bytes, not FLOPs). MoE experts count at their top_k/n_experts
    activation fraction; includes the unembedding projection; excludes
    norms/biases."""
    from repro.quant.int8 import SERVING_QUANT_KEYS
    total = 0.0
    moe_frac = (cfg.moe_cfg.top_k / cfg.moe_cfg.n_experts
                if cfg.moe_cfg is not None else 1.0)

    def walk(p, frac):
        nonlocal total
        for k, v in p.items():
            if isinstance(v, dict):
                if "q8" in v:
                    if k in SERVING_QUANT_KEYS:
                        total += frac * int(v["q8"].size)
                else:
                    walk(v, moe_frac if k == "moe" else frac)
            elif k in SERVING_QUANT_KEYS and getattr(v, "ndim", 0) >= 2:
                total += frac * int(v.size)

    walk(params, 1.0)
    if cfg.tie_embeddings:
        total += int(params["embed"]["w"].size)
    else:
        total += int(params["unembed"]["w"].size)
    return total


def attn_layers(cfg: tf_lib.LMConfig) -> int:
    pat = sum(1 for sp in cfg.pattern if sp.kind == "attn") * cfg.repeats
    return pat + sum(1 for sp in cfg.tail if sp.kind == "attn")


def decode_tick_flops(matmul_elems: float, n_attn: int, attn_dims: int,
                      ctx_sum: float, n_active: int) -> float:
    """Modeled FLOPs of one plain decode tick: every active slot streams
    the matmul weights for one token and attends its live context
    (``ctx_sum`` = sum over active slots of prompt + generated so far)."""
    return (2.0 * matmul_elems * n_active
            + 4.0 * n_attn * attn_dims * ctx_sum)


def block_recompute_flops(matmul_elems: float, n_attn: int, attn_dims: int,
                          start_tok: int, n_tok: int) -> float:
    """Modeled FLOPs to *recompute* one cached KV block of ``n_tok`` tokens
    whose first token sits at absolute position ``start_tok`` (=
    block depth x page size). Each token streams the matmul weights once
    and causally attends its own prefix, so deeper blocks are strictly
    more expensive to regenerate:

        2 * matmul_elems * n_tok
        + 4 * n_attn * attn_dims * sum_{p=start}^{start+n-1} (p + 1).

    The cost-aware eviction policy (DESIGN.md §16) divides this by the
    block's resident bytes to get recompute-FLOPs-per-byte; since every
    block in a pool has identical byte size, ranking by this value alone
    preserves the per-byte ordering."""
    n = float(n_tok)
    attn_keys = n * float(start_tok) + n * (n + 1.0) / 2.0
    return 2.0 * matmul_elems * n + 4.0 * n_attn * attn_dims * attn_keys


def prefill_span_flops(matmul_elems: float, n_attn: int, attn_dims: int,
                       start: float, n_tok: float) -> float:
    """Modeled FLOPs of ONE prefill row's chunk ``[start, start + n_tok)``:
    each token streams the matmul weights once, causal attention over the
    span sums to the ``end^2 - start^2`` form the engine's aggregate
    admission bill already uses — this is the same formula factored
    per-row, so the chaos tier can bill a quarantined slot's re-prefill
    (its *recovery* energy, DESIGN.md §17) with exactly the admission
    path's arithmetic."""
    end = float(start) + float(n_tok)
    return (2.0 * matmul_elems * float(n_tok)
            + 2.0 * n_attn * attn_dims * (end * end - float(start) ** 2))


def spec_verify_flops(matmul_elems: float, n_attn: int, attn_dims: int,
                      ctx_sum: float, n_active: int, width: int) -> float:
    """Modeled FLOPs of one speculative verification pass (DESIGN.md §15):
    a q-block of ``width`` tokens per active slot through the matmul
    weights ONCE, with causal attention — lane t of a slot at live context
    c attends c + t keys, so the attention term is
    ``sum_t (c + t) = width*c + width*(width-1)/2`` per slot."""
    return (2.0 * matmul_elems * width * n_active
            + 4.0 * n_attn * attn_dims
            * (width * ctx_sum + n_active * width * (width - 1) / 2.0))


def spec_oracle_draft_flops(matmul_elems: float, n_attn: int, attn_dims: int,
                            ctx_sum: float, n_active: int, k: int) -> float:
    """Modeled FLOPs of the ``oracle`` drafter: ``k`` sequential plain
    decode passes of the target model itself, context growing by one per
    pass — the accept-all harness's honest (weight-heavy) draft bill."""
    return sum(decode_tick_flops(matmul_elems, n_attn, attn_dims,
                                 ctx_sum + j * n_active, n_active)
               for j in range(k))


def expected_replay_ticks(interval: int) -> float:
    """Expected ticks of journal replay a warm restart pays, for a crash
    uniform over the checkpoint cycle (DESIGN.md §19): snapshots land
    every ``interval`` ticks, so the tail since the last snapshot is
    uniform on ``[0, interval)`` with mean ``(interval - 1) / 2``.
    0.0 when checkpointing is off — there is nothing to replay into."""
    if interval <= 0:
        return 0.0
    return (float(interval) - 1.0) / 2.0


def durability_overhead_bytes_per_tick(snapshot_bytes: float,
                                       journal_bytes_per_tick: float,
                                       interval: int) -> float:
    """Steady-state durability write traffic per tick: every tick appends
    a journal record; every ``interval`` ticks a full snapshot lands. The
    measurable knob behind the checkpoint-interval tradeoff — shrink the
    interval and write overhead rises while
    :func:`expected_replay_ticks` (recovery recompute) falls."""
    amortized = (float(snapshot_bytes) / float(interval)
                 if interval > 0 else 0.0)
    return float(journal_bytes_per_tick) + amortized


def lm_train_step_cost(params: PyTree, cfg: tf_lib.LMConfig, *,
                       batch: int, seq_len: int,
                       opt_state: PyTree = None) -> energy.TrainStepCost:
    """Per-optimizer-step modeled cost for one LM training step.

    ``params`` is the live (dtype-bearing) weight tree, ``opt_state`` the
    optimizer state tree (its resident bytes bill the update phase).
    """
    tokens = float(batch) * float(seq_len)
    w_elems = matmul_weight_elems(params, cfg)
    attn_dims = cfg.n_heads * cfg.resolved_head_dim
    attn_flops_tok = 2.0 * attn_layers(cfg) * attn_dims * seq_len
    fwd_flops = (2.0 * w_elems + attn_flops_tok) * tokens
    weight_bytes = float(tree_bytes(params))
    n_params = float(sum(int(l.size) for l in jax.tree.leaves(params)))
    grad_bytes = 4.0 * n_params                    # grads are fp32
    opt_bytes_ = float(tree_bytes(opt_state)) if opt_state is not None else 0.0
    return energy.TrainStepCost(
        fwd_flops=fwd_flops,
        bwd_flops=2.0 * fwd_flops,
        fwd_bytes=weight_bytes,
        bwd_bytes=weight_bytes + grad_bytes,
        opt_bytes=grad_bytes + 2.0 * opt_bytes_ + 2.0 * weight_bytes,
        tokens=tokens,
        samples=float(batch),
    )
