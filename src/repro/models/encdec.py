"""Encoder-decoder transformer (Whisper-large-v3 backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed mel-frame embeddings (B, n_audio_ctx, d_model). The encoder adds
sinusoidal positions and runs bidirectional attention; the decoder runs causal
self-attention + cross-attention with learned positions.

Decode uses self-attn KV caches plus precomputed cross-attn K/V ("cross
cache") built at prefill from the encoder output.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, layers
from repro.models.common import Axed, group_dict
from repro.models.layers import AttnConfig, KVCache


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_audio_ctx: int = 1500
    act: str = "gelu"
    sp_attention: bool = False   # 20 heads don't divide 16: context parallel

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab + 127) // 128) * 128

    def attn_cfg(self, causal: bool) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_heads, head_dim=self.head_dim,
                          qkv_bias=True, causal=causal, pos_emb="none",
                          sp=self.sp_attention)

    @property
    def n_layers(self) -> int:
        return self.n_enc_layers + self.n_dec_layers


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    """Whisper's sinusoidal position embedding."""
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    scaled = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def _init_enc_block(key, cfg: EncDecConfig, dtype) -> Axed:
    k1, k2 = jax.random.split(key)
    return group_dict({
        "norm_attn": layers.init_layernorm(cfg.d_model),
        "attn": layers.init_attention(k1, cfg.attn_cfg(causal=False), dtype),
        "norm_ffn": layers.init_layernorm(cfg.d_model),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    })


def _init_dec_block(key, cfg: EncDecConfig, dtype) -> Axed:
    k1, k2, k3 = jax.random.split(key, 3)
    return group_dict({
        "norm_self": layers.init_layernorm(cfg.d_model),
        "self_attn": layers.init_attention(k1, cfg.attn_cfg(causal=True), dtype),
        "norm_cross": layers.init_layernorm(cfg.d_model),
        "cross_attn": layers.init_attention(k2, cfg.attn_cfg(causal=False), dtype),
        "norm_ffn": layers.init_layernorm(cfg.d_model),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False, dtype=dtype),
    })


def init_encdec(key, cfg: EncDecConfig, dtype=jnp.bfloat16) -> Axed:
    keys = jax.random.split(key, 6)
    max_dec_pos = 32768  # learned decoder positions (sized for the shape grid)
    return group_dict({
        "embed": layers.init_embed(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "pos_dec": common.leaf(
            common.trunc_normal(keys[1], (max_dec_pos, cfg.d_model), 0.01, dtype),
            "seq", "embed"),
        "enc": common.vmap_init(lambda k: _init_enc_block(k, cfg, dtype),
                                keys[2], cfg.n_enc_layers),
        "dec": common.vmap_init(lambda k: _init_dec_block(k, cfg, dtype),
                                keys[3], cfg.n_dec_layers),
        "norm_enc": layers.init_layernorm(cfg.d_model),
        "norm_dec": layers.init_layernorm(cfg.d_model),
    })


# -----------------------------------------------------------------------------
# Encoder
# -----------------------------------------------------------------------------

def encode(params, cfg: EncDecConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, n_audio_ctx, d_model) precomputed embeddings (stub frontend)."""
    b, s, _ = frames.shape
    x = frames + sinusoids(s, cfg.d_model).astype(frames.dtype)[None]
    acfg = cfg.attn_cfg(causal=False)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, p):
        h = layers.layer_norm(p["norm_attn"], x)
        x = x + layers.attention(p["attn"], acfg, h, positions)
        h = layers.layer_norm(p["norm_ffn"], x)
        x = x + layers.mlp(p["mlp"], h, cfg.act)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
    return layers.layer_norm(params["norm_enc"], x)


# -----------------------------------------------------------------------------
# Decoder
# -----------------------------------------------------------------------------

def decode_train(params, cfg: EncDecConfig, tokens: jnp.ndarray,
                 enc_out: jnp.ndarray) -> jnp.ndarray:
    """Teacher-forced decoder. tokens (B,S) -> logits (B,S,V)."""
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens)
    x = x + params["pos_dec"][:s].astype(x.dtype)[None]
    self_cfg, cross_cfg = cfg.attn_cfg(True), cfg.attn_cfg(False)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, p):
        h = layers.layer_norm(p["norm_self"], x)
        x = x + layers.attention(p["self_attn"], self_cfg, h, positions)
        h = layers.layer_norm(p["norm_cross"], x)
        x = x + layers.cross_attention(p["cross_attn"], cross_cfg, h, enc_out)
        h = layers.layer_norm(p["norm_ffn"], x)
        x = x + layers.mlp(p["mlp"], h, cfg.act)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec"])
    x = layers.layer_norm(params["norm_dec"], x)
    return layers.unembed(params["embed"], x)


def loss_fn(params, cfg: EncDecConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    enc_out = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, batch["tokens"], enc_out)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - ll)
    return ce, {"ce": ce, "tokens": jnp.asarray(labels.size, jnp.float32)}


# -- serving -------------------------------------------------------------------

def init_dec_caches(cfg: EncDecConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16):
    kv = lambda slen: KVCache(
        k=jnp.zeros((cfg.n_dec_layers, batch, slen, cfg.n_heads, cfg.head_dim), dtype),
        v=jnp.zeros((cfg.n_dec_layers, batch, slen, cfg.n_heads, cfg.head_dim), dtype))
    return {"self": kv(max_len), "cross": kv(cfg.n_audio_ctx)}


def build_cross_cache(params, cfg: EncDecConfig, enc_out: jnp.ndarray) -> KVCache:
    """Precompute per-layer cross-attention K/V from the encoder output."""
    def body(_, p):
        k = jnp.einsum("bsd,dhk->bshk", enc_out,
                       p["cross_attn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out,
                       p["cross_attn"]["wv"].astype(enc_out.dtype))
        k = k + p["cross_attn"]["bk"].astype(k.dtype)
        v = v + p["cross_attn"]["bv"].astype(v.dtype)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params["dec"])
    return KVCache(k=ks, v=vs)


def decode_step(params, cfg: EncDecConfig, token: jnp.ndarray, pos: jnp.ndarray,
                caches) -> Tuple[jnp.ndarray, Dict]:
    """One decoder token. caches = {"self": KVCache(L,...), "cross": KVCache(L,...)}."""
    b = token.shape[0]
    x = layers.embed(params["embed"], token)
    x = x + jax.lax.dynamic_slice(params["pos_dec"], (pos, 0),
                                  (1, cfg.d_model)).astype(x.dtype)[None]
    self_cfg, cross_cfg = cfg.attn_cfg(True), cfg.attn_cfg(False)

    def body(x, inp):
        p, kself, vself, kcross, vcross = inp
        h = layers.layer_norm(p["norm_self"], x)
        q, k_new, v_new = layers._project_qkv(p["self_attn"], self_cfg, h,
                                              jnp.broadcast_to(pos[None, None], (b, 1)))
        kc = jax.lax.dynamic_update_slice(kself, k_new.astype(kself.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vself, v_new.astype(vself.dtype),
                                          (0, pos, 0, 0))
        kpos = jnp.arange(kc.shape[1])[None]
        mask = (kpos <= pos)[:, None, :]
        out = layers.sdpa(q, kc, vc, mask, self_cfg.scale)
        x = x + jnp.einsum("bshk,hkd->bsd", out,
                           p["self_attn"]["wo"].astype(out.dtype))
        # cross attention against the precomputed cache
        h = layers.layer_norm(p["norm_cross"], x)
        qc = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"].astype(h.dtype))
        qc = qc + p["cross_attn"]["bq"].astype(qc.dtype)
        maskc = jnp.ones((b, 1, kcross.shape[1]), bool)
        outc = layers.sdpa(qc, kcross, vcross, maskc, cross_cfg.scale)
        x = x + jnp.einsum("bshk,hkd->bsd", outc,
                           p["cross_attn"]["wo"].astype(outc.dtype))
        h = layers.layer_norm(p["norm_ffn"], x)
        x = x + layers.mlp(p["mlp"], h, cfg.act)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], caches["self"].k, caches["self"].v,
                  caches["cross"].k, caches["cross"].v))
    x = layers.layer_norm(params["norm_dec"], x)
    logits = layers.unembed(params["embed"], x)
    return logits[..., :cfg.vocab], {"self": KVCache(k=ks, v=vs),
                                     "cross": caches["cross"]}
