"""Transformer building blocks: norms, dense, embeddings, RoPE/M-RoPE, GQA.

All ``init_*`` return common.Axed; all ``apply`` are plain functions.
Attention supports: grouped-query (n_kv <= n_heads), causal masking, sliding
windows (gemma3's 5:1 local:global), optional QKV bias (qwen1.5), incremental
KV-cache decode, and M-RoPE (qwen2-vl).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Axed, group, leaf
from repro.parallel.ctx import constrain

def wl(w, dtype):
    """Weight loader: dequantize int8-served weights at use (fused into the
    consuming matmul's operand load on TPU; the paper's C5 quantized
    inference — see quant.int8.quantize_params_for_serving /
    quantize_weight). ``s8`` is a scalar, per-layer, or keepdims per-channel
    scale — all broadcast against ``q8``."""
    if isinstance(w, dict) and "q8" in w:
        return w["q8"].astype(dtype) * w["s8"].astype(dtype)
    return w.astype(dtype)


def q8_matmul(x: jnp.ndarray, w: dict, contract_ndim: int = 1) -> jnp.ndarray:
    """x (..., contract dims) @ int8-quantized weight via the fused Pallas
    kernel (kernels/int8_matmul.py): int8 loads from HBM, in-register widen,
    per-channel scale on the output tile. The quantized serving fast path's
    weight matmul (DESIGN.md §12); the XLA fallback is wl()+einsum.

    ``w`` is {"q8","s8"} with the first ``contract_ndim`` dims contracted;
    returns (..., *w.shape[contract_ndim:]).
    """
    from repro.kernels import ops as kops
    q = w["q8"]
    kdim = math.prod(q.shape[:contract_ndim])
    out_shape = q.shape[contract_ndim:]
    sv = jnp.broadcast_to(w["s8"], (1,) * contract_ndim + out_shape)
    lead = x.shape[:-contract_ndim]
    y = kops.int8_matmul(x.reshape(*lead, kdim), q.reshape(kdim, -1),
                         sv.reshape(-1))
    return y.reshape(*lead, *out_shape)


# -----------------------------------------------------------------------------
# Norms
# -----------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Axed:
    return group(scale=leaf(jnp.ones((d,), dtype), "embed"))


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_core(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def _rms_fwd(x, scale, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv32 = jax.lax.rsqrt(var + eps)
    return x * inv32.astype(x.dtype) * scale.astype(x.dtype), (x, inv32, scale)


def _rms_bwd(eps, res, dy):
    # backward stays in x.dtype with fp32 REDUCTIONS only. An fp32 cotangent
    # here forces the whole scanned-layer backward into fp32 and XLA then
    # hoists convert(saved-activation-stack) into a +25 GB/device buffer
    # (measured on mamba2 train_4k; EXPERIMENTS.md §Perf iter 0).
    x, inv32, scale = res
    inv = inv32.astype(x.dtype)
    s = scale.astype(x.dtype)
    d = x.shape[-1]
    g = dy * s                                               # (.., D)
    dot = jnp.sum((g * x).astype(jnp.float32), axis=-1, keepdims=True)
    corr = (inv32 ** 3) * (dot / d)
    dx = g * inv - x * corr.astype(x.dtype)
    dscale = jnp.sum((dy * x * inv).astype(jnp.float32),
                     axis=tuple(range(x.ndim - 1))).astype(scale.dtype)
    return dx, dscale


_rms_core.defvjp(_rms_fwd, _rms_bwd)


def rms_norm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return _rms_core(x, params["scale"], eps)


def init_layernorm(d: int, dtype=jnp.float32) -> Axed:
    return group(scale=leaf(jnp.ones((d,), dtype), "embed"),
                 bias=leaf(jnp.zeros((d,), dtype), "embed"))


def layer_norm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    # same no-fp32-copy discipline as rms_norm
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32) - jnp.square(mu)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return (y * params["scale"].astype(x.dtype)
            + params["bias"].astype(x.dtype))


# -----------------------------------------------------------------------------
# Embedding / unembedding
# -----------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype=jnp.float32) -> Axed:
    # 1/sqrt(d) keeps tied-unembedding logits O(1) at init
    w = common.trunc_normal(key, (vocab, d), 1.0 / math.sqrt(d), dtype)
    return group(w=leaf(w, "vocab", "embed"))


def embed(params, tokens: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    """Activations follow the param dtype unless overridden (bf16 in prod,
    fp32 in equivalence tests)."""
    dt = compute_dtype or params["w"].dtype
    return params["w"].astype(dt)[tokens]


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: logits in fp32 (standard for loss stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params["w"].astype(jnp.float32))


def init_unembed(key, d: int, vocab: int, dtype=jnp.float32) -> Axed:
    w = common.fan_in_init(key, (d, vocab), fan_in=d, dtype=dtype)
    return group(w=leaf(w, "embed", "vocab"))


def apply_unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                      params["w"].astype(jnp.float32))


# -----------------------------------------------------------------------------
# Dense / MLP
# -----------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, axes=("embed", "ffn")) -> Axed:
    w = common.fan_in_init(key, (d_in, d_out), dtype=dtype)
    parts = {"w": leaf(w, *axes)}
    if bias:
        parts["b"] = leaf(jnp.zeros((d_out,), dtype), axes[-1])
    return common.group_dict(parts)


def dense(params, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("...d,df->...f", x, wl(params["w"], x.dtype))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def init_mlp(key, d: int, d_ff: int, *, gated: bool = True,
             dtype=jnp.float32) -> Axed:
    k1, k2, k3 = jax.random.split(key, 3)
    parts = {
        "w_in": leaf(common.fan_in_init(k1, (d, d_ff), dtype=dtype), "embed", "ffn"),
        "w_out": leaf(common.fan_in_init(k3, (d_ff, d), dtype=dtype), "ffn", "embed"),
    }
    if gated:
        parts["w_gate"] = leaf(common.fan_in_init(k2, (d, d_ff), dtype=dtype),
                               "embed", "ffn")
    return common.group_dict(parts)


def mlp(params, x: jnp.ndarray, act: str = "silu",
        int8_kernel: bool = False) -> jnp.ndarray:
    act_fn = {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
              "relu": jax.nn.relu}[act]
    if int8_kernel and isinstance(params["w_in"], dict) and "q8" in params["w_in"]:
        h = q8_matmul(x, params["w_in"])
        if "w_gate" in params:
            h = act_fn(q8_matmul(x, params["w_gate"])) * h
        else:
            h = act_fn(h)
        return q8_matmul(h, params["w_out"])
    h = jnp.einsum("...d,df->...f", x, wl(params["w_in"], x.dtype))
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, wl(params["w_gate"], x.dtype))
        h = act_fn(g) * h
    else:
        h = act_fn(h)
    return jnp.einsum("...f,fd->...d", h, wl(params["w_out"], x.dtype))


# -----------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# -----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions_thw: jnp.ndarray,
                sections: Tuple[int, int, int], theta: float = 10000.0,
                ) -> jnp.ndarray:
    """Multimodal RoPE (qwen2-vl): 3 position streams (t,h,w) rotate disjoint
    frequency sections of the head dim.

    x: (B, S, H, Dh); positions_thw: (B, S, 3) int32; sections sum to Dh//2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    # pick, per frequency index, which of the 3 position streams drives it
    sec_id = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                              for i, s in enumerate(sections)])  # (half,)
    pos = positions_thw.astype(jnp.float32)[..., sec_id]         # (B,S,half)
    angles = pos * freqs                                       # (B,S,half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -----------------------------------------------------------------------------
# Attention (GQA, windows, cache)
# -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    # sliding window in tokens; <0 = global/full attention
    window: int = -1
    # "rope" | "mrope" | "none"
    pos_emb: str = "rope"
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)
    softmax_scale: Optional[float] = None
    # sequence-parallel attention: shard q/k/v activations on seq over the
    # model axis (context parallelism) — the TP fallback for archs whose head
    # counts don't divide the mesh (starcoder2 36H, whisper 20H); §Perf HC-A
    sp: bool = False
    # route int8-quantized projection matmuls through the fused Pallas
    # int8 kernel (set by LMConfig.attn_cfg on the quantized serving fast
    # path; XLA dequant+einsum elsewhere)
    int8_kernel: bool = False
    # training fast path (DESIGN.md §13): full-sequence attention through
    # the custom-VJP flash Pallas kernel — forward saves only (O, lse), the
    # backward runs the fused recompute kernels instead of autodiff through
    # sdpa's materialized probability tensor
    flash_vjp: bool = False

    @property
    def scale(self) -> float:
        return self.softmax_scale or (1.0 / math.sqrt(self.head_dim))


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> Axed:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    parts = {
        "wq": leaf(common.fan_in_init(kq, (d, h, dh), fan_in=d, dtype=dtype),
                   "embed", "heads", "head_dim"),
        "wk": leaf(common.fan_in_init(kk, (d, kvh, dh), fan_in=d, dtype=dtype),
                   "embed", "kv_heads", "head_dim"),
        "wv": leaf(common.fan_in_init(kv, (d, kvh, dh), fan_in=d, dtype=dtype),
                   "embed", "kv_heads", "head_dim"),
        "wo": leaf(common.fan_in_init(ko, (h, dh, d), fan_in=h * dh, dtype=dtype),
                   "heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        parts["bq"] = leaf(jnp.zeros((h, dh), dtype), "heads", "head_dim")
        parts["bk"] = leaf(jnp.zeros((kvh, dh), dtype), "kv_heads", "head_dim")
        parts["bv"] = leaf(jnp.zeros((kvh, dh), dtype), "kv_heads", "head_dim")
    return common.group_dict(parts)


def _q8_active(cfg, w) -> bool:
    return cfg.int8_kernel and isinstance(w, dict) and "q8" in w


def _project_qkv(params, cfg: AttnConfig, x: jnp.ndarray, positions):
    if _q8_active(cfg, params["wq"]):
        q = q8_matmul(x, params["wq"])
        k = q8_matmul(x, params["wk"])
        v = q8_matmul(x, params["wv"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, wl(params["wq"], x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x, wl(params["wk"], x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, wl(params["wv"], x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_emb == "mrope":
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    if cfg.sp:
        # context parallel: queries shard on seq over "model"; K/V stay
        # seq-replicated (the partitioner gathers them once per layer)
        q = constrain(q, "batch", "seq_tp", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    return q, k, v


def attention_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, *, causal: bool,
                   window) -> jnp.ndarray:
    """(.., Sq, Sk) bool mask. ``window`` may be a traced scalar; window<0
    means full attention (so one scanned stack can mix local/global layers)."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    m = jnp.ones(diff.shape, bool)
    if causal:
        m &= diff >= 0
    w = jnp.asarray(window)
    m &= jnp.where(w > 0, diff < w, True)
    return m


def sdpa(q, k, v, mask, scale: float) -> jnp.ndarray:
    """Reference scaled-dot-product attention with GQA head grouping.

    q: (B,Sq,H,Dh), k/v: (B,Sk,Hkv,Dh); mask broadcastable to (B,H,Sq,Sk).
    fp32 softmax for stability; returns q.dtype.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, dh)
    logits = jnp.einsum("bqhrd,bnhd->bhrqn", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    # logits: (B, Hkv, rep, Sq, Sk)
    mask_b = jnp.broadcast_to(mask[:, None, None] if mask.ndim == 3
                              else mask[None, None, None], logits.shape)
    logits = jnp.where(mask_b, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqn,bnhd->bqhrd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


# above this many KV positions the S x S logits tensor cannot live in HBM;
# the exact q-chunked path (XLA-level stand-in for the flash Pallas kernel)
# takes over. 8k: chunk logits are (B,Hkv,rep,1024,S) fp32.
_CHUNKED_SDPA_THRESHOLD = 8192
_SDPA_Q_CHUNK = 1024


def sdpa_q_chunked(q, k, v, q_pos, k_pos, *, causal: bool, window,
                   scale: float, chunk: int = _SDPA_Q_CHUNK) -> jnp.ndarray:
    """Exact attention scanning over query chunks (O(chunk*Sk) live memory).

    Semantics identical to sdpa+attention_mask; used for long sequences where
    the full (Sq, Sk) logits tensor would not fit. On TPU the flash Pallas
    kernel (kernels/flash_attention.py) replaces this at runtime.
    """
    b, sq, h, dh = q.shape
    nc = -(-sq // chunk)
    pad = nc * chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    qc = q.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(b, nc, chunk).transpose(1, 0, 2)

    def one(_, inp):
        q_i, p_i = inp                                   # (B,chunk,H,dh)
        mask = attention_mask(p_i, k_pos, causal=causal, window=window)
        mask &= (p_i >= 0)[..., None]
        return None, sdpa(q_i, k, v, mask, scale)

    _, out = jax.lax.scan(one, None, (qc, pc))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, dh)
    return out[:, :sq]


def attention(params, cfg: AttnConfig, x: jnp.ndarray,
              positions: Optional[jnp.ndarray] = None,
              window=None, arange_positions: bool = False) -> jnp.ndarray:
    """Full (training/prefill) self-attention.

    ``arange_positions``: static promise from the caller that ``positions``
    is the standard 0..S-1 arange (or None, which synthesizes it) — the
    precondition for the flash-kernel route, whose masking is by block
    index, not by the positions tensor.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        arange_positions = True
    q, k, v = _project_qkv(params, cfg, x, positions)
    pos1d = positions[..., 0] if positions.ndim == 3 else positions
    w = cfg.window if window is None else window
    if (cfg.flash_vjp and arange_positions and cfg.causal
            and isinstance(w, int) and not cfg.sp
            and cfg.pos_emb != "mrope"):
        # training fast path: block-index masking is exact because the
        # caller vouched positions == arange (packed/custom-position
        # batches stay on the mask-from-positions sdpa paths below)
        from repro.kernels import ops as kops
        out = kops.flash_attention_train(q, k, v, scale=cfg.scale,
                                         causal=True, window=w)
    elif s > _CHUNKED_SDPA_THRESHOLD:
        out = sdpa_q_chunked(q, k, v, pos1d, pos1d, causal=cfg.causal,
                             window=w, scale=cfg.scale)
    else:
        mask = attention_mask(pos1d, pos1d, causal=cfg.causal, window=w)
        out = sdpa(q, k, v, mask, cfg.scale)
    if _q8_active(cfg, params["wo"]):
        return q8_matmul(out, params["wo"], contract_ndim=2)
    return jnp.einsum("bshk,hkd->bsd", out, wl(params["wo"], out.dtype))


# -- incremental decode -------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    """Ring-less append cache: k/v (B, S_max, Hkv, Dh), scalar write index."""
    k: jnp.ndarray
    v: jnp.ndarray

jax.tree_util.register_dataclass(KVCache, data_fields=["k", "v"], meta_fields=[])


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(k=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
                   v=jnp.zeros((batch, max_len, n_kv, head_dim), dtype))


def attention_decode(params, cfg: AttnConfig, x: jnp.ndarray,
                     cache: KVCache, pos: jnp.ndarray,
                     window=None) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode: x (B,1,D), pos scalar int32 (same for all rows).

    Attends over cache[0:pos] + the new token; respects sliding windows.
    """
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)) if pos.ndim == 0 else pos
    if cfg.pos_emb == "mrope":
        positions = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, pos.astype(jnp.int32), 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, pos.astype(jnp.int32), 0, 0))
    s_max = k.shape[1]
    k_pos = jnp.arange(s_max)[None]                         # (1, S)
    q_pos = positions[..., 0] if positions.ndim == 3 else positions
    mask = attention_mask(q_pos, k_pos, causal=True,
                          window=cfg.window if window is None else window)
    mask &= (k_pos <= q_pos[..., :, None])                  # exclude unwritten slots
    out = sdpa(q, k, v, mask, cfg.scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))
    return y, KVCache(k=k, v=v)


# -- cross attention (whisper decoder) ----------------------------------------

def cross_attention(params, cfg: AttnConfig, x: jnp.ndarray,
                    kv_src: jnp.ndarray) -> jnp.ndarray:
    """x: (B,Sq,D) queries; kv_src: (B,Sk,D) encoder output (no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, wl(params["wq"], x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    mask = jnp.ones((x.shape[0], q.shape[1], k.shape[1]), bool)
    out = sdpa(q, k, v, mask, cfg.scale)
    return jnp.einsum("bshk,hkd->bsd", out, wl(params["wo"], out.dtype))
