"""Mixture-of-Experts FFN: top-k token-choice routing.

Two execution paths with identical semantics (equivalence-tested):

* ``moe_dense``     — reference: every expert computes every token, combined by
                      the routing weights. O(E) compute; used for tests/smoke.
* ``moe_ep``        — production expert-parallel path: tokens replicated across
                      the ``model`` mesh axis, experts sharded over it. Each
                      rank counting-sorts its local tokens into capacity-padded
                      per-expert buffers (dropless up to the capacity factor),
                      runs only its local experts, scatter-combines, and
                      psums partial outputs over the axis. One all-reduce per
                      block — the same collective cost as a Megatron TP FFN,
                      with no all-to-all (see DESIGN.md §5).

Router: softmax-after-top-k normalization (Mixtral/DeepSeek style), with the
Switch load-balance auxiliary loss and router z-loss.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import Axed, group, leaf
from repro.parallel.ctx import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden size
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"
    router_z_coef: float = 1e-3
    lb_coef: float = 1e-2

    def capacity(self, n_tokens: int) -> int:
        cap = int(self.capacity_factor * n_tokens * self.top_k / self.n_experts)
        return max(cap, self.top_k)


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32) -> Axed:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return group(
        router=leaf(common.fan_in_init(kr, (d, e), dtype=jnp.float32),
                    "embed", "experts"),
        w_in=leaf(common.fan_in_init(k1, (e, d, f), fan_in=d, dtype=dtype),
                  "experts", "embed", "ffn"),
        w_gate=leaf(common.fan_in_init(k2, (e, d, f), fan_in=d, dtype=dtype),
                    "experts", "embed", "ffn"),
        w_out=leaf(common.fan_in_init(k3, (e, f, d), fan_in=f, dtype=dtype),
                   "experts", "ffn", "embed"),
    )


# -----------------------------------------------------------------------------
# Routing
# -----------------------------------------------------------------------------

def route(params, cfg: MoEConfig, x2d: jnp.ndarray
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x2d: (T, d) -> (gates (T,k) fp32, expert_ids (T,k) int32, aux_loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    top_logits, expert_ids = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_logits, axis=-1)
    # Switch-style load-balance loss: E * sum_e (frac_tokens_e * mean_prob_e)
    probs = jax.nn.softmax(logits, axis=-1)
    counts = jnp.zeros((cfg.n_experts,), jnp.float32).at[expert_ids.reshape(-1)].add(
        jnp.ones((x2d.shape[0] * cfg.top_k,), jnp.float32))
    frac = counts / (x2d.shape[0] * cfg.top_k)
    lb = cfg.n_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = cfg.lb_coef * lb + cfg.router_z_coef * z
    return gates, expert_ids.astype(jnp.int32), aux


def _expert_ffn(w_in, w_gate, w_out, x, act: str) -> jnp.ndarray:
    """x: (..., d) with expert-major leading dims matching w_* leading dims."""
    from repro.models.layers import wl
    act_fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    h = jnp.einsum("ecd,edf->ecf", x, wl(w_in, x.dtype))
    g = jnp.einsum("ecd,edf->ecf", x, wl(w_gate, x.dtype))
    return jnp.einsum("ecf,efd->ecd", act_fn(g) * h, wl(w_out, x.dtype))


# -----------------------------------------------------------------------------
# Dense reference path
# -----------------------------------------------------------------------------

def moe_dense(params, cfg: MoEConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-experts reference. x: (B,S,d) -> (y, aux_loss)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, expert_ids, aux = route(params, cfg, x2d)
    act_fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
    # (E, T, f): every expert on every token (reference only)
    h = jnp.einsum("td,edf->etf", x2d, params["w_in"].astype(x.dtype))
    g = jnp.einsum("td,edf->etf", x2d, params["w_gate"].astype(x.dtype))
    y_all = jnp.einsum("etf,efd->etd", act_fn(g) * h,
                       params["w_out"].astype(x.dtype))       # (E,T,d)
    onehot = jax.nn.one_hot(expert_ids, cfg.n_experts, dtype=jnp.float32)  # (T,k,E)
    weights = jnp.einsum("tk,tke->te", gates, onehot)          # (T,E)
    y = jnp.einsum("te,etd->td", weights.astype(x.dtype), y_all)
    return y.reshape(b, s, d), aux


# -----------------------------------------------------------------------------
# Capacity-dispatch path (pjit-native; the production path under SPMD)
# -----------------------------------------------------------------------------

def moe_capacity(params, cfg: MoEConfig, x: jnp.ndarray,
                 group_size: int = 4096) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """T5X-style capacity-padded token-choice dispatch, fully pjit-friendly.

    Tokens are split into groups (sharded on the data axes); experts shard on
    the model axis. The dispatch/combine one-hots contract locally; the only
    collective is the d_model-sized partial-sum all-reduce over the model axis
    — the same cost as a Megatron TP FFN.

    FIFO-within-group capacity: routes beyond capacity are dropped (standard;
    exact vs. moe_dense when capacity_factor is large — equivalence-tested).
    """
    b, s, d = x.shape
    t = b * s
    g = max(t // group_size, 1)
    tg = t // g
    assert g * tg == t, (t, group_size)
    e, k = cfg.n_experts, cfg.top_k

    gates, expert_ids, aux = route(params, cfg, x.reshape(t, d))
    xg = x.reshape(g, tg, d)
    gates = gates.reshape(g, tg, k)
    ids = expert_ids.reshape(g, tg, k)
    cap = cfg.capacity(tg)

    oh = jax.nn.one_hot(ids, e, dtype=jnp.float32)            # (G,Tg,k,E)
    ohf = oh.reshape(g, tg * k, e)                            # token-major FIFO
    ranks_f = jnp.cumsum(ohf, axis=1) - ohf                   # rank per route
    rank = jnp.einsum("gxe,gxe->gx", ranks_f, ohf).reshape(g, tg, k)
    keep = (rank < cap).astype(jnp.float32)
    ohc = jax.nn.one_hot(rank.astype(jnp.int32), cap, dtype=jnp.float32) \
        * keep[..., None]                                      # (G,Tg,k,C)

    dispatch = jnp.einsum("gtke,gtkc->gtec", oh, ohc)          # (G,Tg,E,C)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oh, ohc, gates)
    # pin groups to the DP axes and experts to the model axis: these are the
    # largest tensors of the block and must not replicate
    dispatch = constrain(dispatch, "batch", None, "experts", None)
    combine = constrain(combine, "batch", None, "experts", None)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    xin = constrain(xin, "batch", "experts", None, None)
    act_fn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
    from repro.models.layers import wl
    h = jnp.einsum("gecd,edf->gecf", xin, wl(params["w_in"], x.dtype))
    gate_h = jnp.einsum("gecd,edf->gecf", xin, wl(params["w_gate"], x.dtype))
    y_e = jnp.einsum("gecf,efd->gecd", act_fn(gate_h) * h,
                     wl(params["w_out"], x.dtype))
    y_e = constrain(y_e, "batch", "experts", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), y_e)
    return y.reshape(b, s, d), aux


# -----------------------------------------------------------------------------
# Expert-parallel path (runs inside shard_map; all ops local + one psum)
# -----------------------------------------------------------------------------

def _counting_sort_dispatch(expert_ids: jnp.ndarray, n_experts: int,
                            capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assign each (token, k) routing decision a slot in (E, C) buffers.

    Returns (slot_token (E*C,) int32 token index or T_pad sentinel,
             slot_of_route (T, k) int32 flat slot or -1 if dropped).
    """
    t, k = expert_ids.shape
    flat_e = expert_ids.reshape(-1)                            # (T*k,)
    order = jnp.argsort(flat_e, stable=True)                   # stable -> FIFO per expert
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < capacity
    slot = jnp.where(keep, flat_e * capacity + rank, -1)       # (T*k,)
    token_of_route = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    # scratch slot at the end absorbs dropped routes; sentinel token id = T
    slot_token = jnp.full((n_experts * capacity + 1,), t, jnp.int32)
    write_idx = jnp.where(keep, slot, n_experts * capacity)
    slot_token = slot_token.at[write_idx].set(token_of_route)[:-1]
    return slot_token, slot.reshape(t, k)


def moe_ep(params, cfg: MoEConfig, x: jnp.ndarray, axis_name: str,
           axis_size: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE; call inside shard_map with experts sharded on
    ``axis_name`` and tokens replicated over it.

    params['w_*'] are the LOCAL expert shards (E_loc, ...); routing uses the
    full router matrix (replicated). x: (B_loc, S, d).
    """
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    e_loc = params["w_in"].shape[0]
    my_rank = jax.lax.axis_index(axis_name)
    e_lo = my_rank * e_loc

    gates, expert_ids, aux = route(params, cfg, x2d)
    cap = cfg.capacity(t)

    slot_token, slot_of_route = _counting_sort_dispatch(
        expert_ids, cfg.n_experts, cap)

    # local slice of the global (E*C) slot space
    lo = e_lo * cap
    local_slot_token = jax.lax.dynamic_slice(slot_token, (lo,), (e_loc * cap,))
    valid = local_slot_token < t                                  # (E_loc*C,)
    gather_idx = jnp.where(valid, local_slot_token, 0)
    dispatched = x2d[gather_idx] * valid[:, None].astype(x2d.dtype)
    dispatched = dispatched.reshape(e_loc, cap, d)

    y_exp = _expert_ffn(params["w_in"], params["w_gate"], params["w_out"],
                        dispatched, cfg.act)                      # (E_loc,C,d)
    y_flat = y_exp.reshape(e_loc * cap, d)

    # combine: for each (token,k) route landing in our expert range, add
    # gate * y[slot]. Routes outside our range contribute 0 here and are
    # summed in by the psum.
    flat_slot = slot_of_route.reshape(-1)                         # (T*k,)
    in_range = (flat_slot >= lo) & (flat_slot < lo + e_loc * cap)
    local_slot = jnp.where(in_range, flat_slot - lo, 0)
    contrib = y_flat[local_slot] * in_range[:, None].astype(y_flat.dtype)
    contrib = contrib * gates.reshape(-1, 1).astype(y_flat.dtype)
    y = jnp.zeros((t, d), y_flat.dtype).at[
        jnp.repeat(jnp.arange(t, dtype=jnp.int32), cfg.top_k)].add(contrib)

    y = jax.lax.psum(y, axis_name)
    aux = aux  # identical on every rank (tokens replicated) — no psum needed
    return y.reshape(b, s, d), aux
