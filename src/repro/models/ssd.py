"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Implements the chunked SSD algorithm for training/prefill (sequential scan
over chunks; quadratic only within a chunk) and the O(1)-per-token recurrent
form for decode. The chunked path is validated against the naive recurrence in
tests/test_ssd.py.

Block layout (faithful to Mamba2):
  in: separate projections z, x, B, C, dt  (separate so TP sharding stays clean)
  causal depthwise conv (width d_conv) over x, B, C
  SSD core:  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t h_t + D x_t
  gated RMSNorm(y * silu(z)) -> out projection
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, layers
from repro.models.common import Axed, group, leaf
from repro.parallel.ctx import constrain


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    n_groups: int = 1           # G (B,C shared per group)
    d_conv: int = 4
    chunk: int = 256            # SSD chunk length (training/prefill)
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim


def init_ssd(key, cfg: SSDConfig, dtype=jnp.float32) -> Axed:
    kz, kx, kb, kc, kdt, ko, ka = jax.random.split(key, 7)
    d, h, p, g, n = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    # dt bias such that softplus(bias) spans [dt_min, dt_max] (mamba init)
    u = jax.random.uniform(ka, (h,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min))
                      + jnp.log(cfg.dt_min))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))   # inverse softplus
    a_init = jnp.log(jnp.linspace(1.0, 16.0, h))        # A in [-16,-1]
    return group(
        w_z=leaf(common.fan_in_init(kz, (d, h, p), fan_in=d, dtype=dtype),
                 "embed", "heads", "head_dim"),
        w_x=leaf(common.fan_in_init(kx, (d, h, p), fan_in=d, dtype=dtype),
                 "embed", "heads", "head_dim"),
        w_b=leaf(common.fan_in_init(kb, (d, g, n), fan_in=d, dtype=dtype),
                 "embed", "ssm_group", "ssm_state"),
        w_c=leaf(common.fan_in_init(kc, (d, g, n), fan_in=d, dtype=dtype),
                 "embed", "ssm_group", "ssm_state"),
        w_dt=leaf(common.fan_in_init(kdt, (d, h), fan_in=d, dtype=dtype),
                  "embed", "heads"),
        dt_bias=leaf(dt_bias.astype(jnp.float32), "heads"),
        a_log=leaf(a_init.astype(jnp.float32), "heads"),
        d_skip=leaf(jnp.ones((h,), jnp.float32), "heads"),
        conv_x=leaf(common.trunc_normal(ko, (cfg.d_conv, h, p), 0.2, dtype),
                    "conv", "heads", "head_dim"),
        conv_b=leaf(jnp.zeros((cfg.d_conv, g, n), dtype), "conv", "ssm_group", "ssm_state"),
        conv_c=leaf(jnp.zeros((cfg.d_conv, g, n), dtype), "conv", "ssm_group", "ssm_state"),
        norm=init_rmsnorm_inner(h * p, dtype),
        w_out=leaf(common.fan_in_init(jax.random.fold_in(ko, 1), (h, p, d),
                                      fan_in=h * p, dtype=dtype),
                   "heads", "head_dim", "embed"),
    )


def init_rmsnorm_inner(d: int, dtype) -> Axed:
    return group(scale=leaf(jnp.ones((d,), dtype), "ssm_inner"))


# -----------------------------------------------------------------------------
# causal depthwise conv (width d_conv), full-sequence and incremental forms
# -----------------------------------------------------------------------------

def _causal_dwconv(x: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,...ch), kernel: (W,...ch). y_t = sum_i k_i x_{t-W+1+i}."""
    w = kernel.shape[0]
    y = x * kernel[-1].astype(x.dtype)
    for i in range(w - 1):
        shift = w - 1 - i
        xs = jnp.pad(x, [(0, 0), (shift, 0)] + [(0, 0)] * (x.ndim - 2))[:, :-shift]
        y = y + xs * kernel[i].astype(x.dtype)
    return y


def _dwconv_step(x_new: jnp.ndarray, conv_state: jnp.ndarray,
                 kernel: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x_new: (B,1,...ch); conv_state: (B,W-1,...ch) past inputs."""
    window = jnp.concatenate([conv_state, x_new], axis=1)     # (B,W,...)
    y = jnp.einsum("bw...,w...->b...", window.astype(jnp.float32),
                   kernel.astype(jnp.float32))[:, None]
    return y.astype(x_new.dtype), window[:, 1:]


# -----------------------------------------------------------------------------
# SSD core
# -----------------------------------------------------------------------------

def ssd_naive(x, dt, a, b_mat, c_mat, init_state=None):
    """Reference O(S·N·P) recurrence (oracle for tests). fp32.

    x: (B,S,H,P) dt: (B,S,H) a: (H,) b/c: (B,S,H,N) (already group-expanded)
    returns y: (B,S,H,P), final state (B,H,N,P)
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = (jnp.zeros((bsz, h, n, p), jnp.float32) if init_state is None
             else init_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a)                                # (B,H)
        xbar = xt * dtt[..., None]                              # (B,H,P)
        state = (decay[..., None, None] * state
                 + jnp.einsum("bhn,bhp->bhnp", bt, xbar))
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          b_mat.astype(jnp.float32).transpose(1, 0, 2, 3),
          c_mat.astype(jnp.float32).transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, init_state=None):
    """Chunked SSD (Mamba2 alg. 1): quadratic intra-chunk, linear inter-chunk.

    Shapes as ssd_naive (b/c already expanded to heads). S % chunk == 0.
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    if s % chunk != 0:
        # pad tail: dt=0 => decay 1 and x̄=0, so states are unaffected
        pad = chunk - s % chunk
        padded = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            a,
            jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0))),
            chunk, init_state)
        return padded[0][:, :s], padded[1]
    nc = s // chunk
    f32 = jnp.float32

    # (B, nc, H, Q, ...)
    xc = x.astype(f32).reshape(bsz, nc, chunk, h, p).transpose(0, 1, 3, 2, 4)
    dtc = dt.astype(f32).reshape(bsz, nc, chunk, h).transpose(0, 1, 3, 2)
    bc = b_mat.astype(f32).reshape(bsz, nc, chunk, h, n).transpose(0, 1, 3, 2, 4)
    cc = c_mat.astype(f32).reshape(bsz, nc, chunk, h, n).transpose(0, 1, 3, 2, 4)

    da = dtc * a[None, None, :, None]                   # (B,nc,H,Q) <= 0
    cum = jnp.cumsum(da, axis=-1)                       # cumulative log-decay
    xbar = xc * dtc[..., None]

    # intra-chunk (masked quadratic attention-like form)
    ldiff = cum[..., :, None] - cum[..., None, :]       # (B,nc,H,Q,Q)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(tri, jnp.exp(ldiff), 0.0)
    scores = jnp.einsum("bchqn,bchkn->bchqk", cc, bc) * l_mat
    y_intra = jnp.einsum("bchqk,bchkp->bchqp", scores, xbar)

    # chunk-final states: S_c = sum_j exp(cum_Q - cum_j) B_j (x̄_j)^T
    decay_to_end = jnp.exp(cum[..., -1:] - cum)         # (B,nc,H,Q)
    s_chunk = jnp.einsum("bchqn,bchqp->bchnp", bc * decay_to_end[..., None], xbar)
    chunk_decay = jnp.exp(cum[..., -1])                 # (B,nc,H)

    # inter-chunk recurrence over nc chunks
    h0 = (jnp.zeros((bsz, h, n, p), f32) if init_state is None
          else init_state.astype(f32))

    def step(hprev, inp):
        s_c, dec = inp                                   # (B,H,N,P), (B,H)
        hnew = dec[..., None, None] * hprev + s_c
        return hnew, hprev                               # emit state *entering* chunk

    hfinal, h_in = jax.lax.scan(
        step, h0, (s_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,N,P)

    y_inter = jnp.einsum("bchqn,bchnp->bchqp",
                         cc * jnp.exp(cum)[..., None], h_in)
    y = (y_intra + y_inter).transpose(0, 1, 3, 2, 4).reshape(bsz, s, h, p)
    return y, hfinal


# -----------------------------------------------------------------------------
# Block-level apply
# -----------------------------------------------------------------------------

@dataclasses.dataclass
class SSDState:
    conv_x: jnp.ndarray     # (B, W-1, H, P)
    conv_b: jnp.ndarray     # (B, W-1, G, N)
    conv_c: jnp.ndarray     # (B, W-1, G, N)
    ssm: jnp.ndarray        # (B, H, N, P)

jax.tree_util.register_dataclass(
    SSDState, data_fields=["conv_x", "conv_b", "conv_c", "ssm"], meta_fields=[])


def init_ssd_state(cfg: SSDConfig, batch: int, dtype=jnp.bfloat16) -> SSDState:
    h, p, g, n, w = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state, cfg.d_conv
    return SSDState(
        conv_x=jnp.zeros((batch, w - 1, h, p), dtype),
        conv_b=jnp.zeros((batch, w - 1, g, n), dtype),
        conv_c=jnp.zeros((batch, w - 1, g, n), dtype),
        ssm=jnp.zeros((batch, h, n, p), jnp.float32),
    )


def _expand_groups(t: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B,S,G,N) -> (B,S,H,N) by repeating each group H/G times."""
    bsz, s, g, n = t.shape
    rep = n_heads // g
    return jnp.broadcast_to(t[:, :, :, None, :], (bsz, s, g, rep, n)
                            ).reshape(bsz, s, n_heads, n)


def _projections(params, cfg: SSDConfig, x: jnp.ndarray):
    z = jnp.einsum("bsd,dhp->bshp", x, params["w_z"].astype(x.dtype))
    xin = jnp.einsum("bsd,dhp->bshp", x, params["w_x"].astype(x.dtype))
    b_raw = jnp.einsum("bsd,dgn->bsgn", x, params["w_b"].astype(x.dtype))
    c_raw = jnp.einsum("bsd,dgn->bsgn", x, params["w_c"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"].astype(x.dtype))
    return z, xin, b_raw, c_raw, dt_raw


def _finish(params, cfg: SSDConfig, y: jnp.ndarray, xin: jnp.ndarray,
            z: jnp.ndarray) -> jnp.ndarray:
    y = y + params["d_skip"][None, None, :, None] * xin.astype(jnp.float32)
    y = y.astype(z.dtype) * jax.nn.silu(z)
    bsz, s = y.shape[:2]
    y = layers.rms_norm(params["norm"], y.reshape(bsz, s, -1))
    y = y.reshape(bsz, s, cfg.n_heads, cfg.head_dim)
    return jnp.einsum("bshp,hpd->bsd", y, params["w_out"].astype(y.dtype))


def ssd_block(params, cfg: SSDConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence (training/prefill) Mamba2 block. x: (B,S,D)."""
    z, xin, b_raw, c_raw, dt_raw = _projections(params, cfg, x)
    xin = jax.nn.silu(_causal_dwconv(xin, params["conv_x"]))
    b_raw = jax.nn.silu(_causal_dwconv(b_raw, params["conv_b"]))
    c_raw = jax.nn.silu(_causal_dwconv(c_raw, params["conv_c"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    # group->head expansion loses the head sharding under GSPMD propagation;
    # re-pin heads to the model axis (the SSD chunk tensors inherit it)
    xin = constrain(xin, "batch", "seq", "heads", "head_dim")
    dt = constrain(dt, "batch", "seq", "heads")
    bm = _expand_groups(b_raw, cfg.n_heads).astype(jnp.float32)
    cm = _expand_groups(c_raw, cfg.n_heads).astype(jnp.float32)
    bm = constrain(bm, "batch", "seq", "heads", "ssm_state")
    cm = constrain(cm, "batch", "seq", "heads", "ssm_state")
    y, _ = ssd_chunked(xin.astype(jnp.float32), dt, a, bm, cm, cfg.chunk)
    y = constrain(y, "batch", "seq", "heads", "head_dim")
    return _finish(params, cfg, y, xin, z)


def ssd_block_decode(params, cfg: SSDConfig, x: jnp.ndarray,
                     state: SSDState) -> Tuple[jnp.ndarray, SSDState]:
    """One-token decode. x: (B,1,D)."""
    z, xin, b_raw, c_raw, dt_raw = _projections(params, cfg, x)
    xin, conv_x = _dwconv_step(xin, state.conv_x, params["conv_x"])
    b_raw, conv_b = _dwconv_step(b_raw, state.conv_b, params["conv_b"])
    c_raw, conv_c = _dwconv_step(c_raw, state.conv_c, params["conv_c"])
    xin, b_raw, c_raw = map(jax.nn.silu, (xin, b_raw, c_raw))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,1,H)
    a = -jnp.exp(params["a_log"])
    bm = _expand_groups(b_raw, cfg.n_heads).astype(jnp.float32)[:, 0]     # (B,H,N)
    cm = _expand_groups(c_raw, cfg.n_heads).astype(jnp.float32)[:, 0]
    dt0 = dt[:, 0]                                                        # (B,H)
    decay = jnp.exp(dt0 * a)                                              # (B,H)
    xbar = xin.astype(jnp.float32)[:, 0] * dt0[..., None]                 # (B,H,P)
    ssm = (decay[..., None, None] * state.ssm
           + jnp.einsum("bhn,bhp->bhnp", bm, xbar))
    y = jnp.einsum("bhn,bhnp->bhp", cm, ssm)[:, None]                     # (B,1,H,P)
    out = _finish(params, cfg, y, xin, z)
    return out, SSDState(conv_x=conv_x, conv_b=conv_b, conv_c=conv_c, ssm=ssm)
