"""Decoder-only LM with composable per-layer block schedules.

One config drives all assigned LM architectures:

* dense GQA transformers (starcoder2, granite, qwen1.5, qwen2-vl backbone)
* sliding-window:global patterns (gemma3's 5:1)
* MoE FFNs (moonshot 64e/top-6, kimi-k2 384e/top-8)
* SSM stacks (mamba2) and hybrid stacks with a shared attention block
  invoked periodically (zamba2)

The layer schedule is ``pattern x repeats + tail``. The repeated pattern is
executed with ``jax.lax.scan`` over stacked parameters (HLO size independent
of depth — essential for 512-device compiles); the tail runs unrolled.
Blocks marked ``shared_attn`` reuse a single parameter set across all scan
iterations (zamba2) while still owning per-invocation KV cache slots.

Entry points: ``forward`` (training / logits), ``prefill`` (logits + caches),
``decode_step`` (one token with caches) — the three things the dry-run cells
lower.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, layers, moe as moe_lib, ssd as ssd_lib
from repro.models.common import Axed, group_dict
from repro.models.layers import AttnConfig, KVCache
from repro.parallel.ctx import constrain

PyTree = Any


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str = "attn"          # "attn" | "ssd"
    window: int = -1            # sliding window (attn); <0 = global
    moe: bool = False           # MoE FFN instead of dense FFN
    shared_attn: bool = False   # zamba2: use the single shared attention block
    has_ffn: bool = True        # pure mamba blocks have no separate FFN


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Serving-time quantization policy (DESIGN.md §12).

    ``weights``: "none" | "int8" — int8 keeps linear-layer weights int8 in
    HBM with per-output-channel fp32 scales (quantize_lm); embeddings,
    norms, and routers stay high-precision.
    ``kv``: "none" | "int8" — int8 stores the KV cache as (int8 codes,
    one fp32 scale per (slot, position, kv-head)); dequant happens inside
    the attention kernel body, so full-precision K/V never round-trip
    through memory.
    """
    weights: str = "none"
    kv: str = "none"

    @property
    def weights_int8(self) -> bool:
        return self.weights == "int8"

    @property
    def kv_int8(self) -> bool:
        return self.kv == "int8"


INT8_QUANT = QuantPolicy(weights="int8", kv="int8")


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    pattern: Tuple[BlockSpec, ...]
    repeats: int
    tail: Tuple[BlockSpec, ...] = ()
    head_dim: Optional[int] = None
    act: str = "silu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    pos_emb: str = "rope"                    # "rope" | "mrope" | "none"
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)
    moe_cfg: Optional[moe_lib.MoEConfig] = None
    ssd_cfg: Optional[ssd_lib.SSDConfig] = None
    tie_embeddings: bool = True
    vision_tokens: int = 0                   # qwen2-vl stub frontend
    logit_softcap: float = 0.0
    remat: str = "full"                      # "none" | "full" | "dots"
    moe_group_size: int = 4096
    ring_cache: bool = False                 # window-sized ring KV caches
    z_loss: float = 0.0
    mlp_gated: bool = True                   # False: classic 2-matrix MLP
    # embedding/logit tables pad up so the vocab dim TP-shards (mamba2's
    # 50280 and whisper's 51866 don't divide 16 — unpadded logits replicate
    # at 13 GB/device; EXPERIMENTS.md §Perf iter 0). labels never reference
    # pad ids; decode/prefill slice logits back to the true vocab.
    vocab_pad_multiple: int = 128
    # sequence-parallel knobs (§Perf HC-A / HC-B):
    sp_attention: bool = False    # shard attention q on seq over model
    sp_residual: bool = False     # keep the residual stream seq-sharded
    # KV-cache storage dtype (§Perf HC-C): "bf16" | "fp8" (f8_e4m3; sdpa
    # upcasts to fp32 so only storage/traffic changes)
    kv_cache_dtype: str = "bf16"
    # serve-core: route batched (per-slot position) decode attention through
    # the Pallas decode kernel (kernels/decode_attention.py). Off by default —
    # the serving engine flips it on for TPU backends (DESIGN.md §serve)
    decode_kernel: bool = False
    # serving-time quantization policy (DESIGN.md §12): int8 weights and/or
    # int8 KV cache. The serving engine sets this from ServeConfig.quant.
    quant: QuantPolicy = QuantPolicy()
    # training fast path (DESIGN.md §13): route full-sequence attention
    # through the custom-VJP flash Pallas kernel so the backward runs the
    # fused recompute-from-lse kernels. Off by default — the TrainEngine
    # flips it on for TPU backends (interpret mode is correctness-only).
    flash_train: bool = False

    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab + m - 1) // m) * m

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats + len(self.tail)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def use_int8_matmul(self) -> bool:
        """Fused Pallas int8 matmul on the quantized fast path; XLA
        dequant+einsum elsewhere (CPU tests, unquantized serving)."""
        return self.quant.weights_int8 and self.decode_kernel

    def attn_cfg(self, window: int = -1) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.resolved_head_dim,
            qkv_bias=self.qkv_bias, rope_theta=self.rope_theta,
            causal=True, window=window, pos_emb=self.pos_emb,
            mrope_sections=self.mrope_sections, sp=self.sp_attention,
            int8_kernel=self.use_int8_matmul, flash_vjp=self.flash_train)


# -----------------------------------------------------------------------------
# Parameter init
# -----------------------------------------------------------------------------

def _init_block(key, cfg: LMConfig, spec: BlockSpec, dtype) -> Axed:
    parts: Dict[str, Axed] = {}
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if spec.kind == "attn" and not spec.shared_attn:
        parts["norm_attn"] = layers.init_rmsnorm(cfg.d_model)
        parts["attn"] = layers.init_attention(k1, cfg.attn_cfg(spec.window), dtype)
    elif spec.kind == "ssd":
        parts["norm_ssd"] = layers.init_rmsnorm(cfg.d_model)
        parts["ssd"] = ssd_lib.init_ssd(k2, cfg.ssd_cfg, dtype)
    if spec.kind == "attn" and spec.has_ffn:
        parts["norm_ffn"] = layers.init_rmsnorm(cfg.d_model)
        if spec.moe:
            parts["moe"] = moe_lib.init_moe(k3, cfg.moe_cfg, dtype)
        else:
            parts["mlp"] = layers.init_mlp(k4, cfg.d_model, cfg.d_ff,
                                           gated=cfg.mlp_gated, dtype=dtype)
    return group_dict(parts)


def _has_shared(cfg: LMConfig) -> bool:
    return any(s.shared_attn for s in tuple(cfg.pattern) + tuple(cfg.tail))


def init_lm(key, cfg: LMConfig, dtype=jnp.bfloat16) -> Axed:
    keys = jax.random.split(key, 8)
    parts: Dict[str, Axed] = {"embed": layers.init_embed(
        keys[0], cfg.padded_vocab, cfg.d_model, dtype)}
    # repeated pattern: one stacked entry per pattern position
    for i, spec in enumerate(cfg.pattern):
        if spec.shared_attn:
            continue
        parts[f"pat{i}"] = common.vmap_init(
            lambda k, sp=spec: _init_block(k, cfg, sp, dtype),
            jax.random.fold_in(keys[1], i), cfg.repeats)
    for i, spec in enumerate(cfg.tail):
        if spec.shared_attn:
            continue
        parts[f"tail{i}"] = _init_block(jax.random.fold_in(keys[2], i), cfg, spec, dtype)
    if _has_shared(cfg):
        shared = {"norm_attn": layers.init_rmsnorm(cfg.d_model),
                  "attn": layers.init_attention(keys[3], cfg.attn_cfg(-1), dtype),
                  "norm_ffn": layers.init_rmsnorm(cfg.d_model),
                  "mlp": layers.init_mlp(keys[4], cfg.d_model, cfg.d_ff, dtype=dtype)}
        parts["shared_attn"] = group_dict(shared)
    parts["final_norm"] = layers.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        parts["unembed"] = layers.init_unembed(keys[5], cfg.d_model,
                                               cfg.padded_vocab, dtype)
    return group_dict(parts)


def quantize_lm(params: PyTree) -> PyTree:
    """Weight-tree int8 quantization for serving (QuantPolicy.weights_int8).

    Linear-layer leaves (quant.int8.SERVING_QUANT_KEYS) become
    ``{"q8": int8, "s8": fp32}`` with **per-output-channel** scales;
    embeddings, norms, and routers pass through untouched. Structure-aware:
    ``pat*`` groups carry a leading repeats dim and ``moe`` groups a leading
    expert dim — both are kept as independent scale dims, never reduced
    over. Consumed transparently by models.layers.wl (XLA dequant+einsum)
    or layers.q8_matmul (fused Pallas kernel) on the serving fast path.
    """
    from repro.quant import int8 as int8_lib

    def walk(p: dict, lead: int) -> dict:
        out = {}
        for k, v in p.items():
            if isinstance(v, dict):
                if "q8" in v:           # already quantized
                    out[k] = v
                elif k == "ssd":
                    # SSD blocks consume projections without the wl()
                    # dequant seam (and their state is not a KV cache) —
                    # they stay full precision
                    out[k] = v
                else:
                    out[k] = walk(v, lead + (1 if k == "moe" else 0))
            elif (k in int8_lib.SERVING_QUANT_KEYS
                  and getattr(v, "ndim", 0) >= lead + 2):
                out_dims = min(int8_lib.weight_out_dims(k), v.ndim - lead - 1)
                out[k] = int8_lib.quantize_weight(v, lead=lead,
                                                  out_dims=out_dims)
            else:
                out[k] = v
        return out

    return {k: (walk(v, 1 if k.startswith("pat") else 0)
                if isinstance(v, dict) else v)
            for k, v in params.items()}


# -----------------------------------------------------------------------------
# Block application (full-sequence)
# -----------------------------------------------------------------------------

def _apply_block(params, shared_params, cfg: LMConfig, spec: BlockSpec,
                 x: jnp.ndarray, positions,
                 arange_pos: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, aux_loss). ``arange_pos``: static flag that ``positions``
    is the synthesized 0..S-1 arange (flash-kernel eligibility)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.kind == "attn":
        p = shared_params if spec.shared_attn else params
        acfg = cfg.attn_cfg(spec.window)
        h = layers.rms_norm(p["norm_attn"], x)
        x = x + layers.attention(p["attn"], acfg, h, positions,
                                 arange_positions=arange_pos)
        if spec.shared_attn:
            h = layers.rms_norm(p["norm_ffn"], x)
            x = x + layers.mlp(p["mlp"], h, cfg.act,
                               int8_kernel=cfg.use_int8_matmul)
            return x, aux
    elif spec.kind == "ssd":
        h = layers.rms_norm(params["norm_ssd"], x)
        x = x + ssd_lib.ssd_block(params["ssd"], cfg.ssd_cfg, h)
    if spec.kind == "attn" and spec.has_ffn and not spec.shared_attn:
        h = layers.rms_norm(params["norm_ffn"], x)
        if spec.moe:
            y, aux = moe_lib.moe_capacity(params["moe"], cfg.moe_cfg, h,
                                          cfg.moe_group_size)
            x = x + y
        else:
            x = x + layers.mlp(params["mlp"], h, cfg.act,
                               int8_kernel=cfg.use_int8_matmul)
    if cfg.sp_residual:
        x = constrain(x, "batch", "seq_tp", None)
    return x, aux


def _remat(cfg: LMConfig, fn):
    if cfg.remat == "none":
        return fn

    def fn_ob(carry, xs):
        # barrier stops XLA hoisting convert(saved-carry-stack) out of the
        # backward loop, which otherwise materializes a full fp32 copy of
        # every layer's saved activations (+25 GB/device on mamba2 train_4k;
        # EXPERIMENTS.md §Perf iter 0)
        carry = jax.lax.optimization_barrier(carry)
        return fn(carry, xs)

    if cfg.remat == "dots":
        return jax.checkpoint(
            fn_ob, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn_ob)


def _pattern_stack_params(params, cfg: LMConfig):
    return {f"pat{i}": params[f"pat{i}"]
            for i, s in enumerate(cfg.pattern) if not s.shared_attn}


def forward(params, cfg: LMConfig, tokens: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None,
            vision_embeds: Optional[jnp.ndarray] = None,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. tokens (B,S) -> (logits (B,S,V) fp32, aux)."""
    b, s = tokens.shape
    x = layers.embed(params["embed"], tokens)
    if vision_embeds is not None and cfg.vision_tokens > 0:
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0))
    x = constrain(x, "batch", "seq", None)
    arange_pos = positions is None
    if positions is None:
        pos1d = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        positions = (jnp.broadcast_to(pos1d[..., None], (b, s, 3))
                     if cfg.pos_emb == "mrope" else pos1d)
    shared = params.get("shared_attn")

    def body(carry, pat_params):
        x, aux = carry
        for i, spec in enumerate(cfg.pattern):
            p = pat_params.get(f"pat{i}")
            x, a = _apply_block(p, shared, cfg, spec, x, positions,
                                arange_pos=arange_pos)
            aux = aux + a
        return (x, aux), None

    aux0 = jnp.zeros((), jnp.float32)
    if cfg.repeats > 0:
        (x, aux), _ = jax.lax.scan(_remat(cfg, body), (x, aux0),
                                   _pattern_stack_params(params, cfg))
    else:
        aux = aux0
    for i, spec in enumerate(cfg.tail):
        p = params.get(f"tail{i}")
        x, a = _apply_block(p, shared, cfg, spec, x, positions,
                            arange_pos=arange_pos)
        aux = aux + a
    x = layers.rms_norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.apply_unembed(params["unembed"], x)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


def loss_fn(params, cfg: LMConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (+ MoE aux + optional z-loss)."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          positions=batch.get("positions"),
                          vision_embeds=batch.get("vision_embeds"))
    labels = batch["labels"]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - label_logit) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    total = ce + aux
    if cfg.z_loss > 0:
        total = total + cfg.z_loss * ((logz * mask) ** 2).sum() / denom
    return total, {"ce": ce, "aux": aux, "tokens": denom}


# -----------------------------------------------------------------------------
# Caches (decode)
# -----------------------------------------------------------------------------

def _cache_len(cfg: LMConfig, spec: BlockSpec, max_len: int) -> int:
    if cfg.ring_cache and spec.window > 0:
        return min(spec.window, max_len)
    return max_len


def init_caches(cfg: LMConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> Dict[str, PyTree]:
    """Cache pytree: pattern positions stacked over repeats, tail single.

    Under ``cfg.quant.kv_int8`` the K/V arrays are int8 codes and each attn
    cache gains a ``kv_scale`` pair — one fp32 scale per (slot, position,
    kv-head) — so the resident cache is ~4x smaller than fp32 (``dtype`` is
    ignored for K/V in that mode).
    """
    caches: Dict[str, PyTree] = {}
    kvh, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    kv_dtype = jnp.int8 if cfg.quant.kv_int8 else dtype

    def one(spec: BlockSpec, stacked: bool):
        if spec.kind == "attn":
            clen = _cache_len(cfg, spec, max_len)
            shape = (cfg.repeats,) if stacked else ()
            kv = KVCache(
                k=jnp.zeros(shape + (batch, clen, kvh, dh), kv_dtype),
                v=jnp.zeros(shape + (batch, clen, kvh, dh), kv_dtype))
            # per-row ring position tags (rows decode at independent positions
            # under the serving engine's vmapped path)
            pos = jnp.full(shape + (batch, clen), -1, jnp.int32)
            if cfg.quant.kv_int8:
                sc = KVCache(
                    k=jnp.zeros(shape + (batch, clen, kvh), jnp.float32),
                    v=jnp.zeros(shape + (batch, clen, kvh), jnp.float32))
                return {"kv": kv, "kv_scale": sc, "pos": pos}
            return {"kv": kv, "pos": pos}
        st = ssd_lib.init_ssd_state(cfg.ssd_cfg, batch, dtype)
        if stacked:
            st = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.repeats,) + a.shape), st)
        return {"ssd": st}

    for i, spec in enumerate(cfg.pattern):
        caches[f"pat{i}"] = one(spec, stacked=True)
    for i, spec in enumerate(cfg.tail):
        caches[f"tail{i}"] = one(spec, stacked=False)
    return caches


def _decode_attn(p, cfg: LMConfig, spec: BlockSpec, x, cache, pos):
    """One-token attention against a (possibly ring) cache.

    ``pos`` is a scalar () shared by every row (classic decode, dry-run
    cells) or a (B,) vector of independent per-slot positions (the serving
    engine's slot-major batched decode).
    """
    acfg = cfg.attn_cfg(spec.window)
    b = x.shape[0]
    kv, pos_tags = cache["kv"], cache["pos"]
    kv_int8 = "kv_scale" in cache
    clen = kv.k.shape[1]
    batched_pos = pos.ndim > 0
    if batched_pos:
        positions = pos[:, None].astype(jnp.int32)            # (B, 1)
    else:
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
    if cfg.pos_emb == "mrope":
        positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))
    q, k_new, v_new = layers._project_qkv(p["attn"], acfg, x, positions)
    if kv_int8:
        # per-(row, head) int8: the cache stores codes + one fp32 scale per
        # (slot, position, kv-head); full-precision K/V exist only for the
        # one new token, in registers
        from repro.quant import int8 as int8_lib
        sc = cache["kv_scale"]
        k_q, k_s = int8_lib.quantize_rowwise(k_new)     # (B,1,H,D),(B,1,H)
        v_q, v_s = int8_lib.quantize_rowwise(v_new)
    if batched_pos:
        # per-row ring slot: one scatter row per sequence
        slot = (pos % clen).astype(jnp.int32)                  # (B,)
        rows = jnp.arange(b)
        if kv_int8:
            k = kv.k.at[rows, slot].set(k_q[:, 0])
            v = kv.v.at[rows, slot].set(v_q[:, 0])
            k_scale = sc.k.at[rows, slot].set(k_s[:, 0])
            v_scale = sc.v.at[rows, slot].set(v_s[:, 0])
        else:
            k = kv.k.at[rows, slot].set(k_new[:, 0].astype(kv.k.dtype))
            v = kv.v.at[rows, slot].set(v_new[:, 0].astype(kv.v.dtype))
        pos_tags = pos_tags.at[rows, slot].set(pos.astype(jnp.int32))
    else:
        slot = pos % clen      # ring slot; == pos when the cache is full-length
        if kv_int8:
            k = jax.lax.dynamic_update_slice(kv.k, k_q, (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(kv.v, v_q, (0, slot, 0, 0))
            k_scale = jax.lax.dynamic_update_slice(sc.k, k_s, (0, slot, 0))
            v_scale = jax.lax.dynamic_update_slice(sc.v, v_s, (0, slot, 0))
        else:
            k = jax.lax.dynamic_update_slice(kv.k, k_new.astype(kv.k.dtype),
                                             (0, slot, 0, 0))
            v = jax.lax.dynamic_update_slice(kv.v, v_new.astype(kv.v.dtype),
                                             (0, slot, 0, 0))
        pos_col = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
        pos_tags = jax.lax.dynamic_update_slice(pos_tags, pos_col, (0, slot))
    q_pos = positions[..., 0] if positions.ndim == 3 else positions
    if batched_pos and cfg.decode_kernel and not cfg.ring_cache:
        # Pallas decode kernel: per-slot lengths => dead/short slots cost no
        # FLOPs. Valid cache rows are the contiguous prefix [0, pos] (the
        # serving engine's invariant for non-ring caches). Int8 caches hand
        # the kernel codes + scales; dequant happens inside the kernel body.
        from repro.kernels import ops as kops
        out = kops.decode_attention(
            q[:, 0], k, v, pos.astype(jnp.int32) + 1, scale=acfg.scale,
            window=spec.window,
            k_scale=k_scale if kv_int8 else None,
            v_scale=v_scale if kv_int8 else None)[:, None]
    else:
        if kv_int8:
            # XLA fallback: dequantize at use (fused into the attention
            # matmul's operand load; storage/traffic stays int8)
            k_at = int8_lib.dequantize_rowwise(k, k_scale, dtype=q.dtype)
            v_at = int8_lib.dequantize_rowwise(v, v_scale, dtype=q.dtype)
        else:
            k_at, v_at = k, v
        mask = layers.attention_mask(q_pos, pos_tags, causal=True,
                                     window=spec.window)
        mask &= (pos_tags >= 0)[:, None, :]
        out = layers.sdpa(q, k_at, v_at, mask, acfg.scale)
    if layers._q8_active(acfg, p["attn"]["wo"]):
        y = layers.q8_matmul(out, p["attn"]["wo"], contract_ndim=2)
    else:
        y = jnp.einsum("bshk,hkd->bsd", out,
                       layers.wl(p["attn"]["wo"], out.dtype))
    new_cache = {"kv": KVCache(k=k, v=v), "pos": pos_tags}
    if kv_int8:
        new_cache["kv_scale"] = KVCache(k=k_scale, v=v_scale)
    return y, new_cache


def _decode_block(params, shared_params, cfg: LMConfig, spec: BlockSpec,
                  x, cache, pos):
    if spec.kind == "attn":
        p = shared_params if spec.shared_attn else params
        h = layers.rms_norm(p["norm_attn"], x)
        y, cache = _decode_attn(p, cfg, spec, h, cache, pos)
        x = x + y
        if spec.shared_attn:
            h = layers.rms_norm(p["norm_ffn"], x)
            return x + layers.mlp(p["mlp"], h, cfg.act,
                               int8_kernel=cfg.use_int8_matmul), cache
    else:
        h = layers.rms_norm(params["norm_ssd"], x)
        y, st = ssd_lib.ssd_block_decode(params["ssd"], cfg.ssd_cfg, h,
                                         cache["ssd"])
        x = x + y
        cache = {"ssd": st}
    if spec.kind == "attn" and spec.has_ffn and not spec.shared_attn:
        h = layers.rms_norm(params["norm_ffn"], x)
        if spec.moe:
            y, _ = moe_lib.moe_capacity(params["moe"], cfg.moe_cfg, h,
                                        group_size=h.shape[0] * h.shape[1])
            x = x + y
        else:
            x = x + layers.mlp(params["mlp"], h, cfg.act,
                               int8_kernel=cfg.use_int8_matmul)
    return x, cache


def decode_step(params, cfg: LMConfig, token: jnp.ndarray, pos: jnp.ndarray,
                caches: Dict[str, PyTree]
                ) -> Tuple[jnp.ndarray, Dict[str, PyTree]]:
    """One decode step. token (B,1) int32 -> (logits (B,1,V), caches).

    pos is () int32 (all rows at the same position) or (B,) int32 (per-slot
    positions — the serving engine's continuous-batching decode tick).
    """
    x = layers.embed(params["embed"], token)
    shared = params.get("shared_attn")

    pat_caches = {f"pat{i}": caches[f"pat{i}"] for i in range(len(cfg.pattern))}

    def body(x, inp):
        pat_params, pat_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            x, nc = _decode_block(pat_params.get(f"pat{i}"), shared, cfg, spec,
                                  x, pat_cache[f"pat{i}"], pos)
            new_cache[f"pat{i}"] = nc
        return x, new_cache

    new_caches: Dict[str, PyTree] = {}
    if cfg.repeats > 0:
        x, new_pat = jax.lax.scan(body, x,
                                  (_pattern_stack_params(params, cfg), pat_caches))
        new_caches.update(new_pat)
    for i, spec in enumerate(cfg.tail):
        x, nc = _decode_block(params.get(f"tail{i}"), shared, cfg, spec, x,
                              caches[f"tail{i}"], pos)
        new_caches[f"tail{i}"] = nc
    return _lm_head(params, cfg, x), new_caches


def _lm_head(params, cfg: LMConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Shared head: final norm -> (un)tied unembed -> softcap ->
    true-vocab slice. Used by every cached-decode entry point (decode,
    prefill, the paged paths) so admission and decode sample from the same
    distribution family. prefill historically skipped logit_softcap —
    harmless for argmax (tanh is monotonic) but it biased first-token
    *temperature* sampling on softcap archs; unified here (no shipped
    config sets softcap > 0, so no behavior shift today)."""
    x = layers.rms_norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x)
    else:
        logits = layers.apply_unembed(params["unembed"], x)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits[..., :cfg.vocab]


# -----------------------------------------------------------------------------
# Paged KV cache (DESIGN.md §14): block pool + page-table indirection
# -----------------------------------------------------------------------------

def paged_supported(cfg: LMConfig) -> bool:
    """The paged path is attention-only (SSM states are not position-
    addressable) and replaces ring caches (pages are not reclaimed by
    window; window masking still applies)."""
    return (not cfg.ring_cache
            and all(sp.kind == "attn"
                    for sp in tuple(cfg.pattern) + tuple(cfg.tail)))


def init_paged_caches(cfg: LMConfig, num_pages: int, page_size: int,
                      dtype=jnp.bfloat16) -> Dict[str, PyTree]:
    """KV block pools: ``num_pages + 1`` pages of ``page_size`` tokens per
    attention layer (same pattern/tail tree shape as :func:`init_caches`,
    pool-major instead of slot-major). The extra page is the **sink** —
    writes from dead/padded lanes land there, so the host allocator can
    recycle pages without any device-side scrub. One page table (built by
    the serve engine) maps every layer's logical blocks to the same
    physical page ids, which is what makes block-granular prefix sharing a
    page-table copy instead of a per-layer KV copy.

    No position-tag array: the engine maintains the contiguous-prefix
    invariant (slot b's valid logical positions are exactly
    ``[0, len_b)`` through its page chain), so validity is ``pos < len``.
    Under ``cfg.quant.kv_int8`` pools hold int8 codes plus per-(page,
    offset, kv-head) fp32 scale pools, exactly mirroring the dense int8
    cache representation.
    """
    if not paged_supported(cfg):
        raise NotImplementedError(
            "paged KV caches are attention-only and incompatible with "
            "ring_cache; use init_caches for SSD/hybrid or ring archs")
    caches: Dict[str, PyTree] = {}
    kvh, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    kv_dtype = jnp.int8 if cfg.quant.kv_int8 else dtype
    p = num_pages + 1                              # +1: sink page

    def one(stacked: bool):
        shape = (cfg.repeats,) if stacked else ()
        kv = KVCache(
            k=jnp.zeros(shape + (p, page_size, kvh, dh), kv_dtype),
            v=jnp.zeros(shape + (p, page_size, kvh, dh), kv_dtype))
        if cfg.quant.kv_int8:
            sc = KVCache(
                k=jnp.zeros(shape + (p, page_size, kvh), jnp.float32),
                v=jnp.zeros(shape + (p, page_size, kvh), jnp.float32))
            return {"kv": kv, "kv_scale": sc}
        return {"kv": kv}

    for i, _ in enumerate(cfg.pattern):
        caches[f"pat{i}"] = one(stacked=True)
    for i, _ in enumerate(cfg.tail):
        caches[f"tail{i}"] = one(stacked=False)
    return caches


def _paged_gather(cache, page_table: jnp.ndarray, compute_dtype):
    """Gather a slot-major (B, NB*page_size, kvh, dh) K/V view through the
    page table (XLA fallback path; the Pallas kernel's index_map does this
    per-tile without materializing the view). Int8 pools dequantize at
    gather so attention sees exactly what the dense int8 path sees."""
    kv = cache["kv"]
    b, nb = page_table.shape
    ps = kv.k.shape[1]

    def flat(pool):
        g = pool[page_table]                       # (B, NB, ps, ...)
        return g.reshape((b, nb * ps) + g.shape[3:])

    k_all, v_all = flat(kv.k), flat(kv.v)
    if "kv_scale" in cache:
        from repro.quant import int8 as int8_lib
        sc = cache["kv_scale"]
        k_all = int8_lib.dequantize_rowwise(k_all, flat(sc.k),
                                            dtype=compute_dtype)
        v_all = int8_lib.dequantize_rowwise(v_all, flat(sc.v),
                                            dtype=compute_dtype)
    else:
        k_all = k_all.astype(compute_dtype)
        v_all = v_all.astype(compute_dtype)
    return k_all, v_all


def move_pages(caches: Dict[str, PyTree], src: jnp.ndarray,
               dst: jnp.ndarray) -> Dict[str, PyTree]:
    """Copy pool page ``src[i]`` -> ``dst[i]`` in every layer's K/V (and
    scale) pool — the device half of page-table compaction (DESIGN.md §16).
    ``src``/``dst`` are (M,) int32; padding entries may point both at the
    sink page (a sink->sink copy is the identity). The caller (serve
    engine) owns the host-side invariants: destinations are freshly
    allocated private pages, sources are released after the copy, and the
    slot's page-table row is rewritten in the same device call."""
    def per_key(key, sub):
        ax = 1 if key.startswith("pat") else 0

        def mv(pool):
            if ax == 0:
                return pool.at[dst].set(pool[src])
            return pool.at[:, dst].set(pool[:, src])

        return jax.tree.map(mv, sub)

    return {k: per_key(k, v) for k, v in caches.items()}


def cow_pages(caches: Dict[str, PyTree], page_table: jnp.ndarray,
              src: jnp.ndarray, dst: jnp.ndarray, slot_idx: jnp.ndarray,
              blk_idx: jnp.ndarray, entry: jnp.ndarray
              ) -> Tuple[Dict[str, PyTree], jnp.ndarray]:
    """Copy-on-write divergence, device half (DESIGN.md §18): duplicate
    pool pages ``src[i] -> dst[i]`` in every layer (``move_pages``) and
    redirect the forked slots' table entries ``page_table[slot_idx[i],
    blk_idx[i]] = entry[i]`` in the same call. All three index vectors are
    (M,) and sink/OOB-padded — a sink->sink copy is the identity and an
    out-of-bounds slot row drops — so one executable serves every event
    count. Retain-only redirects (the last co-owner adopting a page
    without a byte copy) pass ``src == dst == sink``; the engine bills
    only real copies as COW bytes."""
    caches = move_pages(caches, src, dst)
    pt = page_table.at[slot_idx, blk_idx].set(entry, mode="drop")
    return caches, pt


def _paged_decode_attn(p, cfg: LMConfig, spec: BlockSpec, x, cache,
                       pos: jnp.ndarray, page_table: jnp.ndarray,
                       active: jnp.ndarray):
    """One-token attention against the paged pool. ``pos`` is (B,) per-slot
    positions (the paged path is serve-engine-only, always batched);
    ``active`` routes dead lanes' writes to the sink page — their table
    entries may point at pages since recycled to other slots."""
    acfg = cfg.attn_cfg(spec.window)
    b = x.shape[0]
    kv = cache["kv"]
    kv_int8 = "kv_scale" in cache
    ps = kv.k.shape[1]
    nb = page_table.shape[1]
    sink = kv.k.shape[0] - 1
    positions = pos[:, None].astype(jnp.int32)                  # (B, 1)
    if cfg.pos_emb == "mrope":
        positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))
    q, k_new, v_new = layers._project_qkv(p["attn"], acfg, x, positions)
    rows = jnp.arange(b)
    blk = jnp.clip(pos // ps, 0, nb - 1).astype(jnp.int32)
    page = jnp.where(active, page_table[rows, blk], sink)
    off = (pos % ps).astype(jnp.int32)
    if kv_int8:
        from repro.quant import int8 as int8_lib
        sc = cache["kv_scale"]
        k_q, k_s = int8_lib.quantize_rowwise(k_new)     # (B,1,H,D),(B,1,H)
        v_q, v_s = int8_lib.quantize_rowwise(v_new)
        k = kv.k.at[page, off].set(k_q[:, 0])
        v = kv.v.at[page, off].set(v_q[:, 0])
        k_scale = sc.k.at[page, off].set(k_s[:, 0])
        v_scale = sc.v.at[page, off].set(v_s[:, 0])
        new_cache = {"kv": KVCache(k=k, v=v),
                     "kv_scale": KVCache(k=k_scale, v=v_scale)}
    else:
        k = kv.k.at[page, off].set(k_new[:, 0].astype(kv.k.dtype))
        v = kv.v.at[page, off].set(v_new[:, 0].astype(kv.v.dtype))
        new_cache = {"kv": KVCache(k=k, v=v)}
    lengths = (pos + 1).astype(jnp.int32)
    if cfg.decode_kernel:
        from repro.kernels import ops as kops
        out = kops.paged_decode_attention(
            q[:, 0], k, v, page_table, lengths, scale=acfg.scale,
            window=spec.window,
            k_scale=new_cache["kv_scale"].k if kv_int8 else None,
            v_scale=new_cache["kv_scale"].v if kv_int8 else None)[:, None]
    else:
        k_all, v_all = _paged_gather(new_cache, page_table, q.dtype)
        j_abs = jnp.arange(nb * ps, dtype=jnp.int32)[None]      # (1, W)
        tags = jnp.where(j_abs < lengths[:, None], j_abs, -1)
        q_pos = positions[..., 0] if positions.ndim == 3 else positions
        mask = layers.attention_mask(q_pos, tags, causal=True,
                                     window=spec.window)
        mask &= (tags >= 0)[:, None, :]
        out = layers.sdpa(q, k_all, v_all, mask, acfg.scale)
    if layers._q8_active(acfg, p["attn"]["wo"]):
        y = layers.q8_matmul(out, p["attn"]["wo"], contract_ndim=2)
    else:
        y = jnp.einsum("bshk,hkd->bsd", out,
                       layers.wl(p["attn"]["wo"], out.dtype))
    return y, new_cache


def _paged_verify_attn(p, cfg: LMConfig, spec: BlockSpec, x, cache,
                       pos: jnp.ndarray, page_table: jnp.ndarray,
                       active: jnp.ndarray):
    """Multi-query attention for speculative verification (DESIGN.md §15):
    a q-block of T tokens per slot — the committed pending token plus the
    drafts — written through the page table and attended causally.

    Every key, including the chunk's own tokens, is read back *through the
    storage dtype* (the gathered pool / the paged kernel), which is exactly
    what sequential ``paged_decode_step`` ticks would see — so lane t's
    logits match the plain single-token tick bit for bit and temp=0
    rejection sampling reproduces the plain stream. (``paged_extend``
    deliberately differs: it attends the in-flight chunk in full precision
    to match *prefill* numerics.)

    Writes of inactive lanes and of positions past the page-table capacity
    go to the sink page; rejected lanes need no cleanup at all — the
    engine simply does not advance ``pos`` past the accepted prefix, the
    ``pos < length`` validity invariant masks the stale writes, and the
    next tick overwrites them.
    """
    acfg = cfg.attn_cfg(spec.window)
    b, t = x.shape[:2]
    kv = cache["kv"]
    kv_int8 = "kv_scale" in cache
    ps = kv.k.shape[1]
    nb = page_table.shape[1]
    sink = kv.k.shape[0] - 1
    rel = jnp.arange(t, dtype=jnp.int32)[None]                  # (1, T)
    pos_abs = pos[:, None].astype(jnp.int32) + rel              # (B, T)
    positions = (jnp.broadcast_to(pos_abs[..., None], (b, t, 3))
                 if cfg.pos_emb == "mrope" else pos_abs)
    q, k_new, v_new = layers._project_qkv(p["attn"], acfg, x, positions)
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t))
    blk = jnp.clip(pos_abs // ps, 0, nb - 1)
    writable = active[:, None] & (pos_abs < nb * ps)
    page = jnp.where(writable, page_table[rows, blk], sink)     # (B, T)
    off = pos_abs % ps
    if kv_int8:
        from repro.quant import int8 as int8_lib
        sc = cache["kv_scale"]
        k_q, k_s = int8_lib.quantize_rowwise(k_new)
        v_q, v_s = int8_lib.quantize_rowwise(v_new)
        new_cache = {
            "kv": KVCache(k=kv.k.at[page, off].set(k_q),
                          v=kv.v.at[page, off].set(v_q)),
            "kv_scale": KVCache(k=sc.k.at[page, off].set(k_s),
                                v=sc.v.at[page, off].set(v_s))}
    else:
        new_cache = {"kv": KVCache(
            k=kv.k.at[page, off].set(k_new.astype(kv.k.dtype)),
            v=kv.v.at[page, off].set(v_new.astype(kv.v.dtype)))}
    # total valid length per slot INCLUDING the chunk; lanes clipped to the
    # sink (pos_abs >= nb*ps, only possible at the max_len edge) simply
    # have no key to attend — the engine never emits from those lanes
    lengths = (pos + t).astype(jnp.int32)
    if cfg.decode_kernel:
        from repro.kernels import ops as kops
        out = kops.paged_verify_attention(
            q, new_cache["kv"].k, new_cache["kv"].v, page_table, lengths,
            scale=acfg.scale, window=spec.window,
            k_scale=new_cache["kv_scale"].k if kv_int8 else None,
            v_scale=new_cache["kv_scale"].v if kv_int8 else None)
    else:
        k_all, v_all = _paged_gather(new_cache, page_table, q.dtype)
        j_abs = jnp.arange(nb * ps, dtype=jnp.int32)[None]      # (1, W)
        tags = jnp.where(j_abs < lengths[:, None], j_abs, -1)
        mask = layers.attention_mask(pos_abs, tags, causal=True,
                                     window=spec.window)
        mask &= (tags >= 0)[:, None, :]
        out = layers.sdpa(q, k_all, v_all, mask, acfg.scale)
    if layers._q8_active(acfg, p["attn"]["wo"]):
        y = layers.q8_matmul(out, p["attn"]["wo"], contract_ndim=2)
    else:
        y = jnp.einsum("bshk,hkd->bsd", out,
                       layers.wl(p["attn"]["wo"], out.dtype))
    return y, new_cache


def _paged_decode_block(params, shared_params, cfg: LMConfig,
                        spec: BlockSpec, x, cache, pos, page_table, active,
                        attn=_paged_decode_attn):
    p = shared_params if spec.shared_attn else params
    h = layers.rms_norm(p["norm_attn"], x)
    y, cache = attn(p, cfg, spec, h, cache, pos, page_table, active)
    x = x + y
    if spec.shared_attn:
        h = layers.rms_norm(p["norm_ffn"], x)
        return x + layers.mlp(p["mlp"], h, cfg.act,
                              int8_kernel=cfg.use_int8_matmul), cache
    if spec.has_ffn:
        h = layers.rms_norm(params["norm_ffn"], x)
        if spec.moe:
            y, _ = moe_lib.moe_capacity(params["moe"], cfg.moe_cfg, h,
                                        group_size=h.shape[0] * h.shape[1])
            x = x + y
        else:
            x = x + layers.mlp(params["mlp"], h, cfg.act,
                               int8_kernel=cfg.use_int8_matmul)
    return x, cache


def paged_decode_step(params, cfg: LMConfig, token: jnp.ndarray,
                      pos: jnp.ndarray, page_table: jnp.ndarray,
                      caches: Dict[str, PyTree],
                      active: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Dict[str, PyTree]]:
    """One decode step against the paged pools. token (B,1) int32, pos (B,)
    per-slot positions, page_table (B, NB) -> (logits (B,1,V), caches).

    ``active`` (B,) bool: lanes that are really decoding. Inactive lanes
    still flow through the batch (the engine tick is one fused call) but
    their K/V writes are routed to the sink page — their page-table rows
    may reference pages that have been recycled to other slots.
    """
    if active is None:
        active = jnp.ones(token.shape[0], bool)
    x = layers.embed(params["embed"], token)
    shared = params.get("shared_attn")
    pat_caches = {f"pat{i}": caches[f"pat{i}"]
                  for i in range(len(cfg.pattern))}

    def body(x, inp):
        pat_params, pat_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            x, nc = _paged_decode_block(pat_params.get(f"pat{i}"), shared,
                                        cfg, spec, x, pat_cache[f"pat{i}"],
                                        pos, page_table, active)
            new_cache[f"pat{i}"] = nc
        return x, new_cache

    new_caches: Dict[str, PyTree] = {}
    if cfg.repeats > 0:
        x, new_pat = jax.lax.scan(
            body, x, (_pattern_stack_params(params, cfg), pat_caches))
        new_caches.update(new_pat)
    for i, spec in enumerate(cfg.tail):
        x, nc = _paged_decode_block(params.get(f"tail{i}"), shared, cfg,
                                    spec, x, caches[f"tail{i}"], pos,
                                    page_table, active)
        new_caches[f"tail{i}"] = nc
    return _lm_head(params, cfg, x), new_caches


def paged_verify_step(params, cfg: LMConfig, tokens: jnp.ndarray,
                      pos: jnp.ndarray, page_table: jnp.ndarray,
                      caches: Dict[str, PyTree],
                      active: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Dict[str, PyTree]]:
    """Speculative verification step (DESIGN.md §15): score a q-block of T
    tokens per slot in ONE forward pass against the paged pools.

    tokens (B, T) int32 — per slot, the committed pending token followed by
    T-1 drafted tokens; pos (B,) — cache length before the tick (token t
    lands at logical position ``pos + t``). Returns (logits (B, T, V),
    caches): logits row t is the target distribution for the token *after*
    ``tokens[:, :t+1]`` — draft t (``tokens[:, t]``, t >= 1) is accepted
    against row t-1 (serve/spec.speculative_accept), and row T-1 supplies
    the bonus token. All T lanes' K/V are written through the
    page table (rejection rolls back by rewinding ``pos``, never by
    scrubbing); attention is causal over the slot's whole chain *through
    the storage dtype*, matching sequential ``paged_decode_step`` numerics
    exactly — at temperature 0 the accepted stream is the plain paged
    stream, token for token.

    Lane-coupled blocks (MoE capacity routing) make verify numerics
    batch-dependent; parity there is measured, not structural (the test
    matrix covers dense-FFN archs).
    """
    if active is None:
        active = jnp.ones(tokens.shape[0], bool)
    x = layers.embed(params["embed"], tokens)
    shared = params.get("shared_attn")
    pat_caches = {f"pat{i}": caches[f"pat{i}"]
                  for i in range(len(cfg.pattern))}

    def body(x, inp):
        pat_params, pat_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            x, nc = _paged_decode_block(pat_params.get(f"pat{i}"), shared,
                                        cfg, spec, x, pat_cache[f"pat{i}"],
                                        pos, page_table, active,
                                        attn=_paged_verify_attn)
            new_cache[f"pat{i}"] = nc
        return x, new_cache

    new_caches: Dict[str, PyTree] = {}
    if cfg.repeats > 0:
        x, new_pat = jax.lax.scan(
            body, x, (_pattern_stack_params(params, cfg), pat_caches))
        new_caches.update(new_pat)
    for i, spec in enumerate(cfg.tail):
        x, nc = _paged_decode_block(params.get(f"tail{i}"), shared, cfg,
                                    spec, x, caches[f"tail{i}"], pos,
                                    page_table, active,
                                    attn=_paged_verify_attn)
        new_caches[f"tail{i}"] = nc
    return _lm_head(params, cfg, x), new_caches


def paged_extend(params, cfg: LMConfig, tokens: jnp.ndarray,
                 starts: jnp.ndarray, lens: jnp.ndarray,
                 page_table: jnp.ndarray, caches: Dict[str, PyTree]
                 ) -> Tuple[jnp.ndarray, Dict[str, PyTree]]:
    """Extend-prefill: run a chunk of prompt tokens against pre-populated
    paged caches. The single primitive behind suffix-after-prefix-hit
    admission AND chunked prefill (DESIGN.md §14).

    tokens: (B, C) right-padded chunk per row; starts: (B,) absolute
    position of each row's first chunk token (0 = plain prefill;
    ``shared_len`` after a prefix-cache hit; ``k*chunk`` mid-chunking);
    lens: (B,) valid tokens per row this call (0 = dead row — its writes
    go to the sink page). Chunk K/V is written into the row's pages, then
    each chunk query attends over the gathered cache window [0, start)
    **plus the chunk itself in full precision** — exactly the dense
    prefill's numerics for the in-chunk part and the dense decode's
    (storage-dtype round-tripped) numerics for the cached part.

    Returns per-row logits at the chunk's last valid token, (B, 1, V) —
    meaningful only for rows whose prompt ends in this chunk.
    """
    b, c = tokens.shape
    nb = page_table.shape[1]
    x = layers.embed(params["embed"], tokens)
    rel = jnp.arange(c, dtype=jnp.int32)[None]                  # (1, C)
    valid = rel < lens[:, None]                                 # (B, C)
    pos_abs = starts[:, None].astype(jnp.int32) + rel           # (B, C)
    shared = params.get("shared_attn")

    def fill_attn(p, spec, x, cache):
        acfg = cfg.attn_cfg(spec.window)
        kv = cache["kv"]
        kv_int8 = "kv_scale" in cache
        ps = kv.k.shape[1]
        sink = kv.k.shape[0] - 1
        w = nb * ps
        positions = (jnp.broadcast_to(pos_abs[..., None], (b, c, 3))
                     if cfg.pos_emb == "mrope" else pos_abs)
        h = layers.rms_norm(p["norm_attn"], x)
        q, k_new, v_new = layers._project_qkv(p["attn"], acfg, h, positions)
        # scatter the chunk's K/V into the rows' pages (invalid lanes ->
        # sink); rope-rotated K is what lands in HBM, same as prefill
        rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, c))
        blk = jnp.clip(pos_abs // ps, 0, nb - 1)
        page = jnp.where(valid, page_table[rows, blk], sink)    # (B, C)
        off = pos_abs % ps
        if kv_int8:
            from repro.quant import int8 as int8_lib
            sc = cache["kv_scale"]
            k_st, k_sc = int8_lib.quantize_rowwise(k_new)
            v_st, v_sc = int8_lib.quantize_rowwise(v_new)
            kc = kv.k.at[page, off].set(k_st)
            vc = kv.v.at[page, off].set(v_st)
            new_cache = {
                "kv": KVCache(k=kc, v=vc),
                "kv_scale": KVCache(k=sc.k.at[page, off].set(k_sc),
                                    v=sc.v.at[page, off].set(v_sc))}
        else:
            kc = kv.k.at[page, off].set(k_new.astype(kv.k.dtype))
            vc = kv.v.at[page, off].set(v_new.astype(kv.v.dtype))
            new_cache = {"kv": KVCache(k=kc, v=vc)}
        # attend over the cached window [0, start) plus the chunk itself in
        # full precision (dense-prefill numerics for the in-chunk part, the
        # dense decode's storage-dtype numerics for the cached part).
        # Kernel path (DESIGN.md §16): the page table rides in scalar-
        # prefetch SMEM and each K/V tile is DMA'd straight from its pool
        # page — per-row gather traffic is ceil(start/ps) pages instead of
        # the XLA fallback's whole-window materialization.
        if cfg.decode_kernel:
            from repro.kernels import ops as kops
            out = kops.paged_prefill_attention(
                q, k_new.astype(q.dtype), v_new.astype(q.dtype),
                new_cache["kv"].k, new_cache["kv"].v, page_table,
                starts, lens, scale=acfg.scale, window=spec.window,
                k_scale=new_cache["kv_scale"].k if kv_int8 else None,
                v_scale=new_cache["kv_scale"].v if kv_int8 else None)
            if layers._q8_active(acfg, p["attn"]["wo"]):
                y = layers.q8_matmul(out, p["attn"]["wo"], contract_ndim=2)
            else:
                y = jnp.einsum("bshk,hkd->bsd", out,
                               layers.wl(p["attn"]["wo"], out.dtype))
            return x + y, new_cache
        k_all, v_all = _paged_gather(new_cache, page_table, q.dtype)
        j_abs = jnp.arange(w, dtype=jnp.int32)[None]            # (1, W)
        rel_w = j_abs - starts[:, None]                         # (B, W)
        in_chunk = (rel_w >= 0) & (rel_w < lens[:, None])
        idx = jnp.clip(rel_w, 0, c - 1)
        k_att = jnp.where(in_chunk[..., None, None],
                          jnp.take_along_axis(k_new.astype(q.dtype),
                                              idx[..., None, None], axis=1),
                          k_all)
        v_att = jnp.where(in_chunk[..., None, None],
                          jnp.take_along_axis(v_new.astype(q.dtype),
                                              idx[..., None, None], axis=1),
                          v_all)
        tags = jnp.where(j_abs < (starts + lens)[:, None], j_abs, -1)
        mask = layers.attention_mask(pos_abs, tags, causal=True,
                                     window=spec.window)
        mask &= (tags >= 0)[:, None, :]
        out = layers.sdpa(q, k_att, v_att, mask, acfg.scale)
        if layers._q8_active(acfg, p["attn"]["wo"]):
            y = layers.q8_matmul(out, p["attn"]["wo"], contract_ndim=2)
        else:
            y = jnp.einsum("bshk,hkd->bsd", out,
                           layers.wl(p["attn"]["wo"], out.dtype))
        return x + y, new_cache

    def fill_block(p, spec, x, cache):
        pp = shared if spec.shared_attn else p
        x, cache = fill_attn(pp, spec, x, cache)
        if spec.shared_attn:
            h = layers.rms_norm(pp["norm_ffn"], x)
            return x + layers.mlp(pp["mlp"], h, cfg.act,
                                  int8_kernel=cfg.use_int8_matmul), cache
        if spec.has_ffn:
            h = layers.rms_norm(p["norm_ffn"], x)
            if spec.moe:
                y, _ = moe_lib.moe_capacity(p["moe"], cfg.moe_cfg, h,
                                            cfg.moe_group_size)
                x = x + y
            else:
                x = x + layers.mlp(p["mlp"], h, cfg.act,
                                   int8_kernel=cfg.use_int8_matmul)
        return x, cache

    def body(x, inp):
        pat_params, pat_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            x, nc = fill_block(pat_params.get(f"pat{i}"), spec, x,
                               pat_cache[f"pat{i}"])
            new_cache[f"pat{i}"] = nc
        return x, new_cache

    pat_caches = {f"pat{i}": caches[f"pat{i}"]
                  for i in range(len(cfg.pattern))}
    new_caches: Dict[str, PyTree] = {}
    if cfg.repeats > 0:
        x, new_pat = jax.lax.scan(
            body, x, (_pattern_stack_params(params, cfg), pat_caches))
        new_caches.update(new_pat)
    for i, spec in enumerate(cfg.tail):
        x, nc = fill_block(params.get(f"tail{i}"), spec, x,
                           caches[f"tail{i}"])
        new_caches[f"tail{i}"] = nc
    # per-row last valid chunk token (rows are right-padded to C)
    idx = jnp.clip(lens - 1, 0, c - 1).astype(jnp.int32)[:, None, None]
    x_last = jnp.take_along_axis(
        x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])), axis=1)
    return _lm_head(params, cfg, x_last), new_caches


def caches_axes(cfg: LMConfig) -> Dict[str, PyTree]:
    """Logical-axes tree mirroring init_caches (dataclass fields as dicts —
    the form parallel.sharding._tree_map2 consumes)."""
    def one(spec: BlockSpec, stacked: bool):
        pre = ("stack",) if stacked else ()
        if spec.kind == "attn":
            kv_ax = pre + ("batch", "seq", "kv_heads", "head_dim")
            out = {"kv": {"k": kv_ax, "v": kv_ax},
                   "pos": pre + ("batch", "seq")}
            if cfg.quant.kv_int8:
                sc_ax = pre + ("batch", "seq", "kv_heads")
                out["kv_scale"] = {"k": sc_ax, "v": sc_ax}
            return out
        st = {"conv_x": ("batch", "conv", "heads", "head_dim"),
              "conv_b": ("batch", "conv", "ssm_group", "ssm_state"),
              "conv_c": ("batch", "conv", "ssm_group", "ssm_state"),
              "ssm": ("batch", "heads", "ssm_state", "head_dim")}
        if stacked:
            st = {k: ("stack",) + v for k, v in st.items()}
        return {"ssd": st}

    out: Dict[str, PyTree] = {}
    for i, spec in enumerate(cfg.pattern):
        out[f"pat{i}"] = one(spec, stacked=True)
    for i, spec in enumerate(cfg.tail):
        out[f"tail{i}"] = one(spec, stacked=False)
    return out


# -----------------------------------------------------------------------------
# Prefill: forward + cache construction
# -----------------------------------------------------------------------------

def prefill(params, cfg: LMConfig, tokens: jnp.ndarray,
            max_len: Optional[int] = None,
            vision_embeds: Optional[jnp.ndarray] = None,
            cache_dtype=jnp.bfloat16,
            lengths: Optional[jnp.ndarray] = None):
    """Process a prompt, returning (last-token logits, filled caches).

    Implemented as full-sequence forward per block, materializing K/V into
    decode caches (sized ``max_len``, default prompt length).

    ``lengths`` (B,) int32 enables padded multi-prompt prefill: rows are
    right-padded to a shared length S, logits are taken at ``lengths - 1``
    per row, and cache position tags past each row's true length are
    invalidated (-1) so decode masks the padding. Causality guarantees the
    tokens before each row's length are unaffected by its padding.
    """
    b, s = tokens.shape
    max_len = max_len or s
    if lengths is not None and any(
            sp.kind == "ssd" for sp in tuple(cfg.pattern) + tuple(cfg.tail)):
        # SSM states integrate over the padded steps — padded prefill would
        # corrupt short rows. The scheduler groups equal-length prompts for
        # SSD/hybrid archs instead.
        raise NotImplementedError("padded prefill is attention-only; "
                                  "group equal-length prompts for SSD archs")
    caches = init_caches(cfg, b, max_len, cache_dtype)
    x = layers.embed(params["embed"], tokens)
    if vision_embeds is not None and cfg.vision_tokens > 0:
        x = jax.lax.dynamic_update_slice(x, vision_embeds.astype(x.dtype), (0, 0, 0))
    pos1d = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    positions = (jnp.broadcast_to(pos1d[..., None], (b, s, 3))
                 if cfg.pos_emb == "mrope" else pos1d)
    shared = params.get("shared_attn")

    def fill_attn(p, spec, x, cache):
        acfg = cfg.attn_cfg(spec.window)
        h = layers.rms_norm(p["norm_attn"], x)
        q, k, v = layers._project_qkv(p["attn"], acfg, h, positions)
        if s > layers._CHUNKED_SDPA_THRESHOLD:
            out = layers.sdpa_q_chunked(q, k, v, pos1d, pos1d, causal=True,
                                        window=spec.window, scale=acfg.scale)
        else:
            mask = layers.attention_mask(pos1d, pos1d, causal=True,
                                         window=spec.window)
            out = layers.sdpa(q, k, v, mask, acfg.scale)
        if layers._q8_active(acfg, p["attn"]["wo"]):
            y = layers.q8_matmul(out, p["attn"]["wo"], contract_ndim=2)
        else:
            y = jnp.einsum("bshk,hkd->bsd", out,
                           layers.wl(p["attn"]["wo"], out.dtype))
        kv, pos_tags = cache["kv"], cache["pos"]
        kv_int8 = "kv_scale" in cache
        clen = kv.k.shape[1]
        bsz = x.shape[0]
        if kv_int8:
            # prompt K/V enter the cache quantized: attention above used the
            # full-precision activations (registers/VMEM), but what lands in
            # HBM is int8 codes + per-(row, position, head) fp32 scales —
            # the same representation decode appends (DESIGN.md §12)
            from repro.quant import int8 as int8_lib
            k_st, k_sc = int8_lib.quantize_rowwise(k)    # (B,S,H,D),(B,S,H)
            v_st, v_sc = int8_lib.quantize_rowwise(v)
        else:
            k_st, v_st = k, v
        if clen >= s:
            kc = jax.lax.dynamic_update_slice(kv.k, k_st.astype(kv.k.dtype),
                                              (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(kv.v, v_st.astype(kv.v.dtype),
                                              (0, 0, 0, 0))
            if kv_int8:
                ksc = jax.lax.dynamic_update_slice(
                    cache["kv_scale"].k, k_sc, (0, 0, 0))
                vsc = jax.lax.dynamic_update_slice(
                    cache["kv_scale"].v, v_sc, (0, 0, 0))
            ptags = jax.lax.dynamic_update_slice(
                pos_tags,
                jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s)),
                (0, 0))
        else:  # ring: keep the last clen positions
            kc = k_st[:, s - clen:].astype(kv.k.dtype)
            vc = v_st[:, s - clen:].astype(kv.v.dtype)
            ptags1 = jnp.arange(s - clen, s, dtype=jnp.int32)
            # rotate so that slot j holds the position with pos % clen == j
            roll = (s - clen) % clen
            kc, vc = jnp.roll(kc, roll, 1), jnp.roll(vc, roll, 1)
            if kv_int8:
                ksc = jnp.roll(k_sc[:, s - clen:], roll, 1)
                vsc = jnp.roll(v_sc[:, s - clen:], roll, 1)
            ptags = jnp.broadcast_to(jnp.roll(ptags1, roll, 0)[None], (bsz, clen))
        if lengths is not None:
            # invalidate tags past each row's true length — decode masks
            # padded K/V by tag, so the garbage rows are never attended
            ptags = jnp.where(ptags < lengths[:, None], ptags, -1)
        new_cache = {"kv": KVCache(k=kc, v=vc), "pos": ptags}
        if kv_int8:
            new_cache["kv_scale"] = KVCache(k=ksc, v=vsc)
        return x + y, new_cache

    def fill_block(p, spec, x, cache):
        if spec.kind == "attn":
            pp = shared if spec.shared_attn else p
            x, cache = fill_attn(pp, spec, x, cache)
            if spec.shared_attn:
                h = layers.rms_norm(pp["norm_ffn"], x)
                return x + layers.mlp(pp["mlp"], h, cfg.act,
                                      int8_kernel=cfg.use_int8_matmul), cache
        else:
            h = layers.rms_norm(p["norm_ssd"], x)
            scfg = cfg.ssd_cfg
            z, xin, b_raw, c_raw, dt_raw = ssd_lib._projections(p["ssd"], scfg, h)
            # conv states carry the last d_conv-1 *pre-activation* inputs
            conv_x_state = xin[:, -(scfg.d_conv - 1):]
            conv_b_state = b_raw[:, -(scfg.d_conv - 1):]
            conv_c_state = c_raw[:, -(scfg.d_conv - 1):]
            xin_c = jax.nn.silu(ssd_lib._causal_dwconv(xin, p["ssd"]["conv_x"]))
            b_c = jax.nn.silu(ssd_lib._causal_dwconv(b_raw, p["ssd"]["conv_b"]))
            c_c = jax.nn.silu(ssd_lib._causal_dwconv(c_raw, p["ssd"]["conv_c"]))
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["ssd"]["dt_bias"])
            a = -jnp.exp(p["ssd"]["a_log"])
            bm = ssd_lib._expand_groups(b_c, scfg.n_heads).astype(jnp.float32)
            cm = ssd_lib._expand_groups(c_c, scfg.n_heads).astype(jnp.float32)
            y, final = ssd_lib.ssd_chunked(xin_c.astype(jnp.float32), dt, a, bm, cm,
                                           scfg.chunk)
            x = x + ssd_lib._finish(p["ssd"], scfg, y, xin_c, z)
            st = ssd_lib.SSDState(conv_x=conv_x_state.astype(cache["ssd"].conv_x.dtype),
                                  conv_b=conv_b_state.astype(cache["ssd"].conv_b.dtype),
                                  conv_c=conv_c_state.astype(cache["ssd"].conv_c.dtype),
                                  ssm=final)
            cache = {"ssd": st}
        if spec.kind == "attn" and spec.has_ffn and not spec.shared_attn:
            h = layers.rms_norm(p["norm_ffn"], x)
            if spec.moe:
                y, _ = moe_lib.moe_capacity(p["moe"], cfg.moe_cfg, h,
                                            cfg.moe_group_size)
                x = x + y
            else:
                x = x + layers.mlp(p["mlp"], h, cfg.act,
                               int8_kernel=cfg.use_int8_matmul)
        return x, cache

    def body(x, inp):
        pat_params, pat_cache = inp
        new_cache = {}
        for i, spec in enumerate(cfg.pattern):
            x, nc = fill_block(pat_params.get(f"pat{i}") if not spec.shared_attn
                               else None, spec, x, pat_cache[f"pat{i}"])
            new_cache[f"pat{i}"] = nc
        return x, new_cache

    pat_caches = {f"pat{i}": caches[f"pat{i}"] for i in range(len(cfg.pattern))}
    new_caches: Dict[str, PyTree] = {}
    if cfg.repeats > 0:
        # no remat: prefill is inference (no gradient tape to save)
        x, new_pat = jax.lax.scan(body, x,
                                  (_pattern_stack_params(params, cfg), pat_caches))
        new_caches.update(new_pat)
    for i, spec in enumerate(cfg.tail):
        x, nc = fill_block(params.get(f"tail{i}"), spec, x, caches[f"tail{i}"])
        new_caches[f"tail{i}"] = nc
    if lengths is not None:
        # per-row last real token (rows are right-padded to a shared S)
        idx = (lengths - 1).astype(jnp.int32)[:, None, None]
        x_last = jnp.take_along_axis(x, jnp.broadcast_to(
            idx, (b, 1, x.shape[-1])), axis=1)
    else:
        x_last = x[:, -1:]
    return _lm_head(params, cfg, x_last), new_caches
