"""Optimizers & schedules (pure JAX; no optax on this box)."""

from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, init_opt_state, apply_updates, global_norm, clip_by_global_norm,
)
from repro.optim import schedules  # noqa: F401
