"""AdamW with mixed precision and quantized optimizer-state options.

State layouts (``state_dtype``):
  * "fp32"  — classic: fp32 m/v (+ fp32 master when params are bf16)
  * "bf16"  — m/v in bf16 (halves optimizer HBM; update math in fp32)
  * "int8"  — m/v block-quantized int8 (8-bit-Adam style, per-tensor absmax
              scale) — the paper's "quantize what you can" insight applied to
              optimizer state; this is what lets kimi-k2-1t fit the 512-chip
              multi-pod budget (see EXPERIMENTS.md §Dry-run).

All state shards like its param (ZeRO-free TP sharding; the DP axes see
replicated state, grads are all-reduced by SPMD).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Callable[[jnp.ndarray], jnp.ndarray]] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "fp32"        # "fp32" | "bf16" | "int8"
    use_master: bool = True          # keep fp32 master when params are low-prec

    def lr_at(self, step: jnp.ndarray) -> jnp.ndarray:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)


# -- quantized moment storage --------------------------------------------------

def _q8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8), scale


def _dq8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _store(x: jnp.ndarray, mode: str):
    if mode == "fp32":
        return x.astype(jnp.float32)
    if mode == "bf16":
        return x.astype(jnp.bfloat16)
    q, s = _q8(x)
    return {"q": q, "s": s}


def _load(x, mode: str) -> jnp.ndarray:
    if mode == "int8":
        return _dq8(x["q"], x["s"])
    return x.astype(jnp.float32)


# -- state ---------------------------------------------------------------------

def init_opt_state(params: PyTree, cfg: AdamWConfig) -> Dict[str, PyTree]:
    zeros = jax.tree.map(lambda p: _store(jnp.zeros(p.shape, jnp.float32),
                                          cfg.state_dtype), params)
    zeros2 = jax.tree.map(lambda p: _store(jnp.zeros(p.shape, jnp.float32),
                                           cfg.state_dtype), params)
    state: Dict[str, PyTree] = {"m": zeros, "v": zeros2,
                                "step": jnp.zeros((), jnp.int32)}
    if cfg.use_master and any(p.dtype != jnp.float32
                              for p in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor), grads), norm


def apply_updates(params: PyTree, grads: PyTree, state: Dict[str, PyTree],
                  cfg: AdamWConfig) -> Tuple[PyTree, Dict[str, PyTree],
                                             Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    lr = cfg.lr_at(step)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    masters = state.get("master", params)

    is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}

    def upd(p, master, g, m, v):
        m32 = _load(m, cfg.state_dtype)
        v32 = _load(v, cfg.state_dtype)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        base = master.astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return new, _store(m32, cfg.state_dtype), _store(v32, cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_master = jax.tree.leaves(masters)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_q)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_q)
    new_p, new_m, new_v = [], [], []
    for p, ms, g, m, v in zip(flat_p, flat_master, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, ms, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    new_master_tree = jax.tree.unflatten(treedef, new_p)
    new_params = jax.tree.map(lambda old, new: new.astype(old.dtype),
                              params, new_master_tree)
    new_state: Dict[str, PyTree] = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    if "master" in state:
        new_state["master"] = new_master_tree
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


def opt_state_axes(params_axes: PyTree, cfg: AdamWConfig) -> Dict[str, PyTree]:
    """Logical axes for the optimizer state (mirrors params; int8 scales are
    scalars)."""
    def ax_state(ax):
        if cfg.state_dtype == "int8":
            return {"q": ax, "s": ()}
        return ax
    is_ax = lambda x: isinstance(x, tuple)
    out = {"m": jax.tree.map(ax_state, params_axes, is_leaf=is_ax),
           "v": jax.tree.map(ax_state, params_axes, is_leaf=is_ax),
           "step": ()}
    out["master"] = params_axes
    return out
