"""Learning-rate schedules (callables of the int32 step)."""

from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def constant(lr: float) -> Callable:
    return lambda step: jnp.full((), lr, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.0) -> Callable:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn


def warmup_rsqrt(peak: float, warmup_steps: int) -> Callable:
    def fn(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        warm = peak * s / max(warmup_steps, 1)
        decay = peak * math.sqrt(warmup_steps) / jnp.sqrt(s)
        return jnp.where(s < warmup_steps, warm, decay)
    return fn


def linear_decay(peak: float, warmup_steps: int, total_steps: int) -> Callable:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        return jnp.where(s < warmup_steps, warm, peak * (1 - t))
    return fn
