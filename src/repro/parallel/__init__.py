"""Distribution layer: meshes, sharding rules, compression, pipeline."""

from repro.parallel import sharding  # noqa: F401
