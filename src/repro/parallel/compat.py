"""Version-portability shims for JAX APIs that moved between 0.4.x and 0.8.

The repo targets the modern surface (``jax.shard_map``, ``jax.sharding.
AxisType``, ``jax.lax.axis_size``); older runtimes spell these
``jax.experimental.shard_map.shard_map``, no axis types, and
``lax.psum(1, axis)``. Everything version-sensitive routes through here so
call sites stay clean.
"""

from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType as _AxisType
except ImportError:          # pre-0.6 runtimes have no explicit axis types
    _AxisType = None


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the runtime supports them."""
    shape, axes = tuple(shape), tuple(axes)
    if _AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def axis_size(axis_name):
    """Static size of a mapped mesh axis (inside shard_map)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def pvary(x, axis_names):
    """Mark x as varying over manual axes (identity where unsupported)."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def cost_analysis_dict(compiled):
    """compiled.cost_analysis() as a flat dict across runtime versions.

    JAX 0.8 returns one dict; 0.4.x returns a per-computation list of dicts
    (usually length 1).
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
