"""Int8 error-feedback gradient compression for the DP all-reduce.

At 512+ chips the cross-pod DP all-reduce rides the slowest links (DCN);
compressing gradients 4x (fp32 -> int8 + one fp32 scale per chunk) cuts the
collective-bound term of the roofline directly. Error feedback keeps the
compression *unbiased over time*: the residual e_t = g_t - dq(q(g_t + e_{t-1}))
is carried in optimizer state, so SGD/Adam converge to the same point
(tested: tests/test_compression.py).

Implementation: a manual ring reduce-scatter + all-gather over ``axis_name``
with int8 payloads (lax.ppermute inside shard_map). Per-hop requantization is
re-absorbed by the same error-feedback state.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.parallel import compat

PyTree = Any


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ring_allreduce_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean-all-reduce of ``x`` over ``axis_name`` with int8 payloads.

    Call inside shard_map. Wire bytes: ~2 * size * (n-1)/n * 1B vs 4B fp32.
    """
    n = compat.axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # ring reduce-scatter: after n-1 hops, rank r owns the full sum of chunk
    # (r+1) % n
    def rs_body(i, carry):
        acc_chunk, send_q, send_s = carry
        recv_q = jax.lax.ppermute(send_q, axis_name, perm)
        recv_s = jax.lax.ppermute(send_s, axis_name, perm)
        # which chunk this rank accumulates at hop i: (idx - i - 1) mod n ...
        # we instead walk the standard schedule: accumulate into the received
        # chunk and keep forwarding.
        chunk_id = (idx - i - 1) % n
        local = jax.lax.dynamic_index_in_dim(chunks, chunk_id, 0, keepdims=False)
        summed = _dequantize(recv_q, recv_s) + local
        q, s = _quantize(summed)
        return summed, q, s

    q0, s0 = _quantize(jax.lax.dynamic_index_in_dim(chunks, idx % n, 0,
                                                    keepdims=False))
    acc0 = compat.pvary(jnp.zeros(chunks.shape[1], jnp.float32), (axis_name,))
    acc, q_fin, s_fin = jax.lax.fori_loop(0, n - 1, rs_body, (acc0, q0, s0))
    # rank r now owns the reduced chunk (r + 1) % n  (as q_fin/s_fin)
    own_id = (idx + 1) % n

    # ring all-gather of the reduced int8 chunks
    def ag_body(i, carry):
        out, send_q, send_s = carry
        recv_q = jax.lax.ppermute(send_q, axis_name, perm)
        recv_s = jax.lax.ppermute(send_s, axis_name, perm)
        # rank r receives chunk ((r - i) mod n)'s reduced value at hop i...
        cid = (own_id - i - 1) % n
        out = jax.lax.dynamic_update_index_in_dim(
            out, _dequantize(recv_q, recv_s), cid, 0)
        return out, recv_q, recv_s

    out0 = jnp.zeros_like(chunks)   # zeros_like inherits the vma of chunks
    out0 = jax.lax.dynamic_update_index_in_dim(
        out0, _dequantize(q_fin, s_fin), own_id, 0)
    out, _, _ = jax.lax.fori_loop(0, n - 1, ag_body, (out0, q_fin, s_fin))
    mean = out.reshape(-1)[:x.size] / n
    return mean.reshape(x.shape).astype(x.dtype)


# -- error feedback ------------------------------------------------------------

def init_ef_state(grads_like: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress_grads_with_ef(grads: PyTree, ef: PyTree
                           ) -> Tuple[PyTree, PyTree]:
    """Quantize (grads + ef) to int8 per leaf; return (dq(grads), new ef).

    Single-device form of the EF transform (the psum then happens on the int
    values upstream); used for tests and for the simple 'quantize before the
    XLA all-reduce' mode where wire format is int32-packed.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = _quantize(target)
        dq = _dequantize(q, s)
        return dq.astype(g.dtype), target - dq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))
