"""Activation-sharding context: model code stays mesh-agnostic.

``constrain(x, *logical_axes)`` is a no-op unless a mesh+rules context is
active (cells.Cell.lower / launch.train install one). Under a context it
applies jax.lax.with_sharding_constraint with the spec derived from the same
logical->mesh rules used for parameters — the GSPMD hygiene that keeps big
intermediates (SSD chunk tensors, MoE dispatch, logits) sharded instead of
replicated (see EXPERIMENTS.md §Perf iteration 0).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.parallel import sharding as sh

_state = threading.local()


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules=None):
    prev = _current()
    _state.ctx = (mesh, rules or sh.DEFAULT_RULES)
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x, *axes: Optional[str]):
    """Constrain ``x``'s sharding by logical axis names (no-op w/o context)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        return x
    spec = sh.spec_for(x.shape, axes, mesh, rules)
    if all(e is None for e in spec):
        return x          # fully replicated constraint would only pessimize
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
