"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

Stages hold equal slices of a homogeneous layer stack; microbatches stream
through a collective-permute ring. The schedule is the classic (M + P - 1)
rotation: rank 0 injects microbatch t at tick t, rank P-1 emits microbatch
t - (P-1); bubble fraction = (P-1)/(M+P-1).

Differentiable end-to-end (the tick loop is a lax.scan; JAX transposes the
ppermutes), so training uses autodiff-GPipe semantics with remat on stages.
At the 256/512-chip roofline scale this framework defaults to DP x TP
(pipeline helps most when model layers >> chips or HBM is param-bound);
PP is exercised by tests/test_pipeline.py on small meshes and available via
TrainConfig.pipeline_stages.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import compat

PyTree = Any


def pipeline_apply(stage_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
                   local_params: PyTree, microbatches: jnp.ndarray,
                   axis_name: str) -> jnp.ndarray:
    """Run inside shard_map: stream microbatches through pipeline stages.

    local_params: this rank's stage parameters (already sharded over
    ``axis_name``, leading stage dim stripped to this rank's slice).
    microbatches: (M, mb, ...) identical on every rank (replicated input).
    Returns (M, mb, ...) final-stage outputs (identical on every rank).
    """
    p = jax.lax.axis_index(axis_name)
    n_stage = compat.axis_size(axis_name)
    m = microbatches.shape[0]
    ticks = m + n_stage - 1
    state0 = jnp.zeros_like(microbatches[0])
    out0 = jnp.zeros_like(microbatches)
    perm = [(i, (i + 1) % n_stage) for i in range(n_stage)]

    def tick(carry, t):
        state, outputs = carry
        inject = microbatches[jnp.clip(t, 0, m - 1)]
        x_in = jnp.where(p == 0, inject, state)
        active = (t - p >= 0) & (t - p < m)
        y = stage_fn(local_params, x_in)
        y = jnp.where(active, y, state)
        out_idx = jnp.clip(t - (n_stage - 1), 0, m - 1)
        emit = (p == n_stage - 1) & (t - (n_stage - 1) >= 0) \
            & (t - (n_stage - 1) < m)
        cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0, keepdims=False)
        outputs = jax.lax.dynamic_update_index_in_dim(
            outputs, jnp.where(emit, y, cur), out_idx, 0)
        state = jax.lax.ppermute(y, axis_name, perm)
        return (state, outputs), None

    (_, outputs), _ = jax.lax.scan(tick, (state0, out0), jnp.arange(ticks))
    # outputs are only populated on the last stage; share them ring-wide
    return jax.lax.psum(jnp.where(p == n_stage - 1, outputs, 0.0), axis_name)


def make_pipelined_fn(stage_fn: Callable, mesh: Mesh, n_micro: int,
                      axis_name: str = "pipe") -> Callable:
    """Wrap ``stage_fn(params_slice, x) -> x`` into a pjit-able pipelined map.

    stacked_params leaves have a leading stage dim == mesh.shape[axis_name];
    x is (batch, ...) and is split into ``n_micro`` microbatches.
    """
    n_stage = mesh.shape[axis_name]

    @partial(compat.shard_map, mesh=mesh,
             in_specs=(P(axis_name), P()), out_specs=P())
    def _run(stacked_params, x):
        local_params = jax.tree.map(lambda a: a[0], stacked_params)
        b = x.shape[0]
        mb = b // n_micro
        micro = x.reshape(n_micro, mb, *x.shape[1:])
        y = pipeline_apply(stage_fn, local_params, micro, axis_name)
        return y.reshape(b, *y.shape[2:])

    return _run
