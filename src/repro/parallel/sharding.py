"""Logical-axis -> mesh sharding rules with divisibility fallbacks.

Params (and caches/activations) carry logical axis names (models.common.Axed).
This module maps them to PartitionSpecs for a concrete mesh:

* default rules: batch->DP axes ("pod","data"), TP dims ("heads", "ffn",
  "vocab", "experts", "ssm-inner") -> "model", everything else replicated;
* **divisibility fallback**: a dim is only sharded if its size divides the
  mesh-axis size — this is what makes starcoder2 (36 heads) and whisper
  (20 heads) lower cleanly on a 16-way model axis (heads replicate; the FFN
  still TPs; the §Perf log tracks the cost);
* **conflict resolution**: one mesh axis appears at most once per spec
  (left-to-right priority — e.g. MoE w_in (experts, embed, ffn) shards
  experts, not ffn, on "model");
* rule overrides per shape cell (e.g. long_500k: batch=1 -> shard "seq" on
  the DP axes instead).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# default logical->mesh rules (order of dict irrelevant; per-leaf resolution
# is left-to-right over dims)
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    # context/sequence-parallel axis: only constrained by archs that opt in
    # (sp_attention / sp_residual; see EXPERIMENTS.md §Perf HC-A/HC-B)
    "seq_tp": "model",
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    # head_dim shards on "model" ONLY when heads/kv_heads couldn't (conflict
    # resolution is left-to-right): gives MQA/low-kv archs (granite kv=1,
    # kimi kv=8, whisper 20H) sharded KV caches instead of replicated ones.
    "head_dim": "model",
    "ffn": "model",
    "experts": "model",
    "stack": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_group": None,
    "conv": None,
    "spatial": None,
    "channels": None,
    None: None,
}

# long-context (batch-unshardable) override: sequence-parallel over DP axes
LONG_CONTEXT_RULES = dict(DEFAULT_RULES, batch=None, seq=("pod", "data"),
                          seq_tp=None)


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _present(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    kept = tuple(a for a in axes if a in mesh.shape)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else kept


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]], mesh: Mesh,
             rules: Optional[Mapping[str, MeshAxes]] = None) -> P:
    """PartitionSpec for one leaf given its logical axes."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    entries = []
    for dim, ax in zip(shape, axes):
        mesh_ax = _present(mesh, rules.get(ax))
        if mesh_ax is None:
            entries.append(None)
            continue
        flat = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        if any(a in used for a in flat):
            entries.append(None)          # conflict: left-to-right priority
            continue
        if dim % _axis_size(mesh, mesh_ax) != 0:
            entries.append(None)          # divisibility fallback
            continue
        used.update(flat)
        entries.append(mesh_ax)
    while entries and entries[-1] is None:
        entries.pop()                      # canonical trailing-None trim
    return P(*entries)


def specs_for_tree(params_shapes: Any, axes_tree: Any, mesh: Mesh,
                   rules: Optional[Mapping[str, MeshAxes]] = None) -> Any:
    """PartitionSpec pytree matching ``params_shapes`` (arrays or SDS)."""
    def one(leaf_shape, ax):
        shape = leaf_shape.shape if hasattr(leaf_shape, "shape") else leaf_shape
        if ax is None or not isinstance(ax, tuple):
            return P()
        return spec_for(shape, ax, mesh, rules)

    return _tree_map2(one, params_shapes, axes_tree)


def _tree_map2(fn, shapes_tree, axes_tree):
    """tree.map over (params, axes) where axes leaves are tuples."""
    if isinstance(shapes_tree, dict):
        return {k: _tree_map2(fn, shapes_tree[k], axes_tree[k])
                for k in shapes_tree}
    # dataclass-pytrees (KVCache/SSDState) mirror into dicts in the axes tree
    if hasattr(shapes_tree, "__dataclass_fields__"):
        vals = {f: _tree_map2(fn, getattr(shapes_tree, f), axes_tree[f])
                for f in shapes_tree.__dataclass_fields__}
        return type(shapes_tree)(**vals)
    return fn(shapes_tree, axes_tree)


def shardings_for_tree(params_shapes: Any, axes_tree: Any, mesh: Mesh,
                       rules: Optional[Mapping[str, MeshAxes]] = None) -> Any:
    specs = specs_for_tree(params_shapes, axes_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def batch_spec(mesh: Mesh, batch_size: int, *, seq_len: int,
               long_context: bool = False) -> P:
    """Input spec for (batch, seq) token arrays."""
    rules = LONG_CONTEXT_RULES if long_context else DEFAULT_RULES
    return spec_for((batch_size, seq_len), ("batch", "seq"), mesh, rules)


def summarize(specs_tree: Any) -> Dict[str, int]:
    """Histogram of spec strings (debugging / EXPERIMENTS.md)."""
    out: Dict[str, int] = {}
    for leaf in jax.tree.leaves(specs_tree,
                                is_leaf=lambda x: isinstance(x, P)):
        key = str(leaf)
        out[key] = out.get(key, 0) + 1
    return out
