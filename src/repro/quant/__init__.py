"""Model reduction (paper C5): ternary / binary / int8 quantization.

The paper's PIM inference engine computes ternary (w in {-1,0,1}) or binary
CNN inference multiplication-free; training stays FP32. This package provides
the weight-reduction transforms; the TPU-native execution of the ternary
matmul lives in repro.kernels.ternary_matmul.
"""

from repro.quant import ternary, int8  # noqa: F401
