"""Symmetric per-channel int8 quantization.

Used for (a) the int8 serving mode of the LM zoo and (b) the error-feedback
gradient compression in parallel/compression.py (the cross-pod DP axis).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Int8Weight:
    q: jnp.ndarray        # int8
    scale: jnp.ndarray    # fp32, per-last-dim-channel

jax.tree_util.register_dataclass(Int8Weight, data_fields=["q", "scale"],
                                 meta_fields=[])


def quantize(w: jnp.ndarray, axis: int = -1) -> Int8Weight:
    w32 = w.astype(jnp.float32)
    red = tuple(i for i in range(w32.ndim) if i != (axis % w32.ndim))
    amax = jnp.max(jnp.abs(w32), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return Int8Weight(q=q, scale=scale.astype(jnp.float32))


def dequantize(iw: Int8Weight, dtype=jnp.float32) -> jnp.ndarray:
    return (iw.q.astype(jnp.float32) * iw.scale).astype(dtype)


def quantize_stochastic(w: jnp.ndarray, rng: jax.Array,
                        axis: int = -1) -> Int8Weight:
    """Stochastic rounding variant (unbiased; used by gradient compression)."""
    w32 = w.astype(jnp.float32)
    red = tuple(i for i in range(w32.ndim) if i != (axis % w32.ndim))
    amax = jnp.max(jnp.abs(w32), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    scaled = w32 / scale
    noise = jax.random.uniform(rng, scaled.shape) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return Int8Weight(q=q, scale=scale.astype(jnp.float32))


def quant_error(w: jnp.ndarray, iw: Int8Weight) -> float:
    wd = dequantize(iw)
    num = jnp.linalg.norm(w.astype(jnp.float32) - wd)
    den = jnp.maximum(jnp.linalg.norm(w.astype(jnp.float32)), 1e-12)
    return float(num / den)


# -----------------------------------------------------------------------------
# Row-wise (per-token / per-channel) quantization for the serving fast path
# -----------------------------------------------------------------------------

# scale floor: an all-zero channel still gets a positive scale so dequant is
# exact zero and division never produces inf/nan
SCALE_FLOOR = 1e-12


def quantize_rowwise(x: jnp.ndarray, axis: int = -1
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 over ONE axis: every other axis keeps its own scale.

    Used for KV-cache entries (axis=-1: one scale per (slot, position, head))
    and as the building block of per-channel weight quantization.
    Returns (q int8 same-shape, scale fp32 with ``axis`` removed).
    """
    x32 = x.astype(jnp.float32)
    ax = axis % x32.ndim
    amax = jnp.max(jnp.abs(x32), axis=ax, keepdims=True)
    scale = jnp.maximum(amax, SCALE_FLOOR) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=ax)


def dequantize_rowwise(q: jnp.ndarray, scale: jnp.ndarray, axis: int = -1,
                       dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32)
            * jnp.expand_dims(scale, axis).astype(jnp.float32)).astype(dtype)


def quantize_weight(w: jnp.ndarray, lead: int = 0, out_dims: int = 1) -> dict:
    """Per-channel int8 weight leaf for the serving fast path.

    Reduces |w| over the contraction dims — everything between the ``lead``
    stack/expert dims and the trailing ``out_dims`` channel dims — keeping
    the reduced dims as size-1 (``s8`` broadcasts against ``q8`` in
    models.layers.wl regardless of weight rank). Returns {"q8","s8"}.
    """
    w32 = w.astype(jnp.float32)
    red = tuple(range(lead, w32.ndim - out_dims))
    amax = jnp.max(jnp.abs(w32), axis=red, keepdims=True)
    scale = jnp.maximum(amax, SCALE_FLOOR) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return {"q8": q, "s8": scale.astype(jnp.float32)}


# -----------------------------------------------------------------------------
# Int8-weight serving mode (paper C5 applied to the LM zoo; §Perf HC-C iter 3)
# -----------------------------------------------------------------------------

# weight-leaf names the serving transform quantizes (linear layers only —
# embeddings/norms/router stay high-precision, mirroring quantize_tree)
SERVING_QUANT_KEYS = frozenset({"wq", "wk", "wv", "wo", "w_in", "w_gate",
                                "w_out", "w_z", "w_x"})

# trailing output-channel dims per weight name: q/k/v and SSD
# in-projections map embed -> (heads, head_dim); all others have a single
# trailing output dim.
_OUT_DIMS = {"wq": 2, "wk": 2, "wv": 2, "w_z": 2, "w_x": 2}


def weight_out_dims(name: str) -> int:
    """Trailing output-channel dim count for a SERVING_QUANT_KEYS leaf."""
    return _OUT_DIMS.get(name, 1)


def _q8_leaf(w, stacked: bool):
    """array or ShapeDtypeStruct -> {"q8","s8"} (per-layer scale if stacked)."""
    if isinstance(w, jax.ShapeDtypeStruct):
        s_shape = (w.shape[0],) if stacked else ()
        return {"q8": jax.ShapeDtypeStruct(w.shape, jnp.int8),
                "s8": jax.ShapeDtypeStruct(s_shape, jnp.float32)}
    w32 = jnp.asarray(w, jnp.float32)
    red = tuple(range(1, w32.ndim)) if stacked else tuple(range(w32.ndim))
    amax = jnp.max(jnp.abs(w32), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return {"q8": q, "s8": scale.reshape((w32.shape[0],) if stacked else ())}


def quantize_params_for_serving(params, axes):
    """(params, axes) -> int8-served versions: selected linear weights become
    {"q8": int8, "s8": fp32 per-layer scale}; everything else passes through.
    Works on arrays AND ShapeDtypeStruct trees (dry-run). The model consumes
    them transparently via models.layers.wl."""
    def walk(p, a):
        if isinstance(p, dict):
            out_p, out_a = {}, {}
            for k in p:
                if (k in SERVING_QUANT_KEYS and not isinstance(p[k], dict)
                        and getattr(p[k], "ndim", 0) >= 2):
                    stacked = isinstance(a[k], tuple) and len(a[k]) > 0 \
                        and a[k][0] == "stack"
                    out_p[k] = _q8_leaf(p[k], stacked)
                    out_a[k] = {"q8": a[k],
                                "s8": ("stack",) if stacked else ()}
                else:
                    out_p[k], out_a[k] = walk(p[k], a[k])
            return out_p, out_a
        return p, a

    return walk(params, axes)
