"""Ternary & binary weight reduction (TWN-style), the paper's inference mode.

Ternarization (Ternary Weight Networks): threshold Δ = 0.7·E|w| per output
channel; q = sign(w)·1[|w|>Δ]; scale α = E[|w| : |w|>Δ]. w ≈ α·q with
q ∈ {-1,0,+1} stored as int8 (the PIM bulk-bitwise representation; the Pallas
kernel consumes q/α directly).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TernaryWeight:
    q: jnp.ndarray        # int8 in {-1,0,1}, same shape as w
    scale: jnp.ndarray    # per-output-channel fp32 scale (broadcast on last dim)

jax.tree_util.register_dataclass(TernaryWeight, data_fields=["q", "scale"],
                                 meta_fields=[])


def ternarize(w: jnp.ndarray, threshold_scale: float = 0.7) -> TernaryWeight:
    """Per-output-channel (last dim) TWN ternarization."""
    w32 = w.astype(jnp.float32)
    red_axes = tuple(range(w32.ndim - 1))
    delta = threshold_scale * jnp.mean(jnp.abs(w32), axis=red_axes, keepdims=True)
    q = jnp.where(jnp.abs(w32) > delta, jnp.sign(w32), 0.0)
    nz = jnp.maximum(jnp.sum(jnp.abs(q), axis=red_axes), 1.0)
    scale = jnp.sum(jnp.abs(w32) * jnp.abs(q), axis=red_axes) / nz
    return TernaryWeight(q=q.astype(jnp.int8), scale=scale.astype(jnp.float32))


def binarize(w: jnp.ndarray) -> TernaryWeight:
    """BWN binarization: q = sign(w), alpha = E|w| (a ternary with no zeros)."""
    w32 = w.astype(jnp.float32)
    red_axes = tuple(range(w32.ndim - 1))
    q = jnp.where(w32 >= 0, 1.0, -1.0)
    scale = jnp.mean(jnp.abs(w32), axis=red_axes)
    return TernaryWeight(q=q.astype(jnp.int8), scale=scale.astype(jnp.float32))


def dequantize(tw: TernaryWeight, dtype=jnp.float32) -> jnp.ndarray:
    return (tw.q.astype(jnp.float32) * tw.scale).astype(dtype)


def quant_error(w: jnp.ndarray, tw: TernaryWeight) -> float:
    """Relative L2 reconstruction error."""
    wd = dequantize(tw)
    num = jnp.linalg.norm(w.astype(jnp.float32) - wd)
    den = jnp.maximum(jnp.linalg.norm(w.astype(jnp.float32)), 1e-12)
    return float(num / den)


# -- bitplane packing (the PIM representation adapted for the TPU kernel) ----

def to_bitplanes(tw: TernaryWeight) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q in {-1,0,1} -> (plus, minus) uint8 planes with q = plus - minus."""
    plus = (tw.q > 0).astype(jnp.uint8)
    minus = (tw.q < 0).astype(jnp.uint8)
    return plus, minus


def from_bitplanes(plus: jnp.ndarray, minus: jnp.ndarray,
                   scale: jnp.ndarray) -> TernaryWeight:
    q = plus.astype(jnp.int8) - minus.astype(jnp.int8)
    return TernaryWeight(q=q, scale=scale)


# -- pytree-level model reduction ---------------------------------------------

def quantize_tree(params: Any, *, mode: str = "ternary",
                  predicate: Optional[Callable[[str, jnp.ndarray], bool]] = None
                  ) -> Any:
    """Quantize every >=2-D weight leaf (by default) in a params pytree.

    Leaves selected by ``predicate(path, leaf)`` become TernaryWeight nodes;
    others pass through. Use with ``dequantize_tree`` or a quant-aware matmul.
    """
    fn = {"ternary": ternarize, "binary": binarize}[mode]

    def pred(path: str, x) -> bool:
        if predicate is not None:
            return predicate(path, x)
        return hasattr(x, "ndim") and x.ndim >= 2 and "embed" not in path

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, x in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append(fn(x) if pred(name, x) else x)
    return jax.tree_util.tree_unflatten(treedef, out)


def dequantize_tree(params: Any, dtype=jnp.float32) -> Any:
    def de(x):
        return dequantize(x, dtype) if isinstance(x, TernaryWeight) else x
    return jax.tree.map(de, params,
                        is_leaf=lambda x: isinstance(x, TernaryWeight))
