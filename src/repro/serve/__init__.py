"""Serving substrate: device-resident continuous-batching serve core."""

from repro.serve.engine import (Request, ServeConfig, ServeEngine,  # noqa: F401
                                StepMetrics)
from repro.serve.quality import token_agreement  # noqa: F401
from repro.serve.reference import ReferenceEngine  # noqa: F401
from repro.serve.scheduler import Scheduler, SchedulerConfig  # noqa: F401
