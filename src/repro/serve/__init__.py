"""Serving substrate: KV-cache engine with continuous batching."""

from repro.serve.engine import ServeEngine, ServeConfig, Request  # noqa: F401
