"""Serving substrate: device-resident continuous-batching serve core."""

from repro.serve.engine import (Request, ServeConfig, ServeEngine,  # noqa: F401
                                StepMetrics)
from repro.serve.faults import (FAULT_KINDS,  # noqa: F401
                                TRANSIENT_FAULT_KINDS, FaultEvent,
                                FaultInjector, FaultPlan, GuardrailConfig,
                                ProcessKilled)
from repro.serve.snapshot import (Journal,  # noqa: F401
                                  check_fingerprint, config_fingerprint,
                                  host_state_dict, install_host_state,
                                  reconcile_ownership)
from repro.serve.pages import (PagePool, block_tokens,  # noqa: F401
                               fragmentation)
from repro.serve.quality import (generation_agreement,  # noqa: F401
                                 run_workload, token_agreement)
from repro.serve.spec import (ngram_draft, ngram_draft_tree,  # noqa: F401
                              speculative_accept)
from repro.serve.reference import ReferenceEngine  # noqa: F401
from repro.serve.scheduler import Scheduler, SchedulerConfig  # noqa: F401
