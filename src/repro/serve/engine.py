"""Batched serving engine: slot-based continuous batching over a shared
KV cache.

* ``max_slots`` concurrent sequences share one batched cache pytree;
* prompts prefill into a free slot (per-slot cache rows written in place);
* decode ticks advance **all active slots together** with per-slot positions
  (vmapped single-row decode under the hood);
* finished slots (EOS / max_tokens) free immediately and the queue refills —
  iteration-level (Orca-style) continuous batching;
* every tick is billed to the CarbonAccountant (the paper's operational-energy
  accounting, live on the serving path).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting
from repro.models import transformer as tf_lib

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    max_len: int = 512
    eos_id: int = -1          # -1: never; sampling stops at max_tokens
    temperature: float = 0.0  # 0 = greedy
    cache_dtype: Any = jnp.float32
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _batch_axis_tree(caches: PyTree) -> PyTree:
    """vmap in_axes: pattern caches carry batch at axis 1 (stacked layer dim
    leads); tail caches at axis 0."""
    def per_key(key, sub):
        ax = 1 if key.startswith("pat") else 0
        return jax.tree.map(lambda _: ax, sub)
    return {k: per_key(k, v) for k, v in caches.items()}


class ServeEngine:
    def __init__(self, params: PyTree, cfg: tf_lib.LMConfig,
                 serve_cfg: ServeConfig,
                 accountant: Optional[accounting.CarbonAccountant] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self.accountant = accountant
        b = serve_cfg.max_slots
        self.caches = tf_lib.init_caches(cfg, b, serve_cfg.max_len,
                                         serve_cfg.cache_dtype)
        self.slot_req: List[Optional[Request]] = [None] * b
        self.slot_pos = np.zeros(b, np.int32)
        self.slot_tok = np.zeros(b, np.int32)
        self.queue: Deque[Request] = deque()
        self._uid = 0
        self._rng = jax.random.PRNGKey(serve_cfg.seed)
        self._build_fns()

    # -- compiled paths -----------------------------------------------------------

    def _build_fns(self):
        cfg, scfg = self.cfg, self.scfg

        def prefill_one(params, tokens):
            return tf_lib.prefill(params, cfg, tokens, max_len=scfg.max_len,
                                  cache_dtype=scfg.cache_dtype)

        self._prefill = jax.jit(prefill_one)

        cache_axes = _batch_axis_tree(self.caches)

        def decode_row(params, token, pos, cache):
            # vmap strips the batch axis from cache leaves; run a B=1 decode
            cache_b = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                                   cache, cache_axes)
            logits, new_cache = tf_lib.decode_step(
                params, cfg, token[None, None], pos, cache_b)
            new_cache = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                                     new_cache, cache_axes)
            return logits[0, 0], new_cache

        self._decode = jax.jit(
            jax.vmap(decode_row, in_axes=(None, 0, 0, cache_axes),
                     out_axes=(0, cache_axes)))

    # -- queue API ------------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_tokens))
        return self._uid

    def _write_slot_cache(self, slot: int, row_caches: PyTree) -> None:
        """Insert a prefilled (batch=1) cache into the batched cache at slot."""
        def ins(batched, row, ax):
            idx = [slice(None)] * batched.ndim
            idx[ax] = slot
            return batched.at[tuple(idx)].set(jnp.squeeze(row, axis=ax))
        axes = _batch_axis_tree(self.caches)
        self.caches = jax.tree.map(ins, self.caches, row_caches, axes)

    def _admit(self) -> None:
        for slot in range(self.scfg.max_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt[None, :])
            logits, row_cache = self._prefill(self.params, prompt)
            self._write_slot_cache(slot, row_cache)
            tok = self._sample(logits[0, -1])
            req.generated.append(int(tok))
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            self.slot_tok[slot] = int(tok)

    def _sample(self, logits: jnp.ndarray) -> int:
        if self.scfg.temperature <= 0:
            return int(jnp.argmax(logits))
        self._rng, sub = jax.random.split(self._rng)
        return int(jax.random.categorical(sub, logits / self.scfg.temperature))

    # -- main tick --------------------------------------------------------------------

    def step(self) -> List[Request]:
        """Admit + one decode tick for all active slots. Returns finished."""
        t0 = time.monotonic()
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        finished: List[Request] = []
        if active:
            toks = jnp.asarray(self.slot_tok)
            poss = jnp.asarray(self.slot_pos)
            logits, self.caches = self._decode(self.params, toks, poss,
                                               self.caches)
            for i in active:
                req = self.slot_req[i]
                tok = self._sample(logits[i])
                req.generated.append(tok)
                self.slot_pos[i] += 1
                self.slot_tok[i] = tok
                hit_eos = (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id)
                if (len(req.generated) >= req.max_tokens or hit_eos
                        or self.slot_pos[i] >= self.scfg.max_len - 1):
                    req.done = True
                    finished.append(req)
                    self.slot_req[i] = None
        if self.accountant is not None:
            self.accountant.observe_step(time.monotonic() - t0,
                                         n_tokens=float(len(active)))
        return finished

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return done
