"""Device-resident continuous-batching serve core.

One jitted **engine tick** does everything on device: the batched decode step
over the shared slot-major KV cache (per-slot positions — no expand/squeeze
vmap tricks), sampling (greedy + per-slot temperature with per-slot PRNG
keys), token/position advance, EOS/max-token done flags, and a device-side
output ring buffer. The host reads back ONE compact (max_slots,) finished
mask per tick; generated tokens leave the device only when a request
finishes. Throughput and J/token are therefore properties of the hardware,
not of Python overhead (the paper's operational-energy argument, measured on
the live path).

Admission is batched too: the scheduler (serve/scheduler.py) picks queued
requests, the engine pads-and-stacks them into ONE prefill call and scatters
every admitted slot's cache rows at once.

Every tick produces a :class:`StepMetrics` billed to the CarbonAccountant,
so J/token is a first-class live serving metric.

The host-loop baseline this replaces lives on as serve/reference.py (the
correctness oracle and the benchmark's "before").
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting
from repro.models import transformer as tf_lib
from repro.serve.pages import ROOT, PagePool, block_tokens
from repro.serve.scheduler import Scheduler, SchedulerConfig

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    max_len: int = 512
    eos_id: int = -1          # -1: never; sampling stops at max_tokens
    temperature: float = 0.0  # default per-request temperature; 0 = greedy
    cache_dtype: Any = jnp.float32
    seed: int = 0
    # route batched decode attention through the Pallas decode kernel
    # (kernels/decode_attention.py). None = auto: on for TPU backends, off
    # elsewhere (interpret mode is correctness-only).
    decode_kernel: Optional[bool] = None
    # quantized serving fast path (DESIGN.md §12): "none" | "int8".
    # int8 quantizes the weight tree (per-channel scales) AND the KV cache
    # (per-token/head scales); cache_dtype is ignored for K/V in that mode.
    quant: str = "none"
    # paged KV cache + prefix reuse + chunked prefill (DESIGN.md §14):
    paged: bool = False
    page_size: int = 16       # tokens per KV page (block granularity)
    # pool capacity in pages. None = dense-equivalent:
    # max_slots * ceil(max_len / page_size) — prefix sharing then *raises*
    # effective capacity; smaller pools admit by deferral.
    num_pages: Optional[int] = None
    # content-matched block reuse at admission: a hit copies page-table
    # entries instead of recomputing the shared prefix's prefill
    prefix_cache: bool = True
    # admit long prompts in chunks of this many tokens, interleaved with
    # decode ticks (bounds tick-time tail latency). 0 = whole suffix in
    # one extend call.
    prefill_chunk: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_tokens: int = 16
    temperature: Optional[float] = None   # None -> ServeConfig.temperature
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class StepMetrics:
    """What one engine tick did — the unit core/accounting.py bills."""
    tokens: int                 # decode tokens produced this tick
    active_slots: int           # slots decoding this tick
    wall_s: float               # host wall time of the tick (incl. admission)
    prefill_tokens: int = 0     # prompt tokens prefilled this tick
    admitted: int = 0           # requests admitted this tick
    queue_depth: int = 0        # requests still waiting after the tick
    # dtype-aware modeled traffic/compute of the tick (engine-computed from
    # the actual resident array sizes; the paper's bytes-dominate-energy
    # argument made measurable — CarbonAccountant bills a per-byte DRAM
    # term from these alongside the FLOPs term)
    weight_bytes: float = 0.0   # parameter bytes streamed from HBM
    kv_bytes: float = 0.0       # KV-cache bytes read/written
    flops: float = 0.0          # modeled FLOPs
    # prefix-cache effect of this tick's admission (DESIGN.md §14): prompt
    # tokens served from cached pages, and the traffic/compute the dense
    # path would have billed for them — the sustainability win, first-class
    prefix_hit_tokens: int = 0  # prompt tokens reused via prefix-cache hits
    saved_bytes: float = 0.0    # KV write bytes NOT moved thanks to reuse
    saved_flops: float = 0.0    # prefill FLOPs NOT executed thanks to reuse

    @property
    def bytes_moved(self) -> float:
        return self.weight_bytes + self.kv_bytes


@dataclasses.dataclass
class _AdmitInfo:
    """What one admission pass did + its modeled traffic/compute bill."""
    admitted: int = 0           # requests newly selected this tick
    prefill_tokens: int = 0     # prompt tokens actually computed this tick
    weight_passes: int = 0      # extra weight-tree streams (0 or 1)
    kv_bytes: float = 0.0
    flops: float = 0.0
    prefix_hit_tokens: int = 0
    saved_bytes: float = 0.0
    saved_flops: float = 0.0


@dataclasses.dataclass
class DeviceState:
    """All per-slot serving state, resident on device between ticks."""
    caches: PyTree
    tok: jnp.ndarray            # (B,)  last token per slot
    pos: jnp.ndarray            # (B,)  next cache write position per slot
    gen: jnp.ndarray            # (B,)  tokens generated per slot
    budget: jnp.ndarray         # (B,)  max_tokens per slot
    active: jnp.ndarray         # (B,)  bool
    temp: jnp.ndarray           # (B,)  per-slot sampling temperature
    rng: jnp.ndarray            # (B, 2) per-slot PRNG keys (uint32)
    out_buf: jnp.ndarray        # (B, max_len) device-side output ring buffer
    # paged mode: (B, NB) logical-block -> physical-page map (serve/pages.py
    # owns allocation; entries past a slot's pages point at the sink page).
    # dense mode: (B, 0) placeholder.
    page_table: jnp.ndarray = None


jax.tree_util.register_dataclass(
    DeviceState,
    data_fields=["caches", "tok", "pos", "gen", "budget", "active", "temp",
                 "rng", "out_buf", "page_table"],
    meta_fields=[])


def _batch_axis_tree(caches: PyTree) -> PyTree:
    """Batch axis per cache leaf: pattern caches carry batch at axis 1 (the
    stacked layer dim leads); tail caches at axis 0."""
    def per_key(key, sub):
        ax = 1 if key.startswith("pat") else 0
        return jax.tree.map(lambda _: ax, sub)
    return {k: per_key(k, v) for k, v in caches.items()}


def _bucket_len(n: int, cap: Optional[int] = None) -> int:
    """Pad prompt-batch length to a pow2 bucket (bounds prefill recompiles).

    ``cap`` clamps the bucket ladder at the configured maximum (max prompt
    length for dense admission, the chunk size for chunked prefill) — the
    executable cache then holds at most ``log2(cap)`` entries, and with
    chunked prefill one chunk-size bucket is the steady state
    (tests/test_serve_paged.py::TestBucketCap).
    """
    b = 4
    while b < n:
        b *= 2
    return min(b, cap) if cap is not None else b


# -- modeled traffic / compute (DESIGN.md §12) --------------------------------
# Shared with the train engine: models/costing.py is the single cost model
# (these aliases keep the engine's call sites and tests stable).

from repro.models.costing import (attn_layers as _attn_layers,
                                  kv_bytes as _kv_bytes,
                                  matmul_weight_elems as _matmul_weight_elems,
                                  tree_bytes as _tree_bytes)


class ServeEngine:
    def __init__(self, params: PyTree, cfg: tf_lib.LMConfig,
                 serve_cfg: ServeConfig,
                 accountant: Optional[accounting.CarbonAccountant] = None,
                 scheduler: Optional[Scheduler] = None):
        use_kernel = serve_cfg.decode_kernel
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        if serve_cfg.quant not in ("none", "int8"):
            raise ValueError(f"unknown quant mode {serve_cfg.quant!r}")
        if serve_cfg.quant == "int8":
            # quantized fast path: int8 weight tree + int8 KV cache; the
            # already-quantized case (caller ran quantize_lm) passes through
            cfg = dataclasses.replace(cfg, quant=tf_lib.INT8_QUANT)
            params = tf_lib.quantize_lm(params)
        self.params = params
        self.cfg = dataclasses.replace(cfg, decode_kernel=bool(use_kernel))
        self.scfg = serve_cfg
        self.accountant = accountant
        self.scheduler = scheduler or Scheduler(SchedulerConfig())
        b, cap = serve_cfg.max_slots, serve_cfg.max_len
        base_key = jax.random.PRNGKey(serve_cfg.seed)
        self._base_key = base_key
        if serve_cfg.paged:
            # paged KV subsystem (DESIGN.md §14): a shared block pool
            # replaces the per-slot dense cache; serve/pages.py owns
            # allocation/refcounts/prefix registry on the host
            if not tf_lib.paged_supported(self.cfg):
                raise NotImplementedError(
                    "paged serving is attention-only (no SSD/hybrid) and "
                    "incompatible with ring caches")
            ps = serve_cfg.page_size
            self._blocks_per_slot = -(-cap // ps)
            n_pages = serve_cfg.num_pages
            if n_pages is None:
                n_pages = b * self._blocks_per_slot
            self.pool = PagePool(n_pages, ps)
            caches = tf_lib.init_paged_caches(self.cfg, n_pages, ps,
                                              serve_cfg.cache_dtype)
            page_table = jnp.full((b, self._blocks_per_slot),
                                  self.pool.sink, jnp.int32)
        else:
            self.pool = None
            caches = tf_lib.init_caches(self.cfg, b, cap,
                                        serve_cfg.cache_dtype)
            page_table = jnp.zeros((b, 0), jnp.int32)
        self.state = DeviceState(
            caches=caches,
            tok=jnp.zeros(b, jnp.int32),
            pos=jnp.zeros(b, jnp.int32),
            gen=jnp.zeros(b, jnp.int32),
            budget=jnp.zeros(b, jnp.int32),
            active=jnp.zeros(b, bool),
            temp=jnp.zeros(b, jnp.float32),
            rng=jax.vmap(lambda i: jax.random.fold_in(base_key, i))(
                jnp.arange(b)),
            out_buf=jnp.zeros((b, cap), jnp.int32),
            page_table=page_table)
        # host mirrors (admission + finished-mask readbacks keep them exact;
        # no per-slot device transfers needed)
        self.slot_req: List[Optional[Request]] = [None] * b
        self._host_gen = [0] * b
        self._uid = 0
        # paged host mirrors: pages owned per slot (released at finish) and
        # in-flight chunked prefills {slot: {"req", "next", "plen", ...}}
        self._slot_pages: List[List[int]] = [[] for _ in range(b)]
        self._prefilling: Dict[int, Dict[str, Any]] = {}
        # padded prefill needs causal masking to localize each row; SSM
        # states integrate over padding, so SSD archs admit equal-length
        # groups instead
        self._pad_ok = all(
            sp.kind == "attn"
            for sp in tuple(cfg.pattern) + tuple(cfg.tail))
        # instrumentation (tests assert the tick stays fused: one trace,
        # one host readback per tick; admission compiles once per length
        # bucket)
        self.tick_trace_count = 0
        self.host_readbacks = 0
        self.admit_trace_counts: Dict[int, int] = {}
        self._admit_fns: Dict[int, Any] = {}
        self.last_metrics: Optional[StepMetrics] = None
        self.metrics_log: List[StepMetrics] = []
        # modeled per-tick traffic/compute (DESIGN.md §12): dtype-aware
        # bytes from the actual resident arrays — this is where the int8
        # path's 2-4x byte reduction becomes measurable
        self.weight_bytes = _tree_bytes(self.params)
        self.kv_cache_bytes = _kv_bytes(self.state.caches)
        self._matmul_elems = _matmul_weight_elems(self.params, self.cfg)
        self._n_attn = _attn_layers(self.cfg)
        self._attn_dims = self.cfg.n_heads * self.cfg.resolved_head_dim
        if serve_cfg.paged:
            # KV payload bytes per cached token (codes + scales), for the
            # page-granular traffic model (DESIGN.md §14)
            self._kv_token_bytes = self.kv_cache_bytes / float(
                (self.pool.num_pages + 1) * serve_cfg.page_size)
        self._build_tick()
        self._build_admit()

    # -- compiled paths -------------------------------------------------------

    def _donate(self):
        # DeviceState is donated on every tick/admit: the KV cache and slot
        # arrays update in place instead of being copied each call. The old
        # state object is dead after the call (step() always reassigns).
        return (1,)

    def _build_tick(self):
        cfg, scfg = self.cfg, self.scfg
        eos_id, max_len = scfg.eos_id, scfg.max_len
        paged = scfg.paged

        def tick(params, st: DeviceState) -> Tuple[DeviceState, jnp.ndarray]:
            self.tick_trace_count += 1      # python side effect: trace count
            b = st.tok.shape[0]
            if paged:
                # dead/prefilling lanes' K/V writes go to the sink page —
                # their page-table rows may reference recycled pages
                logits1, caches = tf_lib.paged_decode_step(
                    params, cfg, st.tok[:, None], st.pos, st.page_table,
                    st.caches, active=st.active)
            else:
                logits1, caches = tf_lib.decode_step(
                    params, cfg, st.tok[:, None], st.pos, st.caches)
            logits = logits1[:, 0]                          # (B, V) fp32
            tok_new, rng_new = _sample(logits, st.rng, st.temp)
            tok_new = jnp.where(st.active, tok_new, st.tok)
            rows = jnp.arange(b)
            widx = jnp.clip(st.gen, 0, st.out_buf.shape[1] - 1)
            out_buf = st.out_buf.at[rows, widx].set(
                jnp.where(st.active, tok_new, st.out_buf[rows, widx]))
            gen_new = st.gen + st.active
            pos_new = st.pos + st.active
            hit_eos = ((tok_new == eos_id) if eos_id >= 0
                       else jnp.zeros_like(st.active))
            done = st.active & (hit_eos | (gen_new >= st.budget)
                                | (pos_new >= max_len - 1))
            new_st = DeviceState(
                caches=caches, tok=tok_new, pos=pos_new, gen=gen_new,
                budget=st.budget, active=st.active & ~done, temp=st.temp,
                rng=rng_new, out_buf=out_buf, page_table=st.page_table)
            return new_st, done

        self._tick = jax.jit(tick, donate_argnums=self._donate())

    def _build_admit(self):
        """Admission executable body. Dense: pad-and-stack prefill + all-slot
        scatter. Paged: page-table update + ``paged_extend`` over the current
        prefill chunks (suffix-after-prefix-hit and chunked admission share
        the one primitive). Either way compiled per length bucket
        (_bucket_len caps how many buckets exist); each bucket's executable
        is cached in ``_admit_fns`` and traced exactly once (asserted via
        ``admit_trace_counts`` in tests/test_serve_quant.py)."""
        if self.scfg.paged:
            self._admit_impl = self._make_extend_impl()
            return
        cfg, scfg = self.cfg, self.scfg
        base_key, max_len = self._base_key, scfg.max_len
        pad_ok = self._pad_ok

        def admit(params, st: DeviceState, toks, lens, slots, budgets, temps,
                  uids) -> Tuple[DeviceState, jnp.ndarray]:
            # one batched prefill over the padded prompt stack
            logits1, row_caches = tf_lib.prefill(
                params, cfg, toks, max_len=max_len,
                cache_dtype=scfg.cache_dtype,
                lengths=lens if pad_ok else None)
            logits = logits1[:, 0]                          # (N, V)
            keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
            tok0, rng0 = _sample(logits, keys, temps)
            # scatter ALL admitted slots' cache rows at once (invalid rows
            # carry out-of-bounds slot ids and drop)
            axes = _batch_axis_tree(st.caches)
            def ins(batched, row, ax):
                if ax == 0:
                    return batched.at[slots].set(
                        row.astype(batched.dtype), mode="drop")
                return batched.at[:, slots].set(
                    row.astype(batched.dtype), mode="drop")
            caches = jax.tree.map(ins, st.caches, row_caches, axes)
            cap = st.out_buf.shape[1]
            out_rows = jnp.zeros((tok0.shape[0], cap), jnp.int32
                                 ).at[:, 0].set(tok0)
            # a request can finish at prefill: max_tokens == 1, prompt at
            # the length cap (total context is capped at max_len), or the
            # very first sampled token being EOS
            done = (budgets <= 1) | (lens >= max_len - 1)
            if scfg.eos_id >= 0:
                done |= tok0 == scfg.eos_id
            new_st = DeviceState(
                caches=caches,
                tok=st.tok.at[slots].set(tok0, mode="drop"),
                pos=st.pos.at[slots].set(lens, mode="drop"),
                gen=st.gen.at[slots].set(1, mode="drop"),
                budget=st.budget.at[slots].set(budgets, mode="drop"),
                active=st.active.at[slots].set(~done, mode="drop"),
                temp=st.temp.at[slots].set(temps, mode="drop"),
                rng=st.rng.at[slots].set(rng0, mode="drop"),
                out_buf=st.out_buf.at[slots].set(out_rows, mode="drop"),
                page_table=st.page_table)
            return new_st, done

        self._admit_impl = admit

    def _make_extend_impl(self):
        """Paged admission body: one ``paged_extend`` call advances every
        in-flight prefill by one chunk. Rows whose prompt *ends* in this
        chunk (``final``) sample their first token and activate their slot;
        mid-chunk rows only record progress (``pos``) and stay inactive, so
        decode ticks interleave freely with long admissions."""
        cfg, scfg = self.cfg, self.scfg
        base_key, max_len = self._base_key, scfg.max_len

        def extend(params, st: DeviceState, toks, starts, lens, slots,
                   tables, budgets, temps, uids, final
                   ) -> Tuple[DeviceState, jnp.ndarray]:
            # ``tables`` is ROW-major (row j belongs to batch row j, sink-
            # filled for unused rows) — paged_extend indexes its table by
            # batch row, NOT by slot id; handing it the slot-major state
            # table would write through some *other* slot's pages whenever
            # rows and slots misalign. The persistent slot-major table is
            # updated separately (OOB slot ids drop).
            pt = st.page_table.at[slots].set(tables, mode="drop")
            logits1, caches = tf_lib.paged_extend(
                params, cfg, toks, starts, lens, tables, st.caches)
            logits = logits1[:, 0]                          # (N, V)
            keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
            tok0, rng0 = _sample(logits, keys, temps)
            end = starts + lens
            done = final & ((budgets <= 1) | (end >= max_len - 1))
            if scfg.eos_id >= 0:
                done |= final & (tok0 == scfg.eos_id)
            cap = st.out_buf.shape[1]
            out_rows = jnp.zeros((tok0.shape[0], cap), jnp.int32
                                 ).at[:, 0].set(jnp.where(final, tok0, 0))
            new_st = DeviceState(
                caches=caches,
                tok=st.tok.at[slots].set(jnp.where(final, tok0, 0),
                                         mode="drop"),
                pos=st.pos.at[slots].set(end, mode="drop"),
                gen=st.gen.at[slots].set(jnp.where(final, 1, 0),
                                         mode="drop"),
                budget=st.budget.at[slots].set(budgets, mode="drop"),
                active=st.active.at[slots].set(final & ~done, mode="drop"),
                temp=st.temp.at[slots].set(temps, mode="drop"),
                rng=st.rng.at[slots].set(rng0, mode="drop"),
                out_buf=st.out_buf.at[slots].set(out_rows, mode="drop"),
                page_table=pt)
            return new_st, done

        return extend

    def _admit_exe(self, bucket: int):
        """One jitted admit/extend executable per length bucket, built on
        first use and reused for every later admission in that bucket — no
        per-call rebuild churn."""
        fn = self._admit_fns.get(bucket)
        if fn is None:
            impl = self._admit_impl

            def admit_b(params, st, *args):
                # python side effect: per-bucket trace count
                self.admit_trace_counts[bucket] = \
                    self.admit_trace_counts.get(bucket, 0) + 1
                return impl(params, st, *args)

            fn = jax.jit(admit_b, donate_argnums=self._donate())
            self._admit_fns[bucket] = fn
        return fn

    # -- queue API ------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16,
               temperature: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size >= self.scfg.max_len:
            raise ValueError(f"prompt length {prompt.size} >= max_len "
                             f"{self.scfg.max_len}")
        if self.pool is not None:
            # a request whose worst-case page demand can never be met would
            # livelock admission (fits() false forever) — reject it here
            need = self._pages_needed(prompt.size, max_tokens)
            if need > self.pool.num_pages:
                raise ValueError(
                    f"request needs {need} pages (prompt {prompt.size} + "
                    f"max_tokens {max_tokens}) but the pool has only "
                    f"{self.pool.num_pages}; raise num_pages or lower "
                    f"max_tokens")
        self._uid += 1
        self.scheduler.submit(Request(self._uid, prompt, max_tokens,
                                      temperature))
        return self._uid

    @property
    def queue(self):
        return self.scheduler.pending

    # -- host readback helpers ------------------------------------------------

    def _readback(self, x) -> np.ndarray:
        """Every device->host transfer goes through here (counted: the tick
        hot path must do exactly one — the finished mask)."""
        self.host_readbacks += 1
        return np.asarray(x)

    def _finish_slot(self, slot: int, finished: List[Request]) -> None:
        req = self.slot_req[slot]
        n = self._host_gen[slot]
        toks = self._readback(self.state.out_buf[slot, :n])
        req.generated = [int(t) for t in toks]
        req.done = True
        finished.append(req)
        self.slot_req[slot] = None
        self._host_gen[slot] = 0
        if self.pool is not None and self._slot_pages[slot]:
            # published prefix pages park in the pool's LRU (still
            # hittable); private decode/suffix pages free immediately
            self.pool.release_all(self._slot_pages[slot])
            self._slot_pages[slot] = []

    # -- admission ------------------------------------------------------------

    def _admit(self, finished: List[Request]) -> "_AdmitInfo":
        if self.scfg.paged:
            return self._admit_paged(finished)
        return self._admit_dense(finished)

    def _admit_dense(self, finished: List[Request]) -> "_AdmitInfo":
        """Batched dense admission: ONE padded prefill + all-slot scatter."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        reqs = self.scheduler.select(len(free))
        if not reqs:
            return _AdmitInfo()
        if not self._pad_ok:
            # SSD/hybrid archs: only equal-length prompts share a prefill
            same = [r for r in reqs if len(r.prompt) == len(reqs[0].prompt)]
            self.scheduler.requeue_front([r for r in reqs if r not in same])
            reqs = same
        nslots = self.scfg.max_slots
        # SSD path runs prefill without per-row lengths, so the stack width
        # must equal the (shared) true prompt length — no bucket padding.
        # The bucket is capped at max_len: a wider stack would push prefill
        # into its ring branch and silently drop the oldest prompt tokens.
        lmax = (_bucket_len(max(len(r.prompt) for r in reqs),
                            cap=self.scfg.max_len)
                if self._pad_ok else len(reqs[0].prompt))
        n = len(reqs)
        toks = np.zeros((nslots, lmax), np.int32)
        lens = np.zeros(nslots, np.int32)
        slots = np.full(nslots, nslots + 1, np.int32)   # OOB rows drop
        budgets = np.ones(nslots, np.int32)
        temps = np.zeros(nslots, np.float32)
        uids = np.zeros(nslots, np.int32)
        for j, req in enumerate(reqs):
            sl = len(req.prompt)
            toks[j, :sl] = req.prompt
            lens[j] = sl
            slots[j] = free[j]
            budgets[j] = req.max_tokens
            temps[j] = (self.scfg.temperature if req.temperature is None
                        else req.temperature)
            uids[j] = req.uid
        self.state, done = self._admit_exe(lmax)(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(slots), jnp.asarray(budgets), jnp.asarray(temps),
            jnp.asarray(uids))
        done_mask = self._readback(done)
        for j, req in enumerate(reqs):
            self.slot_req[free[j]] = req
            self._host_gen[free[j]] = 1
            if done_mask[j]:
                self._finish_slot(free[j], finished)
        toks_n = int(lens.sum())
        sq = int((lens.astype(np.int64) ** 2).sum())
        return _AdmitInfo(
            admitted=len(reqs), prefill_tokens=toks_n, weight_passes=1,
            kv_bytes=self.kv_cache_bytes * len(reqs) / self.scfg.max_slots,
            flops=(2.0 * self._matmul_elems * toks_n
                   + 2.0 * self._n_attn * self._attn_dims * sq))

    # -- paged admission (DESIGN.md §14) --------------------------------------

    def _pages_needed(self, prompt_len: int, max_tokens: int) -> int:
        """Worst-case (no-hit) page demand of a request: its full possible
        context, prompt + budget, capped at max_len."""
        ctx = min(prompt_len + max_tokens, self.scfg.max_len)
        return -(-ctx // self.scfg.page_size)

    def _admit_paged(self, finished: List[Request]) -> "_AdmitInfo":
        """Paged admission tick: select new requests that fit the pool,
        look up their prefix blocks, allocate suffix+decode pages, then
        advance EVERY in-flight prefill (new and continuing) by one chunk
        in a single ``paged_extend`` call. With ``prefill_chunk == 0`` the
        whole suffix lands in one call (the dense-equivalent behaviour,
        minus the shared prefix); with a chunk size, per-tick prefill work
        is bounded by ``max_slots * prefill_chunk`` tokens regardless of
        prompt length — the tick-time tail-latency bound."""
        scfg = self.scfg
        ps = scfg.page_size
        nslots, nb = scfg.max_slots, self._blocks_per_slot
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        budget_pages = [self.pool.available]

        def fits(req: Request) -> bool:
            # conservative: ignores hits (submit() guarantees need can be
            # met by an empty pool, so deferral always terminates)
            need = self._pages_needed(len(req.prompt), req.max_tokens)
            if need > budget_pages[0]:
                return False
            budget_pages[0] -= need
            return True

        reqs = self.scheduler.select(len(free), fits=fits)
        admitted = len(reqs)
        hit_tokens = 0
        hit_sq = 0.0
        for j, req in enumerate(reqs):
            slot = free[j]
            plen = len(req.prompt)
            blocks = (block_tokens(req.prompt, ps)
                      if scfg.prefix_cache else [])
            hits = self.pool.lookup(blocks)
            n_hit0 = len(hits)
            # at least one suffix token must run to produce the sampling
            # logits, so a fully cached prompt re-computes its last block
            while hits and len(hits) * ps >= plen:
                self.pool.release(hits.pop())
            shared = len(hits) * ps
            fresh = self.pool.alloc(
                self._pages_needed(plen, req.max_tokens) - len(hits))
            if fresh is None:       # estimate raced capacity: defer
                self.pool.release_all(hits)
                # the retry re-runs lookup: roll back this attempt's stats
                # so hit_rate counts each admission once
                self.pool.unbook_lookup(n_hit0, len(blocks))
                self.scheduler.requeue_front(
                    [req] + reqs[j + 1:])
                admitted = j
                break
            pages = hits + fresh
            self.slot_req[slot] = req
            self._slot_pages[slot] = pages
            self._prefilling[slot] = {
                "req": req, "plen": plen, "next": shared,
                "blocks": blocks, "pages": pages}
            hit_tokens += shared
            hit_sq += float(shared) ** 2
        # one extend call advances every in-flight prefill by one chunk
        work = sorted(self._prefilling.items())
        if not work:
            return _AdmitInfo(admitted=admitted,
                              prefix_hit_tokens=hit_tokens)
        # even with chunking off, cap the implicit chunk at the chunked-
        # SDPA threshold: extend's attention materializes O(C * window)
        # fp32 logits per layer, and dense prefill bounds the same blow-up
        # by switching to sdpa_q_chunked at this width
        from repro.models.layers import _CHUNKED_SDPA_THRESHOLD
        chunk_cap = scfg.prefill_chunk or min(scfg.max_len,
                                              _CHUNKED_SDPA_THRESHOLD)
        call_lens = [min(w["plen"] - w["next"], chunk_cap)
                     for _, w in work]
        # every call_len <= chunk_cap, so the bucket always covers them
        width = _bucket_len(max(call_lens), cap=chunk_cap)
        toks = np.zeros((nslots, width), np.int32)
        starts = np.zeros(nslots, np.int32)
        lens = np.zeros(nslots, np.int32)
        slots = np.full(nslots, nslots + 1, np.int32)   # OOB rows drop
        # row-major page tables for this call; unused rows write to sink
        tables = np.full((nslots, nb), self.pool.sink, np.int32)
        budgets = np.ones(nslots, np.int32)
        temps = np.zeros(nslots, np.float32)
        uids = np.zeros(nslots, np.int32)
        final = np.zeros(nslots, bool)
        for j, ((slot, w), clen) in enumerate(zip(work, call_lens)):
            req = w["req"]
            toks[j, :clen] = req.prompt[w["next"]:w["next"] + clen]
            starts[j] = w["next"]
            lens[j] = clen
            slots[j] = slot
            budgets[j] = req.max_tokens
            temps[j] = (scfg.temperature if req.temperature is None
                        else req.temperature)
            uids[j] = req.uid
            final[j] = w["next"] + clen >= w["plen"]
            row = w["pages"] + [self.pool.sink] * (nb - len(w["pages"]))
            tables[j] = row[:nb]
        self.state, done = self._admit_exe(width)(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(starts),
            jnp.asarray(lens), jnp.asarray(slots), jnp.asarray(tables),
            jnp.asarray(budgets), jnp.asarray(temps), jnp.asarray(uids),
            jnp.asarray(final))
        done_mask = self._readback(done)
        computed = int(lens.sum())
        # causal-attention FLOPs of the chunk: sum over rows of
        # end^2 - start^2 (the start=0 case reduces to the dense bill)
        ends = (starts + lens).astype(np.int64)
        attn_sq = float((ends ** 2 - starts.astype(np.int64) ** 2).sum())
        for j, ((slot, w), clen) in enumerate(zip(work, call_lens)):
            if final[j]:
                del self._prefilling[slot]
                self._host_gen[slot] = 1
                # publish the prompt's full, now-frozen blocks for reuse,
                # chaining each key through the CANONICAL page publish()
                # returns — two slots computing the same prefix in the same
                # tick must converge on one chain, not register a shadow
                # chain no lookup can reach
                if scfg.prefix_cache:
                    parent = ROOT
                    for bi, block in enumerate(w["blocks"]):
                        parent = self.pool.publish(w["pages"][bi], parent,
                                                   block)
                if done_mask[j]:
                    self._finish_slot(slot, finished)
            else:
                w["next"] += clen
        return _AdmitInfo(
            admitted=admitted, prefill_tokens=computed, weight_passes=1,
            prefix_hit_tokens=hit_tokens,
            # extend reads the cached window [0, start) once per chunk and
            # writes the chunk's KV — page-granular, not whole-cache
            kv_bytes=self._kv_token_bytes * (float(starts.sum()) + computed),
            flops=(2.0 * self._matmul_elems * computed
                   + 2.0 * self._n_attn * self._attn_dims * attn_sq),
            saved_bytes=self._kv_token_bytes * hit_tokens,
            saved_flops=(2.0 * self._matmul_elems * hit_tokens
                         + 2.0 * self._n_attn * self._attn_dims * hit_sq))

    # -- main tick ------------------------------------------------------------

    def step(self) -> List[Request]:
        """Admit + one fused decode tick. Returns finished requests."""
        t0 = time.monotonic()
        finished: List[Request] = []
        adm = self._admit(finished)
        # decoding slots only: mid-prefill paged slots occupy a slot but
        # don't produce decode tokens until their final chunk activates them
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and i not in self._prefilling]
        # live context per decoding slot: the tick attends lengths pos+1 =
        # prompt + generated-so-far — captured before finishes clear the
        # slot (page-granular KV read bill)
        ctx = sum(len(self.slot_req[i].prompt) + self._host_gen[i]
                  for i in active) if self.scfg.paged else 0
        if active:
            self.state, done = self._tick(self.params, self.state)
            done_mask = self._readback(done)   # the ONLY per-tick transfer
            for i in active:
                self._host_gen[i] += 1
            for i in np.nonzero(done_mask)[0]:
                if (self.slot_req[int(i)] is not None
                        and int(i) not in self._prefilling):
                    self._finish_slot(int(i), finished)
        # modeled traffic/compute of the tick (DESIGN.md §12/§14): every
        # jitted call streams the full weight tree once; the dense decode
        # reads the whole resident KV payload, while the paged decode reads
        # only the active slots' live context (page-granular) — admission
        # terms come pre-computed from the admit path.
        wb = kvb = fl = 0.0
        if active:
            wb += self.weight_bytes
            if self.scfg.paged:
                kvb += self._kv_token_bytes * ctx
                fl += (len(active) * 2.0 * self._matmul_elems
                       + 4.0 * self._n_attn * self._attn_dims * ctx)
            else:
                kvb += self.kv_cache_bytes
                fl += len(active) * (2.0 * self._matmul_elems
                                     + 4.0 * self._n_attn * self._attn_dims
                                     * self.scfg.max_len)
        if adm.weight_passes:
            wb += self.weight_bytes * adm.weight_passes
        kvb += adm.kv_bytes
        fl += adm.flops
        m = StepMetrics(tokens=len(active), active_slots=len(active),
                        wall_s=time.monotonic() - t0,
                        prefill_tokens=adm.prefill_tokens,
                        admitted=adm.admitted,
                        queue_depth=len(self.scheduler),
                        weight_bytes=wb, kv_bytes=kvb, flops=fl,
                        prefix_hit_tokens=adm.prefix_hit_tokens,
                        saved_bytes=adm.saved_bytes,
                        saved_flops=adm.saved_flops)
        self.last_metrics = m
        self.metrics_log.append(m)
        if self.accountant is not None:
            self.accountant.observe_serve(m)
        return finished

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not len(self.scheduler) and all(r is None
                                               for r in self.slot_req):
                break
        return done

    # -- aggregate metrics ----------------------------------------------------

    def summary(self) -> Dict[str, float]:
        toks = sum(m.tokens for m in self.metrics_log)
        wall = sum(m.wall_s for m in self.metrics_log)
        out = {"ticks": len(self.metrics_log),
               "decode_tokens": toks,
               "prefill_tokens": sum(m.prefill_tokens
                                     for m in self.metrics_log),
               "wall_s": wall,
               "decode_tokens_per_s": toks / wall if wall > 0 else 0.0}
        if self.scfg.paged:
            hit = sum(m.prefix_hit_tokens for m in self.metrics_log)
            total = hit + out["prefill_tokens"]
            out["prefix_hit_tokens"] = hit
            out["prefix_hit_rate"] = hit / total if total else 0.0
            out["saved_bytes"] = sum(m.saved_bytes for m in self.metrics_log)
            out["pool_pages"] = self.pool.num_pages
            out["pool_pages_live"] = self.pool.live
        return out


def _sample(logits: jnp.ndarray, keys: jnp.ndarray, temp: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-slot sampling: greedy where temp == 0, else categorical at temp,
    each slot drawing from its own PRNG key. Returns (tokens, new keys)."""
    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # (B, 2, 2)
    sub = split[:, 1]
    new_keys = jnp.where((temp > 0)[:, None], split[:, 0], keys)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tsafe = jnp.where(temp > 0, temp, 1.0)
    sampled = jax.vmap(jax.random.categorical)(
        sub, logits / tsafe[:, None]).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy), new_keys
