"""Device-resident continuous-batching serve core.

One jitted **engine tick** does everything on device: the batched decode step
over the shared slot-major KV cache (per-slot positions — no expand/squeeze
vmap tricks), sampling (greedy + per-slot temperature with per-slot PRNG
keys), token/position advance, EOS/max-token done flags, and a device-side
output ring buffer. The host reads back ONE compact (max_slots,) finished
mask per tick; generated tokens leave the device only when a request
finishes. Throughput and J/token are therefore properties of the hardware,
not of Python overhead (the paper's operational-energy argument, measured on
the live path).

Admission is batched too: the scheduler (serve/scheduler.py) picks queued
requests, the engine pads-and-stacks them into ONE prefill call and scatters
every admitted slot's cache rows at once.

Every tick produces a :class:`StepMetrics` billed to the CarbonAccountant,
so J/token is a first-class live serving metric.

The host-loop baseline this replaces lives on as serve/reference.py (the
correctness oracle and the benchmark's "before").
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting
from repro.models import transformer as tf_lib
from repro.serve.scheduler import Scheduler, SchedulerConfig

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    max_len: int = 512
    eos_id: int = -1          # -1: never; sampling stops at max_tokens
    temperature: float = 0.0  # default per-request temperature; 0 = greedy
    cache_dtype: Any = jnp.float32
    seed: int = 0
    # route batched decode attention through the Pallas decode kernel
    # (kernels/decode_attention.py). None = auto: on for TPU backends, off
    # elsewhere (interpret mode is correctness-only).
    decode_kernel: Optional[bool] = None
    # quantized serving fast path (DESIGN.md §12): "none" | "int8".
    # int8 quantizes the weight tree (per-channel scales) AND the KV cache
    # (per-token/head scales); cache_dtype is ignored for K/V in that mode.
    quant: str = "none"


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_tokens: int = 16
    temperature: Optional[float] = None   # None -> ServeConfig.temperature
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class StepMetrics:
    """What one engine tick did — the unit core/accounting.py bills."""
    tokens: int                 # decode tokens produced this tick
    active_slots: int           # slots decoding this tick
    wall_s: float               # host wall time of the tick (incl. admission)
    prefill_tokens: int = 0     # prompt tokens prefilled this tick
    admitted: int = 0           # requests admitted this tick
    queue_depth: int = 0        # requests still waiting after the tick
    # dtype-aware modeled traffic/compute of the tick (engine-computed from
    # the actual resident array sizes; the paper's bytes-dominate-energy
    # argument made measurable — CarbonAccountant bills a per-byte DRAM
    # term from these alongside the FLOPs term)
    weight_bytes: float = 0.0   # parameter bytes streamed from HBM
    kv_bytes: float = 0.0       # KV-cache bytes read/written
    flops: float = 0.0          # modeled FLOPs

    @property
    def bytes_moved(self) -> float:
        return self.weight_bytes + self.kv_bytes


@dataclasses.dataclass
class DeviceState:
    """All per-slot serving state, resident on device between ticks."""
    caches: PyTree
    tok: jnp.ndarray            # (B,)  last token per slot
    pos: jnp.ndarray            # (B,)  next cache write position per slot
    gen: jnp.ndarray            # (B,)  tokens generated per slot
    budget: jnp.ndarray         # (B,)  max_tokens per slot
    active: jnp.ndarray         # (B,)  bool
    temp: jnp.ndarray           # (B,)  per-slot sampling temperature
    rng: jnp.ndarray            # (B, 2) per-slot PRNG keys (uint32)
    out_buf: jnp.ndarray        # (B, max_len) device-side output ring buffer


jax.tree_util.register_dataclass(
    DeviceState,
    data_fields=["caches", "tok", "pos", "gen", "budget", "active", "temp",
                 "rng", "out_buf"],
    meta_fields=[])


def _batch_axis_tree(caches: PyTree) -> PyTree:
    """Batch axis per cache leaf: pattern caches carry batch at axis 1 (the
    stacked layer dim leads); tail caches at axis 0."""
    def per_key(key, sub):
        ax = 1 if key.startswith("pat") else 0
        return jax.tree.map(lambda _: ax, sub)
    return {k: per_key(k, v) for k, v in caches.items()}


def _bucket_len(n: int) -> int:
    """Pad prompt-batch length to a pow2 bucket (bounds prefill recompiles)."""
    b = 4
    while b < n:
        b *= 2
    return b


# -- modeled traffic / compute (DESIGN.md §12) --------------------------------
# Shared with the train engine: models/costing.py is the single cost model
# (these aliases keep the engine's call sites and tests stable).

from repro.models.costing import (attn_layers as _attn_layers,
                                  kv_bytes as _kv_bytes,
                                  matmul_weight_elems as _matmul_weight_elems,
                                  tree_bytes as _tree_bytes)


class ServeEngine:
    def __init__(self, params: PyTree, cfg: tf_lib.LMConfig,
                 serve_cfg: ServeConfig,
                 accountant: Optional[accounting.CarbonAccountant] = None,
                 scheduler: Optional[Scheduler] = None):
        use_kernel = serve_cfg.decode_kernel
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        if serve_cfg.quant not in ("none", "int8"):
            raise ValueError(f"unknown quant mode {serve_cfg.quant!r}")
        if serve_cfg.quant == "int8":
            # quantized fast path: int8 weight tree + int8 KV cache; the
            # already-quantized case (caller ran quantize_lm) passes through
            cfg = dataclasses.replace(cfg, quant=tf_lib.INT8_QUANT)
            params = tf_lib.quantize_lm(params)
        self.params = params
        self.cfg = dataclasses.replace(cfg, decode_kernel=bool(use_kernel))
        self.scfg = serve_cfg
        self.accountant = accountant
        self.scheduler = scheduler or Scheduler(SchedulerConfig())
        b, cap = serve_cfg.max_slots, serve_cfg.max_len
        base_key = jax.random.PRNGKey(serve_cfg.seed)
        self._base_key = base_key
        self.state = DeviceState(
            caches=tf_lib.init_caches(self.cfg, b, cap, serve_cfg.cache_dtype),
            tok=jnp.zeros(b, jnp.int32),
            pos=jnp.zeros(b, jnp.int32),
            gen=jnp.zeros(b, jnp.int32),
            budget=jnp.zeros(b, jnp.int32),
            active=jnp.zeros(b, bool),
            temp=jnp.zeros(b, jnp.float32),
            rng=jax.vmap(lambda i: jax.random.fold_in(base_key, i))(
                jnp.arange(b)),
            out_buf=jnp.zeros((b, cap), jnp.int32))
        # host mirrors (admission + finished-mask readbacks keep them exact;
        # no per-slot device transfers needed)
        self.slot_req: List[Optional[Request]] = [None] * b
        self._host_gen = [0] * b
        self._uid = 0
        # padded prefill needs causal masking to localize each row; SSM
        # states integrate over padding, so SSD archs admit equal-length
        # groups instead
        self._pad_ok = all(
            sp.kind == "attn"
            for sp in tuple(cfg.pattern) + tuple(cfg.tail))
        # instrumentation (tests assert the tick stays fused: one trace,
        # one host readback per tick; admission compiles once per length
        # bucket)
        self.tick_trace_count = 0
        self.host_readbacks = 0
        self.admit_trace_counts: Dict[int, int] = {}
        self._admit_fns: Dict[int, Any] = {}
        self.last_metrics: Optional[StepMetrics] = None
        self.metrics_log: List[StepMetrics] = []
        # modeled per-tick traffic/compute (DESIGN.md §12): dtype-aware
        # bytes from the actual resident arrays — this is where the int8
        # path's 2-4x byte reduction becomes measurable
        self.weight_bytes = _tree_bytes(self.params)
        self.kv_cache_bytes = _kv_bytes(self.state.caches)
        self._matmul_elems = _matmul_weight_elems(self.params, self.cfg)
        self._n_attn = _attn_layers(self.cfg)
        self._attn_dims = self.cfg.n_heads * self.cfg.resolved_head_dim
        self._build_tick()
        self._build_admit()

    # -- compiled paths -------------------------------------------------------

    def _donate(self):
        # DeviceState is donated on every tick/admit: the KV cache and slot
        # arrays update in place instead of being copied each call. The old
        # state object is dead after the call (step() always reassigns).
        return (1,)

    def _build_tick(self):
        cfg, scfg = self.cfg, self.scfg
        eos_id, max_len = scfg.eos_id, scfg.max_len

        def tick(params, st: DeviceState) -> Tuple[DeviceState, jnp.ndarray]:
            self.tick_trace_count += 1      # python side effect: trace count
            b = st.tok.shape[0]
            logits1, caches = tf_lib.decode_step(params, cfg, st.tok[:, None],
                                                 st.pos, st.caches)
            logits = logits1[:, 0]                          # (B, V) fp32
            tok_new, rng_new = _sample(logits, st.rng, st.temp)
            tok_new = jnp.where(st.active, tok_new, st.tok)
            rows = jnp.arange(b)
            widx = jnp.clip(st.gen, 0, st.out_buf.shape[1] - 1)
            out_buf = st.out_buf.at[rows, widx].set(
                jnp.where(st.active, tok_new, st.out_buf[rows, widx]))
            gen_new = st.gen + st.active
            pos_new = st.pos + st.active
            hit_eos = ((tok_new == eos_id) if eos_id >= 0
                       else jnp.zeros_like(st.active))
            done = st.active & (hit_eos | (gen_new >= st.budget)
                                | (pos_new >= max_len - 1))
            new_st = DeviceState(
                caches=caches, tok=tok_new, pos=pos_new, gen=gen_new,
                budget=st.budget, active=st.active & ~done, temp=st.temp,
                rng=rng_new, out_buf=out_buf)
            return new_st, done

        self._tick = jax.jit(tick, donate_argnums=self._donate())

    def _build_admit(self):
        """Pad-and-stack prefill + all-slot scatter. Compiled per length
        bucket (_bucket_len bounds how many buckets exist); each bucket's
        executable is cached in ``_admit_fns`` and traced exactly once
        (asserted via ``admit_trace_counts`` in tests/test_serve_quant.py)."""
        cfg, scfg = self.cfg, self.scfg
        base_key, max_len = self._base_key, scfg.max_len
        pad_ok = self._pad_ok

        def admit(params, st: DeviceState, toks, lens, slots, budgets, temps,
                  uids) -> Tuple[DeviceState, jnp.ndarray]:
            # one batched prefill over the padded prompt stack
            logits1, row_caches = tf_lib.prefill(
                params, cfg, toks, max_len=max_len,
                cache_dtype=scfg.cache_dtype,
                lengths=lens if pad_ok else None)
            logits = logits1[:, 0]                          # (N, V)
            keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
            tok0, rng0 = _sample(logits, keys, temps)
            # scatter ALL admitted slots' cache rows at once (invalid rows
            # carry out-of-bounds slot ids and drop)
            axes = _batch_axis_tree(st.caches)
            def ins(batched, row, ax):
                if ax == 0:
                    return batched.at[slots].set(
                        row.astype(batched.dtype), mode="drop")
                return batched.at[:, slots].set(
                    row.astype(batched.dtype), mode="drop")
            caches = jax.tree.map(ins, st.caches, row_caches, axes)
            cap = st.out_buf.shape[1]
            out_rows = jnp.zeros((tok0.shape[0], cap), jnp.int32
                                 ).at[:, 0].set(tok0)
            # a request can finish at prefill: max_tokens == 1, prompt at
            # the length cap (total context is capped at max_len), or the
            # very first sampled token being EOS
            done = (budgets <= 1) | (lens >= max_len - 1)
            if scfg.eos_id >= 0:
                done |= tok0 == scfg.eos_id
            new_st = DeviceState(
                caches=caches,
                tok=st.tok.at[slots].set(tok0, mode="drop"),
                pos=st.pos.at[slots].set(lens, mode="drop"),
                gen=st.gen.at[slots].set(1, mode="drop"),
                budget=st.budget.at[slots].set(budgets, mode="drop"),
                active=st.active.at[slots].set(~done, mode="drop"),
                temp=st.temp.at[slots].set(temps, mode="drop"),
                rng=st.rng.at[slots].set(rng0, mode="drop"),
                out_buf=st.out_buf.at[slots].set(out_rows, mode="drop"))
            return new_st, done

        self._admit_impl = admit

    def _admit_exe(self, bucket: int):
        """One jitted admit executable per prompt-length bucket, built on
        first use and reused for every later admission in that bucket — no
        per-call rebuild churn."""
        fn = self._admit_fns.get(bucket)
        if fn is None:
            impl = self._admit_impl

            def admit_b(params, st, toks, lens, slots, budgets, temps, uids):
                # python side effect: per-bucket trace count
                self.admit_trace_counts[bucket] = \
                    self.admit_trace_counts.get(bucket, 0) + 1
                return impl(params, st, toks, lens, slots, budgets, temps,
                            uids)

            fn = jax.jit(admit_b, donate_argnums=self._donate())
            self._admit_fns[bucket] = fn
        return fn

    # -- queue API ------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16,
               temperature: Optional[float] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size >= self.scfg.max_len:
            raise ValueError(f"prompt length {prompt.size} >= max_len "
                             f"{self.scfg.max_len}")
        self._uid += 1
        self.scheduler.submit(Request(self._uid, prompt, max_tokens,
                                      temperature))
        return self._uid

    @property
    def queue(self):
        return self.scheduler.pending

    # -- host readback helpers ------------------------------------------------

    def _readback(self, x) -> np.ndarray:
        """Every device->host transfer goes through here (counted: the tick
        hot path must do exactly one — the finished mask)."""
        self.host_readbacks += 1
        return np.asarray(x)

    def _finish_slot(self, slot: int, finished: List[Request]) -> None:
        req = self.slot_req[slot]
        n = self._host_gen[slot]
        toks = self._readback(self.state.out_buf[slot, :n])
        req.generated = [int(t) for t in toks]
        req.done = True
        finished.append(req)
        self.slot_req[slot] = None
        self._host_gen[slot] = 0

    # -- admission ------------------------------------------------------------

    def _admit(self, finished: List[Request]) -> Tuple[int, int, int]:
        """Batched admission. Returns (n_admitted, prompt_tokens,
        sum of squared prompt lengths — the prefill-attention FLOPs term)."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        reqs = self.scheduler.select(len(free))
        if not reqs:
            return 0, 0, 0
        if not self._pad_ok:
            # SSD/hybrid archs: only equal-length prompts share a prefill
            same = [r for r in reqs if len(r.prompt) == len(reqs[0].prompt)]
            self.scheduler.requeue_front([r for r in reqs if r not in same])
            reqs = same
        nslots = self.scfg.max_slots
        # SSD path runs prefill without per-row lengths, so the stack width
        # must equal the (shared) true prompt length — no bucket padding.
        # The bucket is clamped to max_len: a wider stack would push prefill
        # into its ring branch and silently drop the oldest prompt tokens.
        lmax = (min(_bucket_len(max(len(r.prompt) for r in reqs)),
                    self.scfg.max_len)
                if self._pad_ok else len(reqs[0].prompt))
        n = len(reqs)
        toks = np.zeros((nslots, lmax), np.int32)
        lens = np.zeros(nslots, np.int32)
        slots = np.full(nslots, nslots + 1, np.int32)   # OOB rows drop
        budgets = np.ones(nslots, np.int32)
        temps = np.zeros(nslots, np.float32)
        uids = np.zeros(nslots, np.int32)
        for j, req in enumerate(reqs):
            sl = len(req.prompt)
            toks[j, :sl] = req.prompt
            lens[j] = sl
            slots[j] = free[j]
            budgets[j] = req.max_tokens
            temps[j] = (self.scfg.temperature if req.temperature is None
                        else req.temperature)
            uids[j] = req.uid
        self.state, done = self._admit_exe(lmax)(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(slots), jnp.asarray(budgets), jnp.asarray(temps),
            jnp.asarray(uids))
        done_mask = self._readback(done)
        for j, req in enumerate(reqs):
            self.slot_req[free[j]] = req
            self._host_gen[free[j]] = 1
            if done_mask[j]:
                self._finish_slot(free[j], finished)
        return len(reqs), int(lens.sum()), int((lens.astype(np.int64) ** 2).sum())

    # -- main tick ------------------------------------------------------------

    def step(self) -> List[Request]:
        """Admit + one fused decode tick. Returns finished requests."""
        t0 = time.monotonic()
        finished: List[Request] = []
        admitted, prefill_toks, prefill_sq = self._admit(finished)
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if active:
            self.state, done = self._tick(self.params, self.state)
            done_mask = self._readback(done)   # the ONLY per-tick transfer
            for i in active:
                self._host_gen[i] += 1
            for i in np.nonzero(done_mask)[0]:
                if self.slot_req[int(i)] is not None:
                    self._finish_slot(int(i), finished)
        # modeled traffic/compute of the tick (DESIGN.md §12): every jitted
        # call streams the full weight tree once; the dense decode reads the
        # whole resident KV payload, admission writes the admitted fraction.
        wb = kvb = fl = 0.0
        if active:
            wb += self.weight_bytes
            kvb += self.kv_cache_bytes
            fl += len(active) * (2.0 * self._matmul_elems
                                 + 4.0 * self._n_attn * self._attn_dims
                                 * self.scfg.max_len)
        if admitted:
            wb += self.weight_bytes
            kvb += self.kv_cache_bytes * admitted / self.scfg.max_slots
            fl += (2.0 * self._matmul_elems * prefill_toks
                   + 2.0 * self._n_attn * self._attn_dims * prefill_sq)
        m = StepMetrics(tokens=len(active), active_slots=len(active),
                        wall_s=time.monotonic() - t0,
                        prefill_tokens=prefill_toks, admitted=admitted,
                        queue_depth=len(self.scheduler),
                        weight_bytes=wb, kv_bytes=kvb, flops=fl)
        self.last_metrics = m
        self.metrics_log.append(m)
        if self.accountant is not None:
            self.accountant.observe_serve(m)
        return finished

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not len(self.scheduler) and all(r is None
                                               for r in self.slot_req):
                break
        return done

    # -- aggregate metrics ----------------------------------------------------

    def summary(self) -> Dict[str, float]:
        toks = sum(m.tokens for m in self.metrics_log)
        wall = sum(m.wall_s for m in self.metrics_log)
        return {"ticks": len(self.metrics_log),
                "decode_tokens": toks,
                "prefill_tokens": sum(m.prefill_tokens
                                      for m in self.metrics_log),
                "wall_s": wall,
                "decode_tokens_per_s": toks / wall if wall > 0 else 0.0}


def _sample(logits: jnp.ndarray, keys: jnp.ndarray, temp: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-slot sampling: greedy where temp == 0, else categorical at temp,
    each slot drawing from its own PRNG key. Returns (tokens, new keys)."""
    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # (B, 2, 2)
    sub = split[:, 1]
    new_keys = jnp.where((temp > 0)[:, None], split[:, 0], keys)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tsafe = jnp.where(temp > 0, temp, 1.0)
    sampled = jax.vmap(jax.random.categorical)(
        sub, logits / tsafe[:, None]).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy), new_keys
