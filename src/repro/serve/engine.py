"""Device-resident continuous-batching serve core.

One jitted **engine tick** does everything on device: the batched decode step
over the shared slot-major KV cache (per-slot positions — no expand/squeeze
vmap tricks), sampling (greedy + per-slot temperature with per-slot PRNG
keys), token/position advance, EOS/max-token done flags, and a device-side
output ring buffer. The host reads back ONE compact (max_slots,) finished
mask per tick; generated tokens leave the device only when a request
finishes. Throughput and J/token are therefore properties of the hardware,
not of Python overhead (the paper's operational-energy argument, measured on
the live path).

Admission is batched too: the scheduler (serve/scheduler.py) picks queued
requests, the engine pads-and-stacks them into ONE prefill call and scatters
every admitted slot's cache rows at once.

Every tick produces a :class:`StepMetrics` billed to the CarbonAccountant,
so J/token is a first-class live serving metric.

The host-loop baseline this replaces lives on as serve/reference.py (the
correctness oracle and the benchmark's "before").
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.core import accounting, energy
from repro.models import transformer as tf_lib
from repro.serve import spec as spec_lib
from repro.serve.faults import (FaultInjector, FaultPlan, GuardrailConfig,
                                ProcessKilled, corrupt_kv_page)
from repro.serve.pages import ROOT, PagePool, block_tokens, fragmentation
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.serve.snapshot import (Journal, check_fingerprint,
                                  host_state_dict, install_host_state,
                                  reconcile_ownership)
from repro.train.ft import Ewma

PyTree = Any

# sentinel stream for a fork cancelled at activation (parent finished on
# its first token): resolved to a copy of stream 0 when the group closes
_FORK_MIRROR = object()


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    max_len: int = 512
    eos_id: int = -1          # -1: never; sampling stops at max_tokens
    temperature: float = 0.0  # default per-request temperature; 0 = greedy
    cache_dtype: Any = jnp.float32
    seed: int = 0
    # route batched decode attention through the Pallas decode kernel
    # (kernels/decode_attention.py). None = auto: on for TPU backends, off
    # elsewhere (interpret mode is correctness-only).
    decode_kernel: Optional[bool] = None
    # quantized serving fast path (DESIGN.md §12): "none" | "int8".
    # int8 quantizes the weight tree (per-channel scales) AND the KV cache
    # (per-token/head scales); cache_dtype is ignored for K/V in that mode.
    quant: str = "none"
    # paged KV cache + prefix reuse + chunked prefill (DESIGN.md §14):
    paged: bool = False
    page_size: int = 16       # tokens per KV page (block granularity)
    # pool capacity in pages. None = dense-equivalent:
    # max_slots * ceil(max_len / page_size) — prefix sharing then *raises*
    # effective capacity; smaller pools admit by deferral.
    num_pages: Optional[int] = None
    # content-matched block reuse at admission: a hit copies page-table
    # entries instead of recomputing the shared prefix's prefill
    prefix_cache: bool = True
    # admit long prompts in chunks of this many tokens, interleaved with
    # decode ticks (bounds tick-time tail latency). 0 = whole suffix in
    # one extend call.
    prefill_chunk: int = 0
    # speculative multi-token decode on the paged path (DESIGN.md §15):
    # draft spec_k tokens per slot per tick, verify all of them in ONE
    # multi-query pass through the page table, commit the accepted prefix
    # plus a correction/bonus token. 0 = off. Requires paged=True.
    spec_k: int = 0
    # "ngram": device-resident prompt-lookup drafter over each slot's own
    # token history (near-zero draft cost); "oracle": the target model
    # drafts greedily — k extra decode passes, the accept-all parity
    # harness, not an energy win (serve/spec.py).
    spec_drafter: str = "ngram"
    # tree speculation (DESIGN.md §18): draft spec_tree_m independent
    # k-token branches per slot per tick over COW-forked page tables and
    # verify ALL of them in the one multi-query pass (branches fold into
    # batch rows); the longest-accepted branch commits, the rest release.
    # 1 = linear speculation (the §15 behavior, bit-identical). Requires
    # spec_k > 0. Branches beyond the first apply to greedy slots only —
    # temperature slots keep the distribution-exact linear path on branch
    # 0, because multi-branch rejection sampling would need a joint
    # residual scheme to stay unbiased.
    spec_tree_m: int = 1
    # long-context tier (DESIGN.md §16):
    # compact a live slot's private page suffix into a contiguous run when
    # its table's fragmentation score (serve/pages.py:fragmentation)
    # reaches this threshold; 0.0 = compaction off. One slot per tick.
    compact_threshold: float = 0.0
    # park reclamation: "lru" | "cost" (evict the cheapest-to-recompute
    # cached block first, scored by costing.block_recompute_flops per byte)
    evict_policy: str = "lru"
    # chaos tier (DESIGN.md §17): a seeded fault schedule to replay
    # against this engine (None = no injection), and the guardrail knobs
    # that arm detection/degradation rungs. All-default guard keeps the
    # pre-chaos behavior exactly; the numerics sentinel is always on (it
    # rides the existing packed readback for free).
    faults: Optional[FaultPlan] = None
    guard: GuardrailConfig = dataclasses.field(
        default_factory=GuardrailConfig)
    # durability tier (DESIGN.md §19): directory for crash-consistent
    # snapshots + the write-ahead request journal (None = durability off,
    # the pre-§19 behavior exactly). checkpoint_interval > 0 snapshots the
    # full engine state every N completed ticks — the knob trades snapshot
    # write J/token against recovery replay J (restore_j): shorter
    # intervals write more, replay less.
    checkpoint_dir: Optional[str] = None
    checkpoint_interval: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_tokens: int = 16
    temperature: Optional[float] = None   # None -> ServeConfig.temperature
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # per-request deadline (DESIGN.md §17): shed from the queue once
    # ``deadline_ticks`` engine ticks have passed since ``submit_tick``
    # without admission (None = wait forever). The engine stamps
    # ``submit_tick``; it also feeds the scheduler's queue-aging term.
    deadline_ticks: Optional[int] = None
    submit_tick: int = -1
    # n-best sampling over COW forks (DESIGN.md §18): a submission with
    # n_best > 1 admits ONE prefill and fans out to n_best slots sharing
    # the prompt's committed pages; the parent request completes only when
    # every fork's stream is in, with ``nbest`` holding all of them
    # (``generated`` aliases stream 0). Fork-internal requests (children,
    # continuations of forks) carry ``fork_group`` (the parent's uid) and
    # their ``fork_idx``; they are never returned to the caller directly.
    n_best: int = 1
    nbest: Optional[List[List[int]]] = None
    fork_group: Optional[int] = None
    fork_idx: int = 0


@dataclasses.dataclass
class StepMetrics:
    """What one engine tick did — the unit core/accounting.py bills."""
    tokens: int                 # decode tokens produced this tick
    active_slots: int           # slots decoding this tick
    wall_s: float               # host wall time of the tick (incl. admission)
    prefill_tokens: int = 0     # prompt tokens prefilled this tick
    admitted: int = 0           # requests admitted this tick
    queue_depth: int = 0        # requests still waiting after the tick
    # dtype-aware modeled traffic/compute of the tick (engine-computed from
    # the actual resident array sizes; the paper's bytes-dominate-energy
    # argument made measurable — CarbonAccountant bills a per-byte DRAM
    # term from these alongside the FLOPs term)
    weight_bytes: float = 0.0   # parameter bytes streamed from HBM
    kv_bytes: float = 0.0       # KV-cache bytes read/written
    flops: float = 0.0          # modeled FLOPs
    # prefix-cache effect of this tick's admission (DESIGN.md §14): prompt
    # tokens served from cached pages, and the traffic/compute the dense
    # path would have billed for them — the sustainability win, first-class
    prefix_hit_tokens: int = 0  # prompt tokens reused via prefix-cache hits
    saved_bytes: float = 0.0    # KV write bytes NOT moved thanks to reuse
    saved_flops: float = 0.0    # prefill FLOPs NOT executed thanks to reuse
    # speculative decode split (DESIGN.md §15): in spec mode ``tokens`` is
    # the EMITTED count (accepted drafts + correction/bonus) and the tick's
    # decode traffic/compute is additionally billed per phase — the drafter
    # and the verification pass are different energy stories (an n-gram
    # drafter is nearly free; the oracle drafter streams weights k times)
    spec_draft_tokens: int = 0      # tokens drafted this tick (k * active)
    spec_accepted_tokens: int = 0   # emitted beyond the 1/tick baseline
    draft_flops: float = 0.0
    draft_bytes: float = 0.0        # drafter DRAM traffic (incl. weights)
    verify_flops: float = 0.0
    verify_bytes: float = 0.0       # verify DRAM traffic (incl. weights)
    # long-context tier (DESIGN.md §16): the cached-window gather term of
    # this tick's prefill — the bytes the extend path actually moved to
    # read KV behind the in-flight chunk (kernel path: page-granular
    # ceil(start/page_size) pages per row; XLA fallback: the whole-table
    # materialization _paged_gather really performs). Included in
    # ``kv_bytes``; broken out because it is the fragmentation-sensitive
    # channel the paged prefill kernel exists to bound.
    prefill_gather_bytes: float = 0.0
    compaction_moves: int = 0       # pages relocated by compaction this tick
    # resilience tier (DESIGN.md §17): what the chaos layer did to this
    # tick and what recovery cost. ``recovery_*`` bill the re-prefill of
    # quarantined slots' context — energy the fault-free run never spends,
    # reported first-class ("On the Sustainability of AI Inferences in
    # the Edge", PAPERS.md). ``degraded`` marks a tick served under any
    # active ladder rung (reduced spec-k, fp fallback, compaction pause).
    faults_injected: int = 0
    quarantined: int = 0            # slots torn down by the sentinel
    shed: int = 0                   # requests deadline-/retry-shed
    recovery_tokens: int = 0        # prompt tokens re-prefilled for recovery
    recovery_flops: float = 0.0
    recovery_bytes: float = 0.0
    degraded: int = 0               # 1 if any degradation rung was active
    readback_retries: int = 0       # re-reads of a garbled/dropped readback
    # copy-on-write tier (DESIGN.md §18): first-class channels for the
    # fork economy. ``cow_bytes`` is real traffic (a shared page copied
    # before a divergent write — read + write of one page, also included
    # in ``kv_bytes``); ``fork_saved_*`` is the duplicate-KV bill a fork
    # did NOT pay (the prompt KV bytes + prefill FLOPs an independent
    # duplicate admission of the same stream would have spent).
    cow_bytes: float = 0.0
    cow_copies: int = 0
    forks: int = 0                  # fork children activated this tick
    fork_saved_bytes: float = 0.0
    fork_saved_flops: float = 0.0

    @property
    def bytes_moved(self) -> float:
        return self.weight_bytes + self.kv_bytes


# StepMetrics fields that are deliberately NOT energy channels — pure
# occupancy/queue observability with no joule interpretation. Everything
# else MUST have a bill site in CarbonAccountant.observe_serve; the
# accounting-completeness lint pass (repro-lint L401, DESIGN.md §20)
# fails CI on any field that is neither billed nor listed here, so a new
# channel can never ship half-wired.
ACCOUNTING_EXEMPT = frozenset({"active_slots", "admitted", "queue_depth"})


@dataclasses.dataclass
class _AdmitInfo:
    """What one admission pass did + its modeled traffic/compute bill."""
    admitted: int = 0           # requests newly selected this tick
    prefill_tokens: int = 0     # prompt tokens actually computed this tick
    weight_passes: int = 0      # extra weight-tree streams (0 or 1)
    kv_bytes: float = 0.0
    flops: float = 0.0
    prefix_hit_tokens: int = 0
    saved_bytes: float = 0.0
    saved_flops: float = 0.0
    gather_bytes: float = 0.0   # cached-window gather share of kv_bytes
    # recovery share of the above (DESIGN.md §17): rows re-prefilling a
    # quarantined slot's context bill their exact per-row cost here too
    recovery_tokens: int = 0
    recovery_flops: float = 0.0
    recovery_bytes: float = 0.0


@dataclasses.dataclass
class DeviceState:
    """All per-slot serving state, resident on device between ticks."""
    caches: PyTree
    tok: jnp.ndarray            # (B,)  last token per slot
    pos: jnp.ndarray            # (B,)  next cache write position per slot
    gen: jnp.ndarray            # (B,)  tokens generated per slot
    budget: jnp.ndarray         # (B,)  max_tokens per slot
    active: jnp.ndarray         # (B,)  bool
    temp: jnp.ndarray           # (B,)  per-slot sampling temperature
    rng: jnp.ndarray            # (B, 2) per-slot PRNG keys (uint32)
    out_buf: jnp.ndarray        # (B, max_len) device-side output ring buffer
    # paged mode: (B, NB) logical-block -> physical-page map (serve/pages.py
    # owns allocation; entries past a slot's pages point at the sink page).
    # dense mode: (B, 0) placeholder.
    page_table: jnp.ndarray = None
    # speculative mode: (B, max_len) full token history per slot (prompt +
    # emitted), valid through pos inclusive — hist[b, pos[b]] is the
    # pending token. The n-gram drafter's lookup corpus. (B, 0) otherwise.
    hist: jnp.ndarray = None


jax.tree_util.register_dataclass(
    DeviceState,
    data_fields=["caches", "tok", "pos", "gen", "budget", "active", "temp",
                 "rng", "out_buf", "page_table", "hist"],
    meta_fields=[])


def _batch_axis_tree(caches: PyTree) -> PyTree:
    """Batch axis per cache leaf: pattern caches carry batch at axis 1 (the
    stacked layer dim leads); tail caches at axis 0."""
    def per_key(key, sub):
        ax = 1 if key.startswith("pat") else 0
        return jax.tree.map(lambda _: ax, sub)
    return {k: per_key(k, v) for k, v in caches.items()}


def _bucket_len(n: int, cap: Optional[int] = None) -> int:
    """Pad prompt-batch length to a pow2 bucket (bounds prefill recompiles).

    ``cap`` clamps the bucket ladder at the configured maximum (max prompt
    length for dense admission, the chunk size for chunked prefill) — the
    executable cache then holds at most ``log2(cap)`` entries, and with
    chunked prefill one chunk-size bucket is the steady state
    (tests/test_serve_paged.py::TestBucketCap).
    """
    b = 4
    while b < n:
        b *= 2
    return min(b, cap) if cap is not None else b


# -- modeled traffic / compute (DESIGN.md §12) --------------------------------
# Shared with the train engine: models/costing.py is the single cost model
# (these aliases keep the engine's call sites and tests stable).

from repro.models import costing
from repro.models.costing import (attn_layers as _attn_layers,
                                  kv_bytes as _kv_bytes,
                                  matmul_weight_elems as _matmul_weight_elems,
                                  tree_bytes as _tree_bytes)


class ServeEngine:
    def __init__(self, params: PyTree, cfg: tf_lib.LMConfig,
                 serve_cfg: ServeConfig,
                 accountant: Optional[accounting.CarbonAccountant] = None,
                 scheduler: Optional[Scheduler] = None):
        use_kernel = serve_cfg.decode_kernel
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        if serve_cfg.quant not in ("none", "int8"):
            raise ValueError(f"unknown quant mode {serve_cfg.quant!r}")
        if serve_cfg.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {serve_cfg.spec_k}")
        if serve_cfg.spec_k > 0 and not serve_cfg.paged:
            raise ValueError("speculative decode (spec_k > 0) runs on the "
                             "paged path only; set paged=True")
        if serve_cfg.spec_drafter not in spec_lib.DRAFTERS:
            raise ValueError(f"unknown drafter {serve_cfg.spec_drafter!r}; "
                             f"expected one of {spec_lib.DRAFTERS}")
        if serve_cfg.spec_tree_m < 1:
            raise ValueError(f"spec_tree_m must be >= 1, got "
                             f"{serve_cfg.spec_tree_m}")
        if serve_cfg.spec_tree_m > 1 and serve_cfg.spec_k <= 0:
            raise ValueError("tree speculation (spec_tree_m > 1) rides the "
                             "speculative verify pass; set spec_k > 0")
        if serve_cfg.spec_tree_m > 1 and serve_cfg.spec_drafter != "ngram":
            raise ValueError("tree speculation drafts with the ngram "
                             "drafter only (the oracle drafter is a linear "
                             "parity harness)")
        if (serve_cfg.paged and serve_cfg.prefill_chunk
                and serve_cfg.prefill_chunk % serve_cfg.page_size != 0):
            raise ValueError(
                f"prefill_chunk ({serve_cfg.prefill_chunk}) must be a "
                f"multiple of page_size ({serve_cfg.page_size}): a chunk "
                f"boundary inside a page would split block publication")
        if not 0.0 <= serve_cfg.compact_threshold <= 1.0:
            raise ValueError(f"compact_threshold must be in [0, 1], got "
                             f"{serve_cfg.compact_threshold}")
        if serve_cfg.checkpoint_interval < 0:
            raise ValueError(f"checkpoint_interval must be >= 0, got "
                             f"{serve_cfg.checkpoint_interval}")
        if (serve_cfg.checkpoint_interval > 0
                and serve_cfg.checkpoint_dir is None):
            raise ValueError("checkpoint_interval > 0 requires a "
                             "checkpoint_dir to write snapshots into")
        self.scfg = serve_cfg
        self.guard = serve_cfg.guard
        self.accountant = accountant
        self.scheduler = scheduler or Scheduler(SchedulerConfig())
        self._base_key = jax.random.PRNGKey(serve_cfg.seed)
        self._use_kernel = bool(use_kernel)
        # the fp oracle pair (pre-quantization params + config): the
        # quarantine re-decode path and the int8->fp fallback rung both
        # rebuild from it (DESIGN.md §17)
        self._oracle = (params, dataclasses.replace(
            cfg, decode_kernel=self._use_kernel))
        if serve_cfg.quant == "int8":
            # quantized fast path: int8 weight tree + int8 KV cache; the
            # already-quantized case (caller ran quantize_lm) passes through
            cfg = dataclasses.replace(cfg, quant=tf_lib.INT8_QUANT)
            params = tf_lib.quantize_lm(params)
        # host mirrors that survive a runtime rebuild
        self._uid = 0
        self._fit_checked: set = set()
        # instrumentation (tests assert the tick stays fused: one trace,
        # one host readback per tick; admission compiles once per length
        # bucket). Cumulative across fp-fallback rebuilds.
        self.tick_trace_count = 0
        self.host_readbacks = 0
        self.admit_trace_counts: Dict[int, int] = {}
        self.compact_trace_count = 0
        self.cow_trace_count = 0
        self.fork_trace_count = 0
        # n-best fork groups (DESIGN.md §18) survive runtime rebuilds: a
        # group's members may be requeued as continuations by the fp
        # fallback and finish on the rebuilt engine.
        # group uid -> {"req": parent, "k": fan-out, "streams": {idx: toks}}
        self._fork_groups: Dict[int, Dict[str, Any]] = {}
        self.last_metrics: Optional[StepMetrics] = None
        self.metrics_log: List[StepMetrics] = []
        # chaos tier state (DESIGN.md §17)
        self._injector = (FaultInjector(serve_cfg.faults)
                          if serve_cfg.faults is not None else None)
        self._tick_idx = 0
        self._cur_spec_k = serve_cfg.spec_k
        self._fell_back = False
        self._recovery: Dict[int, Dict[str, Any]] = {}
        self._recovering: set = set()
        self._pending_shed: List[Request] = []
        self._defer_counts: Dict[int, int] = {}
        self._retry_after: Dict[int, int] = {}
        self._spike_holds: List[Tuple[int, List[int]]] = []
        self._tick_wall_ewma = Ewma(alpha=self.guard.ewma_alpha)
        self._accept_ewma = Ewma(alpha=self.guard.ewma_alpha)
        self._drift_ewma = Ewma(alpha=self.guard.ewma_alpha)
        self._compact_pause_until = 0
        self._drift_rr = 0
        self._tick_shed = 0
        self._tick_quarantined = 0
        self._rb_retries_tick = 0
        self.n_quarantined = 0
        self.n_shed = 0
        self.n_finished_ok = 0
        self.spec_backoffs = 0
        self.fp_fallbacks = 0
        self.compaction_pauses = 0
        self.audit_failures = 0
        self.audit_log: List[str] = []
        self.readback_retries_total = 0
        # durability tier (DESIGN.md §19): snapshot manager + write-ahead
        # journal. Synchronous saves — a snapshot must be on disk before
        # the tick that follows it can be journaled as replayable-after.
        self._ckpt_mgr: Optional[CheckpointManager] = None
        self._journal: Optional[Journal] = None
        if serve_cfg.checkpoint_dir is not None:
            self._ckpt_mgr = CheckpointManager(CheckpointConfig(
                directory=os.path.join(serve_cfg.checkpoint_dir,
                                       "snapshots"),
                async_save=False))
            self._journal = Journal(os.path.join(serve_cfg.checkpoint_dir,
                                                 "journal.jsonl"))
        # replay mode: journaling/snapshotting suppressed, recompute billed
        # to the restore_* channels instead of silently folded into serve
        self._replaying = False
        # ticks at or before this index already fired their process_kill
        # (the crash a restore recovered from); -1 = fresh engine
        self._restore_boundary = -1
        self.snapshots_taken = 0
        self.snapshot_bytes_total = 0.0
        self.journal_bytes_total = 0.0
        self.replayed_ticks = 0
        self.restore_flops = 0.0
        self.restore_bytes = 0.0
        self._init_runtime(params, cfg)

    def _init_runtime(self, params: PyTree, cfg: tf_lib.LMConfig) -> None:
        """(Re)build every device-resident and device-coupled structure:
        pool, caches, slot state, cost-model scalars, compiled tick/admit
        executables. Called once from ``__init__`` and again by the
        int8->fp fallback rung (DESIGN.md §17), which swaps in the fp
        oracle params after capturing all live slots as continuations —
        queue, accounting, and instrumentation counters survive."""
        serve_cfg = self.scfg
        self.params = params
        self.cfg = dataclasses.replace(cfg, decode_kernel=self._use_kernel)
        cfg = self.cfg
        b, cap = serve_cfg.max_slots, serve_cfg.max_len
        base_key = self._base_key
        if serve_cfg.paged:
            # paged KV subsystem (DESIGN.md §14): a shared block pool
            # replaces the per-slot dense cache; serve/pages.py owns
            # allocation/refcounts/prefix registry on the host
            if not tf_lib.paged_supported(self.cfg):
                raise NotImplementedError(
                    "paged serving is attention-only (no SSD/hybrid) and "
                    "incompatible with ring caches")
            ps = serve_cfg.page_size
            self._blocks_per_slot = -(-cap // ps)
            n_pages = serve_cfg.num_pages
            if n_pages is None:
                n_pages = b * self._blocks_per_slot
            # block_cost is attached below, once the cost-model scalars
            # (matmul elems, attn dims, per-token KV bytes) exist
            self.pool = PagePool(n_pages, ps,
                                 evict_policy=serve_cfg.evict_policy)
            caches = tf_lib.init_paged_caches(self.cfg, n_pages, ps,
                                              serve_cfg.cache_dtype)
            page_table = jnp.full((b, self._blocks_per_slot),
                                  self.pool.sink, jnp.int32)
        else:
            self.pool = None
            caches = tf_lib.init_caches(self.cfg, b, cap,
                                        serve_cfg.cache_dtype)
            page_table = jnp.zeros((b, 0), jnp.int32)
        self.state = DeviceState(
            caches=caches,
            tok=jnp.zeros(b, jnp.int32),
            pos=jnp.zeros(b, jnp.int32),
            gen=jnp.zeros(b, jnp.int32),
            budget=jnp.zeros(b, jnp.int32),
            active=jnp.zeros(b, bool),
            temp=jnp.zeros(b, jnp.float32),
            rng=jax.vmap(lambda i: jax.random.fold_in(base_key, i))(
                jnp.arange(b)),
            out_buf=jnp.zeros((b, cap), jnp.int32),
            page_table=page_table,
            # token history only exists in speculative mode (the n-gram
            # drafter's corpus); zero-width otherwise so the tick carries
            # no dead weight
            hist=jnp.zeros((b, cap if serve_cfg.spec_k > 0 else 0),
                           jnp.int32))
        # host mirrors (admission + finished-mask readbacks keep them exact;
        # no per-slot device transfers needed)
        self.slot_req: List[Optional[Request]] = [None] * b
        self._host_gen = [0] * b
        # paged host mirrors: pages owned per slot (released at finish) and
        # in-flight chunked prefills {slot: {"req", "next", "plen", ...}}
        self._slot_pages: List[List[int]] = [[] for _ in range(b)]
        self._prefilling: Dict[int, Dict[str, Any]] = {}
        # COW fork mirrors (DESIGN.md §18), slot-scoped so a runtime
        # rebuild resets them: child slots reserved for a parent still
        # mid-prefill (excluded from the active set until the fork), and
        # parent slot -> its reserved children
        self._fork_wait: Dict[int, int] = {}
        self._fork_children: Dict[int, List[int]] = {}
        # tree speculation: this tick's staged branch windows,
        # slot -> (window_lo, window_hi, [branch pages or None] * (m-1))
        self._tree_branches: Dict[int, Tuple[int, int, list]] = {}
        # per-tick COW accumulators (reset in step(), billed via
        # StepMetrics)
        self._tick_cow_bytes = 0.0
        self._tick_cow_copies = 0
        self._tick_forks = 0
        self._tick_fork_saved_bytes = 0.0
        self._tick_fork_saved_flops = 0.0
        # any injector page holds referenced the previous pool
        self._spike_holds = []
        # cached all-zero poison vector: the fault-free tick passes it by
        # reference (no per-tick host->device churn)
        self._zero_poison = jnp.zeros(b, jnp.float32)
        # padded prefill needs causal masking to localize each row; SSM
        # states integrate over padding, so SSD archs admit equal-length
        # groups instead
        self._pad_ok = all(
            sp.kind == "attn"
            for sp in tuple(cfg.pattern) + tuple(cfg.tail))
        # per-bucket admission executables bind this runtime's impl
        self._admit_fns: Dict[int, Any] = {}
        # modeled per-tick traffic/compute (DESIGN.md §12): dtype-aware
        # bytes from the actual resident arrays — this is where the int8
        # path's 2-4x byte reduction becomes measurable
        self.weight_bytes = _tree_bytes(self.params)
        self.kv_cache_bytes = _kv_bytes(self.state.caches)
        self._matmul_elems = _matmul_weight_elems(self.params, self.cfg)
        self._n_attn = _attn_layers(self.cfg)
        self._attn_dims = self.cfg.n_heads * self.cfg.resolved_head_dim
        if serve_cfg.paged:
            # KV payload bytes per cached token (codes + scales), for the
            # page-granular traffic model (DESIGN.md §14)
            self._kv_token_bytes = self.kv_cache_bytes / float(
                (self.pool.num_pages + 1) * serve_cfg.page_size)
            # cost-aware eviction score (DESIGN.md §16): recompute FLOPs
            # per resident byte of one block at chain depth d — deeper
            # blocks imply re-prefilling their whole prefix, so they are
            # the last to go under "cost" policy
            ps = serve_cfg.page_size
            block_bytes = self._kv_token_bytes * ps
            self.pool.block_cost = lambda d: costing.block_recompute_flops(
                self._matmul_elems, self._n_attn, self._attn_dims,
                d * ps, ps) / block_bytes
        self._build_tick()
        self._build_admit()
        if serve_cfg.paged and serve_cfg.compact_threshold > 0.0:
            self._build_compact()
        if serve_cfg.paged:
            self._build_cow()
            self._build_fork()

    # -- compiled paths -------------------------------------------------------

    def _donate(self):
        # DeviceState is donated on every tick/admit: the KV cache and slot
        # arrays update in place instead of being copied each call. The old
        # state object is dead after the call (step() always reassigns).
        return (1,)

    def _build_tick(self):
        """Build the tick executable cache. One executable per spec-k in
        use: the spec-k backoff rung (DESIGN.md §17) steps k down (4 -> 2
        -> 1) when acceptance collapses, and each k is its own trace.
        Every tick takes a ``poison`` vector ((B,) float32, all zeros in
        healthy runs — a traced argument, so injection never retraces)
        and folds the numerics sentinel into the packed readback: a slot
        whose logits go non-finite commits NOTHING that tick (no token,
        no advance, no cache-visible progress beyond an idempotent KV
        write) and self-deactivates, so the host can quarantine it
        without any rewind arithmetic. Plain tick readback: (2, B) int32
        ``[done, bad]``; spec tick: (3, B) ``[done, emitted, bad]`` —
        still ONE host readback per tick."""
        self._tick_fns: Dict[int, Any] = {}
        self._tick = self._tick_for(self._cur_spec_k)

    def _tick_for(self, k: int):
        fn = self._tick_fns.get(k)
        if fn is None:
            fn = jax.jit(self._make_tick_impl(k),
                         donate_argnums=self._donate())
            self._tick_fns[k] = fn
        return fn

    def _make_tick_impl(self, spec_k: int):
        cfg, scfg = self.cfg, self.scfg
        eos_id, max_len = scfg.eos_id, scfg.max_len
        paged = scfg.paged

        def tick(params, st: DeviceState, poison
                 ) -> Tuple[DeviceState, jnp.ndarray]:
            self.tick_trace_count += 1      # python side effect: trace count
            b = st.tok.shape[0]
            if paged:
                # dead/prefilling lanes' K/V writes go to the sink page —
                # their page-table rows may reference recycled pages
                logits1, caches = tf_lib.paged_decode_step(
                    params, cfg, st.tok[:, None], st.pos, st.page_table,
                    st.caches, active=st.active)
            else:
                logits1, caches = tf_lib.decode_step(
                    params, cfg, st.tok[:, None], st.pos, st.caches)
            logits = logits1[:, 0] + poison[:, None]        # (B, V) fp32
            # numerics sentinel: a non-finite logit row means this slot's
            # output can't be trusted — it makes NO progress this tick
            # (the KV write for st.tok is value-clean and idempotent: the
            # un-advanced pos means a healthy retry rewrites it) and
            # deactivates itself for the host to quarantine
            bad = st.active & ~jnp.all(jnp.isfinite(logits), axis=-1)
            ok = st.active & ~bad
            tok_new, rng_new = _sample(logits, st.rng, st.temp)
            tok_new = jnp.where(ok, tok_new, st.tok)
            rng_new = jnp.where(ok[:, None], rng_new, st.rng)
            rows = jnp.arange(b)
            widx = jnp.clip(st.gen, 0, st.out_buf.shape[1] - 1)
            out_buf = st.out_buf.at[rows, widx].set(
                jnp.where(ok, tok_new, st.out_buf[rows, widx]))
            gen_new = st.gen + ok
            pos_new = st.pos + ok
            hit_eos = ((tok_new == eos_id) if eos_id >= 0
                       else jnp.zeros_like(st.active))
            done = ok & (hit_eos | (gen_new >= st.budget)
                         | (pos_new >= max_len - 1))
            new_st = DeviceState(
                caches=caches, tok=tok_new, pos=pos_new, gen=gen_new,
                budget=st.budget, active=st.active & ~done & ~bad,
                temp=st.temp, rng=rng_new, out_buf=out_buf,
                page_table=st.page_table, hist=st.hist)
            packed = jnp.stack([done, bad]).astype(jnp.int32)
            return new_st, packed

        def spec_tick(params, st: DeviceState, poison
                      ) -> Tuple[DeviceState, jnp.ndarray]:
            """Speculative tick (DESIGN.md §15): draft k, verify all k in
            one multi-query pass, commit the accepted prefix + one
            correction/bonus token. Returns (state, (3, B) int32 packed
            [done, emitted, bad]) — still ONE host readback per tick."""
            self.tick_trace_count += 1
            b = st.tok.shape[0]
            k = spec_k
            active = st.active
            caches = st.caches
            if scfg.spec_drafter == "oracle":
                # the target model drafts itself greedily: k plain decode
                # passes. The verify rewrite of the same positions is
                # value-identical, so the combined tick stays idempotent.
                d_list = []
                tok_j, pos_j = st.tok, st.pos
                for _ in range(k):
                    lg, caches = tf_lib.paged_decode_step(
                        params, cfg, tok_j[:, None], pos_j, st.page_table,
                        caches, active=active)
                    nxt = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)
                    d_list.append(nxt)
                    tok_j = jnp.where(active, nxt, tok_j)
                    pos_j = pos_j + active
                drafts = jnp.stack(d_list, axis=1)          # (B, K)
            else:
                drafts = spec_lib.ngram_draft(st.hist, st.pos, k)
            chunk = jnp.concatenate([st.tok[:, None], drafts], axis=1)
            logits, caches = tf_lib.paged_verify_step(
                params, cfg, chunk, st.pos, st.page_table, caches,
                active=active)                              # (B, K+1, V)
            logits = logits + poison[:, None, None]
            # numerics sentinel over the whole verify block: NaN anywhere
            # in a slot's q-block (poison, or NaN KV attended through the
            # page table) voids ALL of its lanes this tick
            bad = active & ~jnp.all(jnp.isfinite(logits), axis=(1, 2))
            ok = active & ~bad
            n_acc, fix_tok, rng_new = spec_lib.speculative_accept(
                logits, drafts, st.rng, st.temp)
            rng_new = jnp.where(ok[:, None], rng_new, st.rng)
            # emission clamps: never exceed the token budget or the context
            # cap — exactly where the plain tick would have stopped
            rem = jnp.minimum(st.budget - st.gen, max_len - 1 - st.pos)
            n_emit = jnp.clip(jnp.minimum(n_acc + 1, rem), 1, k + 1)
            t_idx = jnp.arange(k + 1, dtype=jnp.int32)[None]    # (1, K+1)
            drafts_pad = jnp.concatenate(
                [drafts, jnp.zeros((b, 1), jnp.int32)], axis=1)
            emitted = jnp.where(t_idx < n_acc[:, None], drafts_pad,
                                fix_tok[:, None])               # (B, K+1)
            if eos_id >= 0:
                # an EOS anywhere in the emitted run truncates it there
                eos_lane = jnp.min(jnp.where(emitted == eos_id, t_idx,
                                             k + 1), axis=1)
                n_emit = jnp.minimum(n_emit, eos_lane + 1)
            lane = t_idx < n_emit[:, None]
            valid = lane & ok[:, None]
            rows2 = jnp.broadcast_to(jnp.arange(b)[:, None], (b, k + 1))
            cap = st.out_buf.shape[1]
            out_buf = st.out_buf.at[
                rows2, jnp.where(valid, st.gen[:, None] + t_idx, cap)
            ].set(emitted, mode="drop")
            hist = st.hist.at[
                rows2, jnp.where(valid, st.pos[:, None] + 1 + t_idx,
                                 st.hist.shape[1])
            ].set(emitted, mode="drop")
            n_step = jnp.where(ok, n_emit, 0)
            last = jnp.take_along_axis(
                emitted, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)[:, 0]
            tok_new = jnp.where(ok, last, st.tok)
            pos_new = st.pos + n_step
            gen_new = st.gen + n_step
            hit_eos = ((tok_new == eos_id) if eos_id >= 0
                       else jnp.zeros_like(active))
            done = ok & (hit_eos | (gen_new >= st.budget)
                         | (pos_new >= max_len - 1))
            new_st = DeviceState(
                caches=caches, tok=tok_new, pos=pos_new, gen=gen_new,
                budget=st.budget, active=active & ~done & ~bad,
                temp=st.temp, rng=rng_new, out_buf=out_buf,
                page_table=st.page_table, hist=hist)
            packed = jnp.stack([done.astype(jnp.int32), n_step,
                                bad.astype(jnp.int32)])
            return new_st, packed

        m = scfg.spec_tree_m

        def tree_tick(params, st: DeviceState, poison, btables, bvalid
                      ) -> Tuple[DeviceState, jnp.ndarray]:
            """Tree-speculative tick (DESIGN.md §18): draft ``m``
            independent k-token branches per slot, fold them into batch
            rows of ONE multi-query verify pass over COW-forked page
            tables, and commit the branch that accepts the longest
            prefix. Returns (state, (4, B) int32 packed
            [done, emitted, bad, winner]) — still ONE host readback."""
            self.tick_trace_count += 1
            b = st.tok.shape[0]
            k = spec_k
            active = st.active
            drafts = spec_lib.ngram_draft_tree(st.hist, st.pos, k, m)
            # branch 0 rides the slot's own table and temperature; extra
            # branches are valid only where the host staged pages AND the
            # slot is greedy
            valid = jnp.concatenate(
                [jnp.ones((b, 1), bool),
                 bvalid & (st.temp <= 0.0)[:, None]], axis=1)   # (B, M)
            tables = jnp.concatenate(
                [st.page_table[:, None], btables], axis=1)      # (B,M,NB)
            chunk = jnp.concatenate(
                [jnp.broadcast_to(st.tok[:, None, None], (b, m, 1)),
                 drafts], axis=2)                               # (B,M,K+1)
            act_f = (active[:, None] & valid).reshape(b * m)
            # branches fold into batch rows: row b*M + j carries branch
            # j's drafts over branch j's table — one weight stream scores
            # the whole tree (kernels/decode_attention.py)
            logits_f, caches = tf_lib.paged_verify_step(
                params, cfg, chunk.reshape(b * m, k + 1),
                jnp.broadcast_to(st.pos[:, None], (b, m)).reshape(b * m),
                tables.reshape(b * m, -1), st.caches, active=act_f)
            logits = (logits_f.reshape(b, m, k + 1, -1)
                      + poison[:, None, None, None])
            # sentinel: non-finite logits in ANY valid branch void the
            # slot's tick — poison and committed-KV corruption hit every
            # branch alike, and a partially-poisoned accept would be
            # unauditable
            fin = jnp.all(jnp.isfinite(logits), axis=(2, 3))    # (B, M)
            bad = active & jnp.any(valid & ~fin, axis=1)
            ok = active & ~bad
            # per-branch accept; extra branches run greedy (temp 0), and
            # branch 0 — the distribution-bearing lane — is the one whose
            # key advance the slot keeps (greedy lanes consume none)
            temp_f = jnp.concatenate(
                [st.temp[:, None],
                 jnp.zeros((b, m - 1), st.temp.dtype)], axis=1)
            keys_f = jnp.broadcast_to(st.rng[:, None], (b, m, 2))
            n_acc_f, fix_f, keys_new = spec_lib.speculative_accept(
                logits.reshape(b * m, k + 1, -1),
                drafts.reshape(b * m, k),
                keys_f.reshape(b * m, 2), temp_f.reshape(b * m))
            n_acc = n_acc_f.reshape(b, m)
            fix = fix_f.reshape(b, m)
            rng_new = keys_new.reshape(b, m, 2)[:, 0]
            rng_new = jnp.where(ok[:, None], rng_new, st.rng)
            rem = jnp.minimum(st.budget - st.gen, max_len - 1 - st.pos)
            n_emit = jnp.clip(jnp.minimum(n_acc + 1, rem[:, None]),
                              1, k + 1)                         # (B, M)
            t3 = jnp.arange(k + 1, dtype=jnp.int32)[None, None]  # (1,1,K+1)
            drafts_pad = jnp.concatenate(
                [drafts, jnp.zeros((b, m, 1), jnp.int32)], axis=2)
            emitted = jnp.where(t3 < n_acc[:, :, None], drafts_pad,
                                fix[:, :, None])                # (B,M,K+1)
            if eos_id >= 0:
                eos_lane = jnp.min(jnp.where(emitted == eos_id, t3,
                                             k + 1), axis=2)
                n_emit = jnp.minimum(n_emit, eos_lane + 1)
            # winner: the valid branch committing the most tokens; argmax
            # takes the FIRST max, so ties fall to branch 0 (the linear
            # stream — a tie-tick is bit-identical to spec_tick)
            n_eff = jnp.where(valid, n_emit, 0)
            w = jnp.argmax(n_eff, axis=1).astype(jnp.int32)     # (B,)
            emitted_w = jnp.take_along_axis(
                emitted, w[:, None, None], axis=1)[:, 0]        # (B, K+1)
            n_emit_w = jnp.take_along_axis(n_emit, w[:, None],
                                           axis=1)[:, 0]
            table_w = jnp.take_along_axis(tables, w[:, None, None],
                                          axis=1)[:, 0]         # (B, NB)
            t_idx = jnp.arange(k + 1, dtype=jnp.int32)[None]    # (1, K+1)
            lane = t_idx < n_emit_w[:, None]
            vmask = lane & ok[:, None]
            rows2 = jnp.broadcast_to(jnp.arange(b)[:, None], (b, k + 1))
            cap = st.out_buf.shape[1]
            out_buf = st.out_buf.at[
                rows2, jnp.where(vmask, st.gen[:, None] + t_idx, cap)
            ].set(emitted_w, mode="drop")
            hist = st.hist.at[
                rows2, jnp.where(vmask, st.pos[:, None] + 1 + t_idx,
                                 st.hist.shape[1])
            ].set(emitted_w, mode="drop")
            n_step = jnp.where(ok, n_emit_w, 0)
            last = jnp.take_along_axis(
                emitted_w, jnp.maximum(n_emit_w - 1, 0)[:, None],
                axis=1)[:, 0]
            tok_new = jnp.where(ok, last, st.tok)
            pos_new = st.pos + n_step
            gen_new = st.gen + n_step
            hit_eos = ((tok_new == eos_id) if eos_id >= 0
                       else jnp.zeros_like(active))
            done = ok & (hit_eos | (gen_new >= st.budget)
                         | (pos_new >= max_len - 1))
            # the winner's window pages become the slot's pages IN the
            # tick; the host mirrors the swap from the packed winner row
            page_table = jnp.where(ok[:, None], table_w, st.page_table)
            new_st = DeviceState(
                caches=caches, tok=tok_new, pos=pos_new, gen=gen_new,
                budget=st.budget, active=active & ~done & ~bad,
                temp=st.temp, rng=rng_new, out_buf=out_buf,
                page_table=page_table, hist=hist)
            packed = jnp.stack([done.astype(jnp.int32), n_step,
                                bad.astype(jnp.int32),
                                jnp.where(ok, w, 0)])
            return new_st, packed

        if spec_k > 0 and m > 1:
            return tree_tick
        return spec_tick if spec_k > 0 else tick

    def _build_admit(self):
        """Admission executable body. Dense: pad-and-stack prefill + all-slot
        scatter. Paged: page-table update + ``paged_extend`` over the current
        prefill chunks (suffix-after-prefix-hit and chunked admission share
        the one primitive). Either way compiled per length bucket
        (_bucket_len caps how many buckets exist); each bucket's executable
        is cached in ``_admit_fns`` and traced exactly once (asserted via
        ``admit_trace_counts`` in tests/test_serve_quant.py)."""
        if self.scfg.paged:
            self._admit_impl = self._make_extend_impl()
            return
        cfg, scfg = self.cfg, self.scfg
        base_key, max_len = self._base_key, scfg.max_len
        pad_ok = self._pad_ok

        def admit(params, st: DeviceState, toks, lens, slots, budgets, temps,
                  uids) -> Tuple[DeviceState, jnp.ndarray]:
            # one batched prefill over the padded prompt stack
            logits1, row_caches = tf_lib.prefill(
                params, cfg, toks, max_len=max_len,
                cache_dtype=scfg.cache_dtype,
                lengths=lens if pad_ok else None)
            logits = logits1[:, 0]                          # (N, V)
            keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
            tok0, rng0 = _sample(logits, keys, temps)
            # scatter ALL admitted slots' cache rows at once (invalid rows
            # carry out-of-bounds slot ids and drop)
            axes = _batch_axis_tree(st.caches)
            def ins(batched, row, ax):
                if ax == 0:
                    return batched.at[slots].set(
                        row.astype(batched.dtype), mode="drop")
                return batched.at[:, slots].set(
                    row.astype(batched.dtype), mode="drop")
            caches = jax.tree.map(ins, st.caches, row_caches, axes)
            cap = st.out_buf.shape[1]
            out_rows = jnp.zeros((tok0.shape[0], cap), jnp.int32
                                 ).at[:, 0].set(tok0)
            # a request can finish at prefill: max_tokens == 1, prompt at
            # the length cap (total context is capped at max_len), or the
            # very first sampled token being EOS
            done = (budgets <= 1) | (lens >= max_len - 1)
            if scfg.eos_id >= 0:
                done |= tok0 == scfg.eos_id
            new_st = DeviceState(
                caches=caches,
                tok=st.tok.at[slots].set(tok0, mode="drop"),
                pos=st.pos.at[slots].set(lens, mode="drop"),
                gen=st.gen.at[slots].set(1, mode="drop"),
                budget=st.budget.at[slots].set(budgets, mode="drop"),
                active=st.active.at[slots].set(~done, mode="drop"),
                temp=st.temp.at[slots].set(temps, mode="drop"),
                rng=st.rng.at[slots].set(rng0, mode="drop"),
                out_buf=st.out_buf.at[slots].set(out_rows, mode="drop"),
                page_table=st.page_table, hist=st.hist)
            return new_st, done

        self._admit_impl = admit

    def _make_extend_impl(self):
        """Paged admission body: one ``paged_extend`` call advances every
        in-flight prefill by one chunk. Rows whose prompt *ends* in this
        chunk (``final``) sample their first token and activate their slot;
        mid-chunk rows only record progress (``pos``) and stay inactive, so
        decode ticks interleave freely with long admissions."""
        cfg, scfg = self.cfg, self.scfg
        base_key, max_len = self._base_key, scfg.max_len

        def extend(params, st: DeviceState, toks, starts, lens, slots,
                   tables, budgets, temps, uids, final
                   ) -> Tuple[DeviceState, jnp.ndarray]:
            # ``tables`` is ROW-major (row j belongs to batch row j, sink-
            # filled for unused rows) — paged_extend indexes its table by
            # batch row, NOT by slot id; handing it the slot-major state
            # table would write through some *other* slot's pages whenever
            # rows and slots misalign. The persistent slot-major table is
            # updated separately (OOB slot ids drop).
            pt = st.page_table.at[slots].set(tables, mode="drop")
            logits1, caches = tf_lib.paged_extend(
                params, cfg, toks, starts, lens, tables, st.caches)
            logits = logits1[:, 0]                          # (N, V)
            keys = jax.vmap(lambda u: jax.random.fold_in(base_key, u))(uids)
            tok0, rng0 = _sample(logits, keys, temps)
            end = starts + lens
            done = final & ((budgets <= 1) | (end >= max_len - 1))
            if scfg.eos_id >= 0:
                done |= final & (tok0 == scfg.eos_id)
            cap = st.out_buf.shape[1]
            out_rows = jnp.zeros((tok0.shape[0], cap), jnp.int32
                                 ).at[:, 0].set(jnp.where(final, tok0, 0))
            hist = st.hist
            if hist.shape[1]:
                # speculative mode: mirror the chunk (and the first sampled
                # token of final rows) into the drafter's token history —
                # invalid lanes index out of bounds and drop
                n, width = toks.shape
                rel = jnp.arange(width, dtype=jnp.int32)[None]
                hrows = jnp.broadcast_to(slots[:, None], (n, width))
                hidx = jnp.where(rel < lens[:, None],
                                 starts[:, None] + rel, hist.shape[1])
                hist = hist.at[hrows, hidx].set(toks, mode="drop")
                hist = hist.at[
                    slots, jnp.where(final, end, hist.shape[1])
                ].set(tok0, mode="drop")
            new_st = DeviceState(
                caches=caches,
                tok=st.tok.at[slots].set(jnp.where(final, tok0, 0),
                                         mode="drop"),
                pos=st.pos.at[slots].set(end, mode="drop"),
                gen=st.gen.at[slots].set(jnp.where(final, 1, 0),
                                         mode="drop"),
                budget=st.budget.at[slots].set(budgets, mode="drop"),
                active=st.active.at[slots].set(final & ~done, mode="drop"),
                temp=st.temp.at[slots].set(temps, mode="drop"),
                rng=st.rng.at[slots].set(rng0, mode="drop"),
                out_buf=st.out_buf.at[slots].set(out_rows, mode="drop"),
                page_table=pt, hist=hist)
            return new_st, done

        return extend

    def _admit_exe(self, bucket: int):
        """One jitted admit/extend executable per length bucket, built on
        first use and reused for every later admission in that bucket — no
        per-call rebuild churn."""
        fn = self._admit_fns.get(bucket)
        if fn is None:
            impl = self._admit_impl

            def admit_b(params, st, *args):
                # python side effect: per-bucket trace count
                self.admit_trace_counts[bucket] = \
                    self.admit_trace_counts.get(bucket, 0) + 1
                return impl(params, st, *args)

            fn = jax.jit(admit_b, donate_argnums=self._donate())
            self._admit_fns[bucket] = fn
        return fn

    # -- queue API ------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16,
               temperature: Optional[float] = None,
               deadline_ticks: Optional[int] = None,
               n_best: int = 1) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size >= self.scfg.max_len:
            raise ValueError(f"prompt length {prompt.size} >= max_len "
                             f"{self.scfg.max_len}")
        if deadline_ticks is not None and deadline_ticks <= 0:
            raise ValueError(f"deadline_ticks must be > 0, got "
                             f"{deadline_ticks}")
        if n_best < 1:
            raise ValueError(f"n_best must be >= 1, got {n_best}")
        if n_best > 1 and not self.scfg.paged:
            raise ValueError("n-best sampling forks the paged KV cache "
                             "(DESIGN.md §18); set paged=True")
        if n_best > self.scfg.max_slots:
            raise ValueError(f"n_best ({n_best}) exceeds max_slots "
                             f"({self.scfg.max_slots}): every fork of one "
                             f"group decodes concurrently")
        if self.pool is not None:
            # a request whose worst-case page demand can never be met would
            # livelock admission (fits() false forever) — reject it here
            need = self._pages_needed_group(prompt.size, max_tokens, n_best)
            if need > self.pool.num_pages:
                raise ValueError(
                    f"request needs {need} pages (prompt {prompt.size} + "
                    f"max_tokens {max_tokens} x n_best {n_best}) but the "
                    f"pool has only {self.pool.num_pages}; raise num_pages "
                    f"or lower max_tokens")
        self._uid += 1
        if self._journal is not None and not self._replaying:
            # WAL contract (DESIGN.md §19): the admission is durable
            # (fsync'd) BEFORE it is acked — an acked request survives any
            # crash and replays from the journal
            nb = self._journal.append_submit(
                uid=self._uid, prompt=[int(t) for t in prompt.tolist()],
                max_tokens=max_tokens, temperature=temperature,
                deadline_ticks=deadline_ticks, n_best=n_best,
                tick=self._tick_idx)
            self.journal_bytes_total += nb
            if self.accountant is not None:
                self.accountant.observe_durability(journal_bytes=nb)
        self.scheduler.submit(Request(self._uid, prompt, max_tokens,
                                      temperature,
                                      deadline_ticks=deadline_ticks,
                                      submit_tick=self._tick_idx,
                                      n_best=n_best))
        return self._uid

    @property
    def queue(self):
        return self.scheduler.pending

    # -- host readback helpers ------------------------------------------------

    def _readback(self, x) -> np.ndarray:
        """Every device->host transfer goes through here (counted: the tick
        hot path must do exactly one — the finished mask)."""
        self.host_readbacks += 1
        return np.asarray(x)

    def _checked_readback(self, x, validate, tick: int) -> np.ndarray:
        """Tick readback with transport-fault detection: the injector may
        drop or garble the host copy, and a real edge deployment's DMA can
        too. ``validate`` knows the packed layout's value domain; a failed
        check re-reads the (unchanged, non-donated) device buffer up to
        ``guard.readback_max_retries`` times before giving up loudly."""
        attempt = 0
        while True:
            arr = self._readback(x)
            if self._injector is not None:
                arr = self._injector.filter_readback(arr, tick, attempt)
            if arr is not None and validate(arr):
                return arr
            attempt += 1
            if attempt > self.guard.readback_max_retries:
                raise RuntimeError(
                    f"tick {tick}: readback failed validation "
                    f"{attempt} times")
            self.readback_retries_total += 1
            self._rb_retries_tick += 1

    @staticmethod
    def _validate_plain_packed(arr: np.ndarray) -> bool:
        return (arr.ndim == 2 and arr.shape[0] == 2
                and bool(np.isin(arr, (0, 1)).all()))

    def _validate_spec_packed(self, arr: np.ndarray) -> bool:
        if arr.ndim != 2 or arr.shape[0] != 3:
            return False
        flags_ok = bool(np.isin(arr[(0, 2), :], (0, 1)).all())
        emit_ok = bool(((arr[1] >= 0)
                        & (arr[1] <= self._cur_spec_k + 1)).all())
        return flags_ok and emit_ok

    def _validate_tree_packed(self, arr: np.ndarray) -> bool:
        if arr.ndim != 2 or arr.shape[0] != 4:
            return False
        flags_ok = bool(np.isin(arr[(0, 2), :], (0, 1)).all())
        emit_ok = bool(((arr[1] >= 0)
                        & (arr[1] <= self._cur_spec_k + 1)).all())
        win_ok = bool(((arr[3] >= 0)
                       & (arr[3] < self.scfg.spec_tree_m)).all())
        return flags_ok and emit_ok and win_ok

    # -- chaos tier: fault application + recovery (DESIGN.md §17) -------------

    def _apply_host_faults(self, tick: int) -> None:
        """Inject this tick's host-side fault events (device-side logit
        poison rides the tick's poison argument instead). Runs before the
        decode tick so the injected state is what the tick observes."""
        inj = self._injector
        # spike holds expire on schedule regardless of new events
        keep = []
        for expires, pages in self._spike_holds:
            if tick >= expires and self.pool is not None:
                self.pool.release_all(pages)
            else:
                keep.append((expires, pages))
        self._spike_holds = keep
        if inj is None:
            return
        stall = inj.stall_seconds(tick)
        if stall > 0.0:
            time.sleep(stall)
        for ev in inj.events_for(tick):
            if ev.kind == "pool_spike" and self.pool is not None:
                n = min(int(ev.magnitude), self.pool.available)
                if n > 0:
                    held = self.pool.alloc(n)
                    if held is not None:
                        self._spike_holds.append(
                            (tick + max(ev.duration, 1), held))
                        inj.count("pool_spike")
            elif ev.kind == "kv_bitflip" and self.scfg.paged:
                self._inject_kv_bitflip(ev)
            elif ev.kind == "process_kill":
                # simulated process death (DESIGN.md §19): the exception
                # propagates out of step() — recovery is restore(), not
                # any in-tick rung. A kill at or before the restore
                # boundary is the crash a restore already recovered from
                # and must not re-fire during or after replay.
                if ev.tick > self._restore_boundary:
                    inj.count("process_kill")
                    raise ProcessKilled(
                        f"process_kill fault at tick {tick}: engine "
                        f"state is gone; restart from checkpoint_dir "
                        f"via ServeEngine.restore()")

    def _inject_kv_bitflip(self, ev) -> None:
        """Corrupt one K page of a decoding slot — inside its attended
        window, so the sentinel (not luck) must catch it. Restricted to
        decoding slots: a mid-prefill slot's extend readback carries no
        ``bad`` lane, and its poisoned logits would go unobserved."""
        ps = self.scfg.page_size
        victims = [i for i, r in enumerate(self.slot_req)
                   if r is not None and i not in self._prefilling]
        if ev.slot in victims:
            victims = [ev.slot]
        for slot in victims:
            pages = self._slot_pages[slot]
            req = self.slot_req[slot]
            n_live = -(-(len(req.prompt) + self._host_gen[slot]) // ps)
            lo = self.pool.movable_suffix(pages)
            cand = [p for j, p in enumerate(pages)
                    if lo <= j < n_live]
            if not cand:
                continue
            self.state = dataclasses.replace(
                self.state,
                caches=corrupt_kv_page(self.state.caches, cand[0]))
            self._injector.count("kv_bitflip")
            return

    def _scrub_slot_storage(self, slot: int) -> None:
        """Zero the K/V storage a quarantined slot may have poisoned. A bad
        tick writes its non-finite activations into the slot's PRIVATE
        pages (every layer past the first NaN attention output projects
        NaN K/V), and a NaN *V* entry leaks through masked attention —
        softmax gives the masked position probability 0, but 0 * NaN is
        NaN — so a freed-then-recycled page would poison its next owner.
        Scrubbing on teardown restores the invariant the allocator relies
        on: free storage is benign garbage (zeros), never NaN. Shared
        prefix pages are immutable-clean by construction and are skipped;
        this is a rare-path device call, not tick work."""
        if self.pool is not None:
            pages = self._slot_pages[slot]
            lo = self.pool.movable_suffix(pages)
            if not pages[lo:]:
                return
            self._scrub_pages(pages[lo:])
        else:
            self._scrub_sel(jnp.asarray([slot], jnp.int32))

    def _scrub_pages(self, pages: List[int]) -> None:
        """Zero a set of pool pages about to be freed — same invariant as
        ``_scrub_slot_storage`` (free storage is never NaN), reachable for
        page lists that belong to no slot (a quarantined slot's ephemeral
        tree-branch windows, DESIGN.md §18)."""
        if pages:
            self._scrub_sel(jnp.asarray(pages, jnp.int32))

    def _scrub_sel(self, sel: jnp.ndarray) -> None:
        caches = {}
        for name, entry in self.state.caches.items():
            e2 = dict(entry)
            for key in ("kv", "kv_scale"):
                if key not in entry:
                    continue
                kv = entry[key]
                # pattern pools stack the layer dim first; tails are flat.
                # The dense layout (B where the paged pool has P) scrubs
                # the slot's whole cache row with the same indexing.
                ax = ((slice(None), sel) if name.startswith("pat")
                      else (sel,))
                e2[key] = dataclasses.replace(
                    kv, k=kv.k.at[ax].set(0), v=kv.v.at[ax].set(0))
            caches[name] = e2
        self.state = dataclasses.replace(self.state, caches=caches)

    def _capture_slot(self, slot: int) -> Request:
        """Freeze a live slot into a continuation request carrying its
        committed progress: prompt = original prompt + valid generated
        tokens, budget = remaining tokens. The original prompt/budget park
        in ``_recovery[uid]`` and are restored at finish, so the caller
        sees one seamless stream. Re-prefilling the continuation IS the
        fp32-oracle re-decode on fp engines (prefill == greedy decode
        parity, DESIGN.md §14) — and its energy is billed as recovery."""
        req = self.slot_req[slot]
        g = self._host_gen[slot]
        toks = ([int(t) for t in self._readback(self.state.out_buf[slot, :g])]
                if g > 0 else [])
        rec = self._recovery.setdefault(
            req.uid, {"prompt": req.prompt, "max_tokens": req.max_tokens,
                      "tokens": []})
        rec["tokens"].extend(toks)
        cont = Request(
            req.uid,
            np.concatenate([np.asarray(rec["prompt"], np.int32),
                            np.asarray(rec["tokens"], np.int32)]),
            max_tokens=max(rec["max_tokens"] - len(rec["tokens"]), 1),
            temperature=req.temperature,
            deadline_ticks=req.deadline_ticks,
            submit_tick=self._tick_idx,
            # a captured fork member stays a member (its finish banks into
            # the group), but never re-forks (n_best stays 1)
            fork_group=req.fork_group, fork_idx=req.fork_idx)
        self._recovering.add(req.uid)
        # teardown mirrors: the slot is free next tick (the device side
        # already deactivated it, or the runtime is being rebuilt)
        self.slot_req[slot] = None
        self._host_gen[slot] = 0
        self._prefilling.pop(slot, None)
        self._fork_wait.pop(slot, None)
        kids = self._fork_children.pop(slot, None)
        if kids is not None:
            # children reserved but never forked (parent captured
            # mid-prefill, e.g. by the fp fallback): requeue them as
            # independent admissions — their streams still bank into the
            # group, only the sharing is lost
            requeue = []
            for kid in kids:
                child = self.slot_req[kid]
                self.slot_req[kid] = None
                self._fork_wait.pop(kid, None)
                if child is not None:
                    child.submit_tick = self._tick_idx
                    requeue.append(child)
            self.scheduler.requeue_front(requeue)
        self._scrub_slot_storage(slot)
        if self.pool is not None and self._slot_pages[slot]:
            # release WITHOUT publishing: pages of a faulted slot may hold
            # corrupt KV; freeing them unkeyed means they are rewritten
            # before any future lookup can hit them
            self.pool.release_all(self._slot_pages[slot])
            self._slot_pages[slot] = []
        return cont

    def _quarantine_slot(self, slot: int) -> None:
        """Sentinel hit: tear the slot down and requeue its continuation
        head-of-line. The slot made no progress on the bad tick, so the
        continuation resumes exactly at the last committed token."""
        cont = self._capture_slot(slot)
        self.scheduler.requeue_front([cont])
        self.n_quarantined += 1
        self._tick_quarantined += 1

    def _shed_request(self, req: Request, finished: List[Request]) -> None:
        """Fail a request fast (deadline expiry / admission-retry
        exhaustion): it completes with whatever tokens recovery already
        banked — never silently vanishes."""
        rec = self._recovery.pop(req.uid, None)
        if rec is not None:
            req.prompt = rec["prompt"]
            req.max_tokens = rec["max_tokens"]
            req.generated = list(rec["tokens"])
        else:
            req.generated = []
        self._recovering.discard(req.uid)
        self._defer_counts.pop(req.uid, None)
        self._retry_after.pop(req.uid, None)
        self._fit_checked.discard(req.uid)
        req.done = True
        if req.fork_group is not None:
            # a shed fork member still reports: the group must close
            self._record_fork_stream(req.fork_group, req.fork_idx,
                                     req.generated, finished)
        elif req.n_best > 1:
            # shed before admission ever forked (no group exists): the
            # caller still sees an n-best-shaped result
            req.nbest = [list(req.generated) for _ in range(req.n_best)]
            finished.append(req)
        else:
            finished.append(req)
        self.n_shed += 1
        self._tick_shed += 1

    def _finish_slot(self, slot: int, finished: List[Request]) -> None:
        req = self.slot_req[slot]
        n = self._host_gen[slot]
        toks = self._readback(self.state.out_buf[slot, :n])
        req.generated = [int(t) for t in toks]
        req.done = True
        self.slot_req[slot] = None
        self._host_gen[slot] = 0
        if self.pool is not None and self._slot_pages[slot]:
            pages = self._slot_pages[slot]
            if self.scfg.prefix_cache and n > 0:
                # publish the finished stream's full, frozen blocks —
                # prompt AND committed generation — BEFORE releasing.
                # Order matters: release_all frees unpublished pages to
                # the free list, so publishing afterwards would certify
                # recyclable pages; and without this step the stream's
                # last exactly-full block (grown during decode) was never
                # reusable as a prefix. The cache holds positions
                # [0, prompt + n - 1): the final generated token is the
                # pending one whose K/V never landed. A recovering slot's
                # "prompt" here is the continuation prompt (original +
                # recovered tokens), which is exactly the stream content —
                # publishing under it stays correct.
                cached = np.concatenate(
                    [np.asarray(req.prompt, np.int64),
                     np.asarray(toks[:n - 1], np.int64)])
                parent = ROOT
                for bi, block in enumerate(
                        block_tokens(cached, self.scfg.page_size)):
                    if bi >= len(pages):
                        break
                    parent = self.pool.publish(pages[bi], parent, block)
            # published blocks park in the pool's LRU (still hittable);
            # private pages free immediately
            self.pool.release_all(pages)
            self._slot_pages[slot] = []
        # recovery merge LAST: restore the original prompt/budget and stitch
        # the recovered tokens in front of this leg's output — the caller
        # sees one uninterrupted stream
        rec = self._recovery.pop(req.uid, None)
        if rec is not None:
            req.prompt = rec["prompt"]
            req.max_tokens = rec["max_tokens"]
            req.generated = list(rec["tokens"]) + req.generated
            self._recovering.discard(req.uid)
        if req.fork_group is not None:
            # fork-group member (DESIGN.md §18): the stream banks into the
            # group; the caller receives the PARENT request once every
            # fork has reported
            self._record_fork_stream(req.fork_group, req.fork_idx,
                                     req.generated, finished)
        else:
            finished.append(req)
        self.n_finished_ok += 1

    # -- admission ------------------------------------------------------------

    def _admit(self, finished: List[Request]) -> "_AdmitInfo":
        if self.scfg.paged:
            return self._admit_paged(finished)
        return self._admit_dense(finished)

    def _admit_dense(self, finished: List[Request]) -> "_AdmitInfo":
        """Batched dense admission: ONE padded prefill + all-slot scatter."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        reqs = self.scheduler.select(len(free), now=self._tick_idx)
        if not reqs:
            return _AdmitInfo()
        if not self._pad_ok:
            # SSD/hybrid archs: only equal-length prompts share a prefill
            same = [r for r in reqs if len(r.prompt) == len(reqs[0].prompt)]
            self.scheduler.requeue_front([r for r in reqs if r not in same])
            reqs = same
        nslots = self.scfg.max_slots
        # SSD path runs prefill without per-row lengths, so the stack width
        # must equal the (shared) true prompt length — no bucket padding.
        # The bucket is capped at max_len: a wider stack would push prefill
        # into its ring branch and silently drop the oldest prompt tokens.
        lmax = (_bucket_len(max(len(r.prompt) for r in reqs),
                            cap=self.scfg.max_len)
                if self._pad_ok else len(reqs[0].prompt))
        n = len(reqs)
        toks = np.zeros((nslots, lmax), np.int32)
        lens = np.zeros(nslots, np.int32)
        slots = np.full(nslots, nslots + 1, np.int32)   # OOB rows drop
        budgets = np.ones(nslots, np.int32)
        temps = np.zeros(nslots, np.float32)
        uids = np.zeros(nslots, np.int32)
        for j, req in enumerate(reqs):
            sl = len(req.prompt)
            toks[j, :sl] = req.prompt
            lens[j] = sl
            slots[j] = free[j]
            budgets[j] = req.max_tokens
            temps[j] = (self.scfg.temperature if req.temperature is None
                        else req.temperature)
            uids[j] = req.uid
        self.state, done = self._admit_exe(lmax)(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(slots), jnp.asarray(budgets), jnp.asarray(temps),
            jnp.asarray(uids))
        done_mask = self._readback(done)
        for j, req in enumerate(reqs):
            self.slot_req[free[j]] = req
            self._host_gen[free[j]] = 1
            if done_mask[j]:
                self._finish_slot(free[j], finished)
        toks_n = int(lens.sum())
        sq = int((lens.astype(np.int64) ** 2).sum())
        # recovery billing: dense prefill is single-shot, so a recovering
        # continuation bills its whole prompt here (start = 0)
        rec_tok, rec_fl, rec_by = 0, 0.0, 0.0
        for req in reqs:
            if req.uid in self._recovering:
                plen = len(req.prompt)
                rec_tok += plen
                rec_fl += costing.prefill_span_flops(
                    self._matmul_elems, self._n_attn, self._attn_dims,
                    0, plen)
                rec_by += self.kv_cache_bytes / self.scfg.max_slots
                self._recovering.discard(req.uid)
        return _AdmitInfo(
            admitted=len(reqs), prefill_tokens=toks_n, weight_passes=1,
            kv_bytes=self.kv_cache_bytes * len(reqs) / self.scfg.max_slots,
            flops=(2.0 * self._matmul_elems * toks_n
                   + 2.0 * self._n_attn * self._attn_dims * sq),
            recovery_tokens=rec_tok, recovery_flops=rec_fl,
            recovery_bytes=rec_by)

    # -- page-table compaction (DESIGN.md §16) --------------------------------

    def _build_compact(self):
        """One jitted device call per compaction: copy the moved pages in
        every layer's pool and rewrite the slot's page-table row, donated
        like the tick. ``src``/``dst`` are padded to ``blocks_per_slot``
        with sink->sink identity copies so a single executable serves
        every move count."""
        def compact(state: DeviceState, src, dst, slot, row):
            self.compact_trace_count += 1   # python side effect: trace count
            caches = tf_lib.move_pages(state.caches, src, dst)
            pt = state.page_table.at[slot].set(row)
            return dataclasses.replace(state, caches=caches, page_table=pt)
        self._compact_exe = jax.jit(compact, donate_argnums=(0,))

    def _maybe_compact(self) -> int:
        """Defragment at most ONE slot's private page suffix per tick
        (bounds tick-time work). A slot qualifies when it is decoding (not
        mid-prefill — its table is rewritten per chunk anyway), its table
        fragmentation reaches the threshold, its movable suffix (refcount
        1, unpublished — serve/pages.py:movable_suffix; shared prefix
        blocks are pinned) is itself scattered, and a contiguous free run
        exists. Returns pages moved. Because a slot's page list is fixed
        at admission, a compacted slot stays compact for its lifetime."""
        thr = self.scfg.compact_threshold
        if thr <= 0.0 or not self.scfg.paged:
            return 0
        # latency-pressure rung (DESIGN.md §17): a tick-stall trigger
        # pauses the (deferrable) defragmentation work for a window
        if self._tick_idx < self._compact_pause_until:
            return 0
        nb, sink = self._blocks_per_slot, self.pool.sink
        for slot, req in enumerate(self.slot_req):
            if req is None or slot in self._prefilling:
                continue
            pages = self._slot_pages[slot]
            if len(pages) < 2 or fragmentation(pages) < thr:
                continue
            lo = self.pool.movable_suffix(pages)
            movable = pages[lo:]
            if len(movable) < 2 or fragmentation(movable) == 0.0:
                continue
            run = self.pool.alloc_run(len(movable))
            if run is None:             # no contiguous free run: next tick
                continue
            src = np.full(nb, sink, np.int32)
            dst = np.full(nb, sink, np.int32)
            src[:len(movable)] = movable
            dst[:len(movable)] = run
            new_pages = pages[:lo] + run
            row = new_pages + [sink] * (nb - len(new_pages))
            self.state = self._compact_exe(
                self.state, jnp.asarray(src), jnp.asarray(dst),
                jnp.int32(slot), jnp.asarray(row[:nb], dtype=jnp.int32))
            self.pool.release_all(movable)  # private + unkeyed -> free list
            self._slot_pages[slot] = new_pages
            return len(movable)
        return 0

    # -- copy-on-write forks (DESIGN.md §18) ----------------------------------

    def _build_cow(self):
        """One jitted device call per COW/boundary-copy batch: copy the
        listed pages in every layer's pool and redirect the owning slots'
        page-table entries, donated like the tick. Events are padded to a
        pow2 bucket with sink->sink identity copies (OOB slot ids drop the
        table write), so a handful of executables serves every batch
        size."""
        def cow(state: DeviceState, src, dst, slot_idx, blk_idx, entry):
            self.cow_trace_count += 1   # python side effect: trace count
            caches, pt = tf_lib.cow_pages(
                state.caches, state.page_table, src, dst, slot_idx,
                blk_idx, entry)
            return dataclasses.replace(state, caches=caches, page_table=pt)
        self._cow_exe = jax.jit(cow, donate_argnums=(0,))

    def _cow_call(self, events: List[Tuple[int, int, int, int, int]]
                  ) -> None:
        """Apply a batch of ``(src, dst, slot, blk, entry)`` page events in
        ONE device call. ``src == dst == sink`` rows update only the table
        (a retain-only redirect); OOB slot rows copy only the page (an
        ephemeral branch window that lives outside any slot's table)."""
        n = _bucket_len(len(events))
        sink = self.pool.sink
        nslots = self.scfg.max_slots
        src = np.full(n, sink, np.int32)
        dst = np.full(n, sink, np.int32)
        sl = np.full(n, nslots + 1, np.int32)
        bl = np.zeros(n, np.int32)
        en = np.full(n, sink, np.int32)
        for j, (s, d, slot, blk, entry) in enumerate(events):
            src[j], dst[j], sl[j], bl[j], en[j] = s, d, slot, blk, entry
        self.state = self._cow_exe(
            self.state, jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(sl), jnp.asarray(bl), jnp.asarray(en))

    def _build_fork(self):
        """One jitted device call per fork group activation: broadcast the
        parent's slot row (pending token, position, budget, output ring,
        drafter history) to every child slot, install each child's own
        page table and PRNG key. No cache bytes move — the children READ
        the shared prompt pages through their tables; that is the whole
        point. ``dsts`` is padded to max_slots with OOB ids (dropped)."""
        def fork(state: DeviceState, src, dsts, tables, rngs):
            self.fork_trace_count += 1  # python side effect: trace count
            f = dsts.shape[0]
            def row(x):
                return x.at[dsts].set(
                    jnp.broadcast_to(x[src], (f,) + x.shape[1:]),
                    mode="drop")
            return DeviceState(
                caches=state.caches,
                tok=row(state.tok), pos=row(state.pos),
                gen=row(state.gen), budget=row(state.budget),
                active=row(state.active), temp=row(state.temp),
                rng=state.rng.at[dsts].set(rngs, mode="drop"),
                out_buf=row(state.out_buf),
                page_table=state.page_table.at[dsts].set(tables,
                                                         mode="drop"),
                hist=row(state.hist))
        self._fork_exe = jax.jit(fork, donate_argnums=(0,))

    def _fork_slots(self, parent_slot: int, kids: List[int]) -> None:
        """Activate a fork group (DESIGN.md §18): retain the parent's
        committed prompt pages into each child's table (no bytes move),
        give each child a private decode tail, and copy the parent's slot
        row to every child in ONE jitted call. A child whose tail
        allocation loses a pool race is requeued as an independent
        admission — its stream still banks into the group, only the
        sharing is lost."""
        scfg = self.scfg
        ps = scfg.page_size
        nslots, nb = scfg.max_slots, self._blocks_per_slot
        parent = self.slot_req[parent_slot]
        plen = len(parent.prompt)
        pages = self._slot_pages[parent_slot]
        # blocks holding committed prompt KV (the last may be partial —
        # shared under COW, diverging writers copy it at the barrier)
        n_shared = -(-plen // ps)
        tail = len(pages) - n_shared
        dsts, tables, rngs, requeue = [], [], [], []
        for kid in kids:
            child = self.slot_req[kid]
            self._fork_wait.pop(kid, None)
            shared = self.pool.fork(pages[:n_shared])
            fresh = self.pool.alloc(tail)
            if fresh is None:
                self.pool.release_all(shared)
                self.slot_req[kid] = None
                child.submit_tick = self._tick_idx
                requeue.append(child)
                continue
            kid_pages = shared + fresh
            self._slot_pages[kid] = kid_pages
            self._host_gen[kid] = 1
            row = kid_pages + [self.pool.sink] * (nb - len(kid_pages))
            dsts.append(kid)
            tables.append(row[:nb])
            rngs.append(np.asarray(
                jax.random.fold_in(self._base_key, child.uid)))
            # the duplicate-KV bill this fork did NOT pay: an independent
            # admission of the same stream would re-prefill the prompt
            self._tick_forks += 1
            self._tick_fork_saved_bytes += self._kv_token_bytes * plen
            self._tick_fork_saved_flops += costing.prefill_span_flops(
                self._matmul_elems, self._n_attn, self._attn_dims,
                0, plen)
        if requeue:
            self.scheduler.requeue_front(requeue)
        if not dsts:
            return
        d = np.full(nslots, nslots + 1, np.int32)
        t = np.full((nslots, nb), self.pool.sink, np.int32)
        r = np.zeros((nslots, 2), np.uint32)
        d[:len(dsts)] = dsts
        t[:len(dsts)] = tables
        r[:len(dsts)] = rngs
        self.state = self._fork_exe(
            self.state, jnp.int32(parent_slot), jnp.asarray(d),
            jnp.asarray(t), jnp.asarray(r))

    def _cancel_fork(self, parent_slot: int, kids: List[int]) -> None:
        """The parent finished AT activation (budget 1 / EOS on its first
        token): every fork would replay the identical one-token stream, so
        the reserved child slots free and the group banks mirror streams
        resolved against stream 0 when the parent's finish records it."""
        gid = self.slot_req[parent_slot].fork_group
        g = self._fork_groups.get(gid)
        for kid in kids:
            child = self.slot_req[kid]
            self.slot_req[kid] = None
            self._fork_wait.pop(kid, None)
            if g is not None and child is not None:
                g["streams"][child.fork_idx] = _FORK_MIRROR

    def _record_fork_stream(self, gid: int, idx: int, toks: List[int],
                            finished: List[Request]) -> None:
        """Bank one fork's finished stream into its group; once every fork
        has reported, the PARENT request completes with ``nbest`` holding
        all streams in fork order (``generated`` aliases stream 0)."""
        g = self._fork_groups.get(gid)
        if g is None:       # defensive: a stray continuation after a shed
            return
        g["streams"][idx] = toks
        s = g["streams"]
        if len(s) < g["k"]:
            return
        base = s.get(0, [])
        streams = [list(base) if s.get(i, []) is _FORK_MIRROR
                   else list(s.get(i, [])) for i in range(g["k"])]
        parent = g["req"]
        parent.nbest = streams
        parent.generated = streams[0]
        parent.done = True
        del self._fork_groups[gid]
        finished.append(parent)

    def _cow_barrier(self, active: List[int]) -> List[int]:
        """Pre-tick write barrier (DESIGN.md §18): every page the coming
        tick may write — the blocks covering positions
        ``[pos, pos + spec_k]`` per decoding slot — must be PRIVATE to its
        slot. A shared (forked) or published page copies first
        (``PagePool.cow_write``), billed as COW traffic; pages without
        committed content redirect table-only. A pool-exhausted copy
        quarantines its slot (with its device lane force-deactivated so
        the tick cannot touch the shared page) rather than corrupt its
        siblings' streams. Returns the surviving active list."""
        ps = self.scfg.page_size
        k = self._cur_spec_k
        events: List[Tuple[int, int, int, int, int]] = []
        drop: List[int] = []
        for slot in active:
            req = self.slot_req[slot]
            pages = self._slot_pages[slot]
            pos = len(req.prompt) + self._host_gen[slot] - 1
            wlo = pos // ps
            whi = min((pos + k) // ps, len(pages) - 1)
            for blk in range(wlo, whi + 1):
                p = pages[blk]
                if self.pool.writable(p):
                    continue
                res = self.pool.cow_write(p)
                if res is None:
                    drop.append(slot)
                    break
                new, copied = res
                pages[blk] = new
                if copied:
                    self._tick_cow_copies += 1
                    self._tick_cow_bytes += (2.0 * ps
                                             * self._kv_token_bytes)
                # committed content below pos copies; later blocks hold
                # nothing yet, so only the table entry moves
                has_content = blk * ps < pos
                events.append((p if has_content else self.pool.sink,
                               new if has_content else self.pool.sink,
                               slot, blk, new))
        for slot in drop:
            # deactivate the device lane BEFORE teardown: without this the
            # tick would still write the page its siblings share
            self.state = dataclasses.replace(
                self.state,
                active=self.state.active.at[slot].set(False))
            self._quarantine_slot(slot)
        if drop:
            events = [e for e in events if e[2] not in drop]
            active = [s for s in active if s not in drop]
        if events:
            self._cow_call(events)
        return active

    def _prepare_tree(self, active: List[int]
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Stage this tick's ephemeral branch windows (DESIGN.md §18): for
        each greedy decoding slot, each of the ``spec_tree_m - 1`` extra
        branches gets private copies of the write-window blocks in a
        forked table row. Only the boundary block holds committed KV (the
        COW barrier just privatized it), so at most one page copies per
        branch — billed as COW traffic. A pool race drops that branch lane
        (``bvalid`` False) and the slot's tick degrades to the linear
        branch-0 path. Returns the device ``(btables, bvalid)`` tick
        arguments; the staged pages park in ``_tree_branches`` for
        ``_commit_tree``."""
        scfg = self.scfg
        m, k, ps = scfg.spec_tree_m, self._cur_spec_k, scfg.page_size
        nslots, nb = scfg.max_slots, self._blocks_per_slot
        sink = self.pool.sink
        btables = np.full((nslots, m - 1, nb), sink, np.int32)
        bvalid = np.zeros((nslots, m - 1), bool)
        self._tree_branches = {}
        events: List[Tuple[int, int, int, int, int]] = []
        for slot in active:
            req = self.slot_req[slot]
            temp = (scfg.temperature if req.temperature is None
                    else req.temperature)
            if temp > 0.0:
                # temperature slots keep the distribution-exact linear
                # path on branch 0 (multi-branch rejection sampling would
                # need a joint residual scheme to stay unbiased)
                continue
            pages = self._slot_pages[slot]
            pos = len(req.prompt) + self._host_gen[slot] - 1
            wlo = pos // ps
            whi = min((pos + k) // ps, len(pages) - 1)
            width = whi - wlo + 1
            row = pages + [sink] * (nb - len(pages))
            branches: List[Optional[List[int]]] = []
            for i in range(m - 1):
                bp = self.pool.alloc(width)
                branches.append(bp)
                if bp is None:
                    continue
                brow = list(row[:nb])
                brow[wlo:whi + 1] = bp
                btables[slot, i] = brow
                bvalid[slot, i] = True
                if pos - wlo * ps > 0:
                    # the boundary block holds committed KV the branch
                    # must attend through its own table: copy it (OOB
                    # slot id — no table row owns branch pages)
                    events.append((pages[wlo], bp[0], nslots + 1, 0,
                                   sink))
                    self._tick_cow_copies += 1
                    self._tick_cow_bytes += (2.0 * ps
                                             * self._kv_token_bytes)
            self._tree_branches[slot] = (wlo, whi, branches)
        if events:
            self._cow_call(events)
        return jnp.asarray(btables), jnp.asarray(bvalid)

    def _commit_tree(self, bad_mask: np.ndarray, winners: np.ndarray
                     ) -> None:
        """Resolve this tick's staged branches from the packed winner row:
        the winning branch's window pages are adopted into the slot's page
        list (the device table already switched inside the tick), the
        replaced window pages (private + unpublished, per the barrier)
        free immediately, and every losing branch releases. A
        sentinel-flagged slot adopts nothing; its branch pages are
        scrubbed before release (the bad verify pass wrote non-finite KV
        into them, and free storage must never be NaN)."""
        for slot, (wlo, whi, branches) in self._tree_branches.items():
            w = int(winners[slot])
            bad = bool(bad_mask[slot])
            for i, bp in enumerate(branches):
                if bp is None:
                    continue
                if not bad and w == i + 1:
                    pages = self._slot_pages[slot]
                    old = pages[wlo:whi + 1]
                    pages[wlo:whi + 1] = bp
                    self.pool.release_all(old)
                else:
                    if bad:
                        self._scrub_pages(bp)
                    self.pool.release_all(bp)
        self._tree_branches = {}

    # -- paged admission (DESIGN.md §14) --------------------------------------

    def _pages_needed(self, prompt_len: int, max_tokens: int) -> int:
        """Worst-case (no-hit) page demand of a request: its full possible
        context, prompt + budget, capped at max_len. Speculative mode books
        ``spec_k`` extra tokens — a verify tick transiently writes up to k
        draft positions past the committed length, and booking them keeps
        those writes in the slot's own (private, masked-out) pages instead
        of colliding in the shared sink page."""
        ctx = min(prompt_len + max_tokens + self.scfg.spec_k,
                  self.scfg.max_len)
        return -(-ctx // self.scfg.page_size)

    def _tree_extra(self) -> int:
        """Per-slot *transient* page demand of tree speculation (DESIGN.md
        §18): each of the ``spec_tree_m - 1`` extra branches claims a
        private copy of the write window for one tick — at most
        ``(ps - 1 + k) // ps + 1`` pages, the worst alignment of a
        k+1-token span. Booked by the admission gate (so steady-state
        ticks can stage their branches) but never attached to a slot;
        a pool race at staging time degrades that slot's tick to the
        linear branch-0 path instead of failing."""
        scfg = self.scfg
        if scfg.spec_tree_m <= 1:
            return 0
        ps = scfg.page_size
        return (scfg.spec_tree_m - 1) * ((ps - 1 + scfg.spec_k) // ps + 1)

    def _pages_needed_group(self, prompt_len: int, max_tokens: int,
                            n_best: int) -> int:
        """Worst-case page demand of an ``n_best``-way fork group: the
        parent's full demand plus, per child, a private decode tail (the
        blocks past the shared committed prompt) and one COW copy of the
        partial boundary block. ``prompt_len // ps`` is exactly the shared
        FULL blocks — the partial boundary block is shared at fork time but
        each diverging writer (except the last, which owns it outright)
        pays one copy, so it counts against every child. Tree mode adds
        each decoding slot's transient branch windows on top."""
        need = self._pages_needed(prompt_len, max_tokens)
        shared_full = prompt_len // self.scfg.page_size
        return (need + (n_best - 1) * (need - shared_full)
                + n_best * self._tree_extra())

    def _defer_admission(self, req: Request, hits: List[int], n_hit0: int,
                         n_blocks: int, rest: List[Request]) -> None:
        """The one deferral path for a selected-but-unallocatable paged
        admission: release the retained hit pages, roll back the lookup's
        stats booking (the retry re-runs lookup — without the unbook each
        deferral would double-count its hits/misses and inflate
        ``PoolStats.hit_rate``), and requeue head-of-line.

        Backpressure rung (DESIGN.md §17): with ``guard.admit_max_retries``
        set, each deferral of the same uid counts; past the cap the request
        is shed (failed fast) instead of retried, and with
        ``guard.admit_backoff`` set the retry is additionally delayed by an
        exponentially growing tick window — a pool-exhaustion spike stops
        burning a full select+lookup per tick on a request that cannot fit."""
        self.pool.release_all(hits)
        self.pool.unbook_lookup(n_hit0, n_blocks)
        guard = self.guard
        n = self._defer_counts.get(req.uid, 0) + 1
        self._defer_counts[req.uid] = n
        if guard.admit_max_retries > 0 and n > guard.admit_max_retries:
            self._defer_counts.pop(req.uid, None)
            self._retry_after.pop(req.uid, None)
            self._pending_shed.append(req)
            self.scheduler.requeue_front(rest)
            return
        if guard.admit_backoff > 0:
            delay = min(guard.admit_backoff * 2 ** (n - 1), 32)
            self._retry_after[req.uid] = self._tick_idx + delay
        self.scheduler.requeue_front([req] + rest)

    def _admit_paged(self, finished: List[Request]) -> "_AdmitInfo":
        """Paged admission tick: select new requests that fit the pool,
        look up their prefix blocks, allocate suffix+decode pages, then
        advance EVERY in-flight prefill (new and continuing) by one chunk
        in a single ``paged_extend`` call. With ``prefill_chunk == 0`` the
        whole suffix lands in one call (the dense-equivalent behaviour,
        minus the shared prefix); with a chunk size, per-tick prefill work
        is bounded by ``max_slots * prefill_chunk`` tokens regardless of
        prompt length — the tick-time tail-latency bound."""
        scfg = self.scfg
        ps = scfg.page_size
        nslots, nb = scfg.max_slots, self._blocks_per_slot
        # never-fittable guard: a queued request whose worst-case demand
        # exceeds the whole pool can never be admitted (fits() false
        # forever -> FIFO head-of-line livelock). submit() rejects these,
        # but requests can reach the queue directly (scheduler.submit) or
        # predate a config that raised the demand (spec_k) — fail them
        # fast, with no stats booked (they never ran a lookup). The
        # verdict per request is immutable, so it is computed once per
        # uid (the memo is pruned at admission, bounding it to queue
        # depth).
        def never_fits(r: Request) -> bool:
            if r.uid in self._fit_checked:
                return False
            self._fit_checked.add(r.uid)
            return (self._pages_needed_group(len(r.prompt), r.max_tokens,
                                             r.n_best)
                    > self.pool.num_pages)

        for req in self.scheduler.drop(never_fits):
            self._fit_checked.discard(req.uid)
            req.done = True
            req.generated = []
            if req.n_best > 1:
                req.nbest = [[] for _ in range(req.n_best)]
            finished.append(req)
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        budget_pages = [self.pool.available]
        budget_slots = [len(free)]

        def fits(req: Request) -> bool:
            # backoff gate (DESIGN.md §17): a deferred request sits out its
            # retry window before consuming any page budget
            if self._retry_after.get(req.uid, 0) > self._tick_idx:
                return False
            # conservative: ignores hits (submit() guarantees need can be
            # met by an empty pool, so deferral always terminates). A
            # non-fitting request is NOT looked up — deferral by this gate
            # books no prefix stats to roll back. An n-best request books
            # its WHOLE fork group here — n_best slots and the group's
            # worst-case pages — so the fork at activation can only fail
            # under a later cross-tick pool race (DESIGN.md §18).
            need = self._pages_needed_group(len(req.prompt),
                                            req.max_tokens, req.n_best)
            if need > budget_pages[0] or req.n_best > budget_slots[0]:
                return False
            budget_pages[0] -= need
            budget_slots[0] -= req.n_best
            return True

        reqs = self.scheduler.select(len(free), fits=fits,
                                     now=self._tick_idx)
        admitted = len(reqs)
        hit_tokens = 0
        hit_sq = 0.0
        # slots assign from a pool, not positionally: an n-best parent
        # consumes its own slot PLUS one reserved slot per child
        slot_pool = list(free)
        for j, req in enumerate(reqs):
            self._fit_checked.discard(req.uid)
            slot = slot_pool[0]
            plen = len(req.prompt)
            blocks = (block_tokens(req.prompt, ps)
                      if scfg.prefix_cache else [])
            hits = self.pool.lookup(blocks)
            n_hit0 = len(hits)
            # at least one suffix token must run to produce the sampling
            # logits, so a fully cached prompt re-computes its last block
            while hits and len(hits) * ps >= plen:
                self.pool.release(hits.pop())
            shared = len(hits) * ps
            fresh = self.pool.alloc(
                self._pages_needed(plen, req.max_tokens) - len(hits))
            if fresh is None:       # estimate raced capacity: defer
                self._defer_admission(req, hits, n_hit0, len(blocks),
                                      reqs[j + 1:])
                admitted = j
                break
            pages = hits + fresh
            # admission succeeded: clear any backpressure bookkeeping
            self._defer_counts.pop(req.uid, None)
            self._retry_after.pop(req.uid, None)
            slot_pool.pop(0)
            self.slot_req[slot] = req
            self._slot_pages[slot] = pages
            self._prefilling[slot] = {
                "req": req, "plen": plen, "next": shared,
                "blocks": blocks, "pages": pages}
            if req.n_best > 1:
                # mint + reserve the fork children NOW (one per extra
                # stream): they hold slots — excluded from decode via
                # _fork_wait — until the parent's final chunk activates
                # and _fork_slots fans the committed pages out
                req.fork_group = req.uid
                self._fork_groups[req.uid] = {
                    "req": req, "k": req.n_best, "streams": {}}
                req.fork_idx = 0
                kids = [slot_pool.pop(0) for _ in range(req.n_best - 1)]
                self._fork_children[slot] = kids
                for i, kid in enumerate(kids):
                    self._uid += 1
                    child = Request(
                        self._uid, req.prompt, req.max_tokens,
                        req.temperature, fork_group=req.uid,
                        fork_idx=i + 1, submit_tick=self._tick_idx)
                    self.slot_req[kid] = child
                    self._fork_wait[kid] = slot
            hit_tokens += shared
            hit_sq += float(shared) ** 2
        # one extend call advances every in-flight prefill by one chunk
        work = sorted(self._prefilling.items())
        if not work:
            return _AdmitInfo(admitted=admitted,
                              prefix_hit_tokens=hit_tokens)
        # even with chunking off, cap the implicit chunk at the chunked-
        # SDPA threshold: extend's attention materializes O(C * window)
        # fp32 logits per layer, and dense prefill bounds the same blow-up
        # by switching to sdpa_q_chunked at this width
        from repro.models.layers import _CHUNKED_SDPA_THRESHOLD
        chunk_cap = scfg.prefill_chunk or min(scfg.max_len,
                                              _CHUNKED_SDPA_THRESHOLD)
        call_lens = [min(w["plen"] - w["next"], chunk_cap)
                     for _, w in work]
        # every call_len <= chunk_cap, so the bucket always covers them
        width = _bucket_len(max(call_lens), cap=chunk_cap)
        toks = np.zeros((nslots, width), np.int32)
        starts = np.zeros(nslots, np.int32)
        lens = np.zeros(nslots, np.int32)
        slots = np.full(nslots, nslots + 1, np.int32)   # OOB rows drop
        # row-major page tables for this call; unused rows write to sink
        tables = np.full((nslots, nb), self.pool.sink, np.int32)
        budgets = np.ones(nslots, np.int32)
        temps = np.zeros(nslots, np.float32)
        uids = np.zeros(nslots, np.int32)
        final = np.zeros(nslots, bool)
        for j, ((slot, w), clen) in enumerate(zip(work, call_lens)):
            req = w["req"]
            toks[j, :clen] = req.prompt[w["next"]:w["next"] + clen]
            starts[j] = w["next"]
            lens[j] = clen
            slots[j] = slot
            budgets[j] = req.max_tokens
            temps[j] = (scfg.temperature if req.temperature is None
                        else req.temperature)
            uids[j] = req.uid
            final[j] = w["next"] + clen >= w["plen"]
            row = w["pages"] + [self.pool.sink] * (nb - len(w["pages"]))
            tables[j] = row[:nb]
        self.state, done = self._admit_exe(width)(
            self.params, self.state, jnp.asarray(toks), jnp.asarray(starts),
            jnp.asarray(lens), jnp.asarray(slots), jnp.asarray(tables),
            jnp.asarray(budgets), jnp.asarray(temps), jnp.asarray(uids),
            jnp.asarray(final))
        done_mask = self._readback(done)
        computed = int(lens.sum())
        # causal-attention FLOPs of the chunk: sum over rows of
        # end^2 - start^2 (the start=0 case reduces to the dense bill)
        ends = (starts + lens).astype(np.int64)
        attn_sq = float((ends ** 2 - starts.astype(np.int64) ** 2).sum())
        # recovery billing (DESIGN.md §17): rows re-prefilling a
        # quarantined/fallback continuation bill their share of this call
        # separately — the energy a fault-free run never spends. Same
        # formulas as the aggregate bill below, factored per row.
        rec_tok, rec_fl, rec_by = 0, 0.0, 0.0
        for j, ((slot, w), clen) in enumerate(zip(work, call_lens)):
            uid = w["req"].uid
            if uid in self._recovering:
                rec_tok += clen
                rec_fl += costing.prefill_span_flops(
                    self._matmul_elems, self._n_attn, self._attn_dims,
                    int(starts[j]), clen)
                row_gather = (-(-int(starts[j]) // ps) * ps
                              if self.cfg.decode_kernel else nb * ps)
                rec_by += self._kv_token_bytes * (row_gather + clen)
                if final[j]:
                    self._recovering.discard(uid)
        for j, ((slot, w), clen) in enumerate(zip(work, call_lens)):
            if final[j]:
                del self._prefilling[slot]
                self._host_gen[slot] = 1
                # publish the prompt's full, now-frozen blocks for reuse,
                # chaining each key through the CANONICAL page publish()
                # returns — two slots computing the same prefix in the same
                # tick must converge on one chain, not register a shadow
                # chain no lookup can reach
                if scfg.prefix_cache:
                    parent = ROOT
                    for bi, block in enumerate(w["blocks"]):
                        parent = self.pool.publish(w["pages"][bi], parent,
                                                   block)
                kids = self._fork_children.pop(slot, None)
                if done_mask[j]:
                    if kids is not None:
                        self._cancel_fork(slot, kids)
                    self._finish_slot(slot, finished)
                elif kids is not None:
                    # the parent's prompt KV is committed and its first
                    # token sampled: fan the group out (DESIGN.md §18)
                    self._fork_slots(slot, kids)
            else:
                w["next"] += clen
        # cached-window gather bill (DESIGN.md §16) — what the extend path
        # ACTUALLY moves to read KV behind the chunk, not the logical
        # window. Kernel path: the page-table index_map clamps dead steps,
        # so each row fetches exactly ceil(start / page_size) pages. XLA
        # fallback: _paged_gather materializes the FULL table width for
        # every slot row, per call — the fragmented-prefill under-billing
        # this field exists to correct.
        if self.cfg.decode_kernel:
            gather_tokens = float(sum(-(-int(s) // ps) * ps
                                      for s in starts[:len(work)]))
        else:
            gather_tokens = float(nslots * nb * ps)
        gather_bytes = self._kv_token_bytes * gather_tokens
        return _AdmitInfo(
            admitted=admitted, prefill_tokens=computed, weight_passes=1,
            prefix_hit_tokens=hit_tokens,
            # extend reads the cached window behind each chunk (the gather
            # bill above) and writes the chunk's KV — page-granular
            kv_bytes=gather_bytes + self._kv_token_bytes * computed,
            gather_bytes=gather_bytes,
            flops=(2.0 * self._matmul_elems * computed
                   + 2.0 * self._n_attn * self._attn_dims * attn_sq),
            saved_bytes=self._kv_token_bytes * hit_tokens,
            saved_flops=(2.0 * self._matmul_elems * hit_tokens
                         + 2.0 * self._n_attn * self._attn_dims * hit_sq),
            recovery_tokens=rec_tok, recovery_flops=rec_fl,
            recovery_bytes=rec_by)

    # -- degradation ladder rungs (DESIGN.md §17) -----------------------------

    def _maybe_spec_backoff(self, accepted: int, n_ok: int) -> None:
        """Acceptance-collapse rung: EWMA the per-tick draft acceptance
        rate; when it sinks below the threshold, halve spec-k (its own
        cached executable — no retrace of healthy k). Never re-escalates
        within a run: flapping between executables would churn compiles."""
        guard = self.guard
        if guard.spec_backoff_threshold <= 0.0 or self._cur_spec_k <= 1:
            return
        if n_ok <= 0:
            return
        self._accept_ewma.update(accepted / float(self._cur_spec_k * n_ok))
        if (self._accept_ewma.n >= guard.spec_backoff_window
                and self._accept_ewma.value < guard.spec_backoff_threshold):
            self._cur_spec_k = max(1, self._cur_spec_k // 2)
            self._tick = self._tick_for(self._cur_spec_k)
            self.spec_backoffs += 1
            self._accept_ewma = Ewma(alpha=guard.ewma_alpha)

    def _maybe_pause_compaction(self, wall_s: float) -> None:
        """Latency-pressure rung: EWMA the tick wall time; a tick slower
        than ``stall_factor`` x the smoothed baseline pauses compaction
        (the one deferrable chunk of tick work) for a recovery window."""
        guard = self.guard
        prev = self._tick_wall_ewma.value
        seen = self._tick_wall_ewma.n
        self._tick_wall_ewma.update(wall_s)
        if guard.stall_factor <= 0.0 or seen < 3 or prev is None:
            return
        if (wall_s > guard.stall_factor * prev
                and self._tick_idx + 1 >= self._compact_pause_until):
            self._compact_pause_until = (self._tick_idx + 1
                                         + guard.compact_pause_ticks)
            self.compaction_pauses += 1

    def _drift_check(self) -> None:
        """Quantization-drift rung: every ``drift_check_interval`` ticks,
        replay ONE decoding greedy slot's next-token prediction through the
        fp32 oracle (teacher-forced prefill of prompt + committed tokens
        minus the last) and compare argmax to what the engine emitted —
        the serve-time sibling of quality.token_agreement. Disagreement
        EWMA above ``drift_threshold`` triggers the fp fallback."""
        guard = self.guard
        cands = [i for i, r in enumerate(self.slot_req)
                 if r is not None and i not in self._prefilling
                 and self._host_gen[i] >= 2
                 and (r.temperature if r.temperature is not None
                      else self.scfg.temperature) == 0.0]
        if not cands:
            return
        slot = cands[self._drift_rr % len(cands)]
        self._drift_rr += 1
        req = self.slot_req[slot]
        g = self._host_gen[slot]
        toks = self._readback(self.state.out_buf[slot, :g])
        o_params, o_cfg = self._oracle
        seq = np.concatenate([np.asarray(req.prompt, np.int32),
                              toks[:-1].astype(np.int32)])
        lg, _ = tf_lib.prefill(o_params, o_cfg, jnp.asarray(seq[None]),
                               cache_dtype=jnp.float32)
        want = int(jnp.argmax(lg[0, -1]))
        self._drift_ewma.update(0.0 if want == int(toks[-1]) else 1.0)
        if (self._drift_ewma.n >= guard.drift_min_checks
                and self._drift_ewma.value > guard.drift_threshold):
            self._fallback_to_fp()

    def _fallback_to_fp(self) -> None:
        """int8 -> fp fallback: capture every live slot as a continuation,
        requeue them head-of-line, and rebuild the whole runtime (pool,
        caches, executables) from the fp oracle params. A heavy, one-way
        rung — quantization drift means every future token is suspect."""
        if self._fell_back:
            return
        conts: List[Request] = []
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            conts.append(self._capture_slot(slot))
        self.scheduler.requeue_front(conts)
        self.fp_fallbacks += 1
        self._fell_back = True
        self._drift_ewma = Ewma(alpha=self.guard.ewma_alpha)
        self._init_runtime(*self._oracle)

    def _run_audit(self) -> None:
        """Page-pool integrity audit: the pool's own invariants plus the
        engine-side ownership reconciliation (every page's refcount equals
        its appearances across slot page lists and injector spike holds;
        no page listed twice by one slot). Violations are recorded, never
        raised — detection must not be the crash."""
        violations = self.pool.audit()
        violations += reconcile_ownership(self.pool, self._slot_pages,
                                          self._spike_holds)
        if violations:
            self.audit_failures += len(violations)
            self.audit_log.extend(
                f"tick {self._tick_idx}: {v}" for v in violations)

    # -- main tick ------------------------------------------------------------

    def step(self) -> List[Request]:
        """Admit + one fused decode tick. Returns finished requests."""
        t0 = time.monotonic()
        tick = self._tick_idx
        finished: List[Request] = []
        self._tick_shed = 0
        self._tick_quarantined = 0
        self._rb_retries_tick = 0
        self._tick_cow_bytes = 0.0
        self._tick_cow_copies = 0
        self._tick_forks = 0
        self._tick_fork_saved_bytes = 0.0
        self._tick_fork_saved_flops = 0.0
        inj0 = (self._injector.faults_injected
                if self._injector is not None else 0)
        # deadline shedding (DESIGN.md §17): expire queued requests whose
        # wait exceeded their deadline BEFORE spending admission work on
        # them — they complete failed-fast, never silently vanish
        for req in self.scheduler.drop(
                lambda r: (r.deadline_ticks is not None
                           and r.submit_tick >= 0
                           and tick - r.submit_tick > r.deadline_ticks)):
            self._shed_request(req, finished)
        # host-side fault events land before admission so a pool spike
        # pressures THIS tick's admission and a KV flip is what the decode
        # tick observes
        self._apply_host_faults(tick)
        adm = self._admit(finished)
        # admission-retry exhaustion sheds, queued by _defer_admission
        for req in self._pending_shed:
            self._shed_request(req, finished)
        self._pending_shed = []
        moves = self._maybe_compact() if self.scfg.paged else 0
        # decoding slots only: mid-prefill paged slots and fork-reserved
        # child slots occupy a slot but don't produce decode tokens until
        # their final chunk / their parent's activation releases them
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and i not in self._prefilling
                  and i not in self._fork_wait]
        if self.scfg.paged and active:
            # COW write barrier (DESIGN.md §18): every page this tick
            # writes must be private to its slot BEFORE the tick runs
            active = self._cow_barrier(active)
        # live context per decoding slot: the tick attends lengths pos+1 =
        # prompt + generated-so-far — captured before finishes clear the
        # slot (page-granular KV read bill)
        ctx = sum(len(self.slot_req[i].prompt) + self._host_gen[i]
                  for i in active) if self.scfg.paged else 0
        spec_k = self._cur_spec_k
        emitted = len(active)       # decode tokens this tick (plain: 1/slot)
        accepted = 0
        n_bad = 0
        if active:
            poison = self._zero_poison
            if self._injector is not None:
                pv = self._injector.logit_poison(tick, active,
                                                 self.scfg.max_slots)
                if pv is not None:
                    poison = jnp.asarray(pv)
            if spec_k > 0 and self.scfg.spec_tree_m > 1:
                # tree speculation (DESIGN.md §18): stage per-branch
                # forked windows, run the folded verify, then resolve the
                # winner's page adoption on the host
                btables, bvalid = self._prepare_tree(active)
                self.state, packed = self._tick(self.params, self.state,
                                                poison, btables, bvalid)
                arr = self._checked_readback(
                    packed, self._validate_tree_packed, tick)
                done_mask = arr[0].astype(bool)
                n_emit = arr[1]
                bad_mask = arr[2].astype(bool)
                self._commit_tree(bad_mask, arr[3])
                emitted = int(n_emit.sum())
                accepted = int(np.maximum(n_emit - 1, 0).sum())
                for i in active:
                    self._host_gen[i] += int(n_emit[i])
            elif spec_k > 0:
                self.state, packed = self._tick(self.params, self.state,
                                                poison)
                # the ONLY hot-path transfer (validated: the injector may
                # garble/drop it, and the device buffer survives a re-read)
                arr = self._checked_readback(
                    packed, self._validate_spec_packed, tick)
                done_mask = arr[0].astype(bool)
                n_emit = arr[1]
                bad_mask = arr[2].astype(bool)
                emitted = int(n_emit.sum())
                accepted = int(np.maximum(n_emit - 1, 0).sum())
                for i in active:
                    self._host_gen[i] += int(n_emit[i])
            else:
                self.state, packed = self._tick(self.params, self.state,
                                                poison)
                arr = self._checked_readback(
                    packed, self._validate_plain_packed, tick)
                done_mask = arr[0].astype(bool)
                bad_mask = arr[1].astype(bool)
                for i in active:
                    if not bad_mask[i]:
                        self._host_gen[i] += 1
                emitted = int(sum(1 for i in active if not bad_mask[i]))
            for i in np.nonzero(done_mask)[0]:
                if (self.slot_req[int(i)] is not None
                        and int(i) not in self._prefilling):
                    self._finish_slot(int(i), finished)
            # sentinel-flagged slots made no progress and self-deactivated
            # on device — quarantine them: teardown + head-of-line
            # continuation. Unaffected slots' streams are untouched.
            n_bad = int(sum(1 for i in active if bad_mask[i]))
            for i in np.nonzero(bad_mask)[0]:
                if (self.slot_req[int(i)] is not None
                        and int(i) not in self._prefilling):
                    self._quarantine_slot(int(i))
            if spec_k > 0:
                self._maybe_spec_backoff(accepted, len(active) - n_bad)
        # modeled traffic/compute of the tick (DESIGN.md §12/§14/§15):
        # every jitted call streams the full weight tree once; the dense
        # decode reads the whole resident KV payload, while the paged
        # decode reads only the active slots' live context (page-granular)
        # — admission terms come pre-computed from the admit path. The
        # speculative tick bills its draft and verify phases separately:
        # the drafter's cost depends on the drafter (n-gram: one history
        # scan; oracle: k more weight streams), the verify pass streams
        # the weights ONCE for k+1 positions per slot — the amortization
        # the whole design exists for.
        wb = kvb = fl = 0.0
        d_fl = d_by = v_fl = v_by = 0.0
        na = len(active)
        if active:
            if spec_k > 0:
                width = spec_k + 1
                oracle = self.scfg.spec_drafter == "oracle"
                # tree mode folds m branch rows per slot into the one
                # verify pass: m x the row compute and KV traffic, still
                # ONE weight stream — the fold's whole economy
                m_eff = self.scfg.spec_tree_m
                v_fl = costing.spec_verify_flops(
                    self._matmul_elems, self._n_attn, self._attn_dims,
                    ctx * m_eff, na * m_eff, width)
                # verify: one weight stream; KV = live context read once
                # plus the chunk's write+readback (page-granular)
                v_kv = self._kv_token_bytes * (ctx
                                               + 2.0 * width * na) * m_eff
                v_by = self.weight_bytes + v_kv
                if oracle:
                    d_fl = costing.spec_oracle_draft_flops(
                        self._matmul_elems, self._n_attn, self._attn_dims,
                        ctx, na, spec_k)
                    d_kv = self._kv_token_bytes * (
                        spec_k * ctx + na * spec_k * (spec_k - 1) / 2.0)
                    d_wb = spec_k * self.weight_bytes
                else:
                    # n-gram drafter: one int32 history scan per slot
                    # (tree mode emits m branches from the same scan —
                    # bill the extra gather lanes, still no weights)
                    d_kv = 4.0 * self.scfg.max_len * na * m_eff
                    d_wb = 0.0
                d_by = d_wb + d_kv
                wb += self.weight_bytes + d_wb
                kvb += v_kv + d_kv
                fl += v_fl + d_fl
            else:
                wb += self.weight_bytes
                if self.scfg.paged:
                    kvb += self._kv_token_bytes * ctx
                    fl += costing.decode_tick_flops(
                        self._matmul_elems, self._n_attn, self._attn_dims,
                        ctx, na)
                else:
                    kvb += self.kv_cache_bytes
                    fl += na * (2.0 * self._matmul_elems
                                + 4.0 * self._n_attn * self._attn_dims
                                * self.scfg.max_len)
        if adm.weight_passes:
            wb += self.weight_bytes * adm.weight_passes
        kvb += adm.kv_bytes
        fl += adm.flops
        if moves:
            # each relocated page is one pool read + one pool write
            kvb += 2.0 * moves * self.scfg.page_size * self._kv_token_bytes
        # COW copies (barrier + tree boundary copies) are real page
        # traffic: already accumulated per event, billed into kv_bytes AND
        # broken out first-class (DESIGN.md §18)
        kvb += self._tick_cow_bytes
        # periodic detection rungs (rare paths; their readbacks/compute are
        # off the hot tick and bounded by their intervals)
        guard = self.guard
        if (guard.drift_check_interval > 0 and not self._fell_back
                and self.scfg.quant == "int8"
                and tick % guard.drift_check_interval == 0):
            self._drift_check()
        if (guard.audit_interval > 0 and self.scfg.paged
                and tick % guard.audit_interval == 0):
            self._run_audit()
        degraded = int(self._cur_spec_k != self.scfg.spec_k
                       or self._fell_back
                       or tick < self._compact_pause_until)
        wall = time.monotonic() - t0
        self._maybe_pause_compaction(wall)
        m = StepMetrics(tokens=emitted, active_slots=na,
                        wall_s=wall,
                        prefill_tokens=adm.prefill_tokens,
                        admitted=adm.admitted,
                        queue_depth=len(self.scheduler),
                        weight_bytes=wb, kv_bytes=kvb, flops=fl,
                        prefix_hit_tokens=adm.prefix_hit_tokens,
                        saved_bytes=adm.saved_bytes,
                        saved_flops=adm.saved_flops,
                        spec_draft_tokens=spec_k * na,
                        spec_accepted_tokens=accepted,
                        draft_flops=d_fl, draft_bytes=d_by,
                        verify_flops=v_fl, verify_bytes=v_by,
                        prefill_gather_bytes=adm.gather_bytes,
                        compaction_moves=moves,
                        faults_injected=(
                            self._injector.faults_injected - inj0
                            if self._injector is not None else 0),
                        quarantined=self._tick_quarantined,
                        shed=self._tick_shed,
                        recovery_tokens=adm.recovery_tokens,
                        recovery_flops=adm.recovery_flops,
                        recovery_bytes=adm.recovery_bytes,
                        degraded=degraded,
                        readback_retries=self._rb_retries_tick,
                        cow_bytes=self._tick_cow_bytes,
                        cow_copies=self._tick_cow_copies,
                        forks=self._tick_forks,
                        fork_saved_bytes=self._tick_fork_saved_bytes,
                        fork_saved_flops=self._tick_fork_saved_flops)
        self.last_metrics = m
        self.metrics_log.append(m)
        if self.accountant is not None:
            self.accountant.observe_serve(m)
        self._tick_idx += 1
        if self._replaying:
            # replayed recompute is physically honest work already billed
            # via observe_serve above — restore_j breaks the SAME joules
            # out as the recovery-cost channel (DESIGN.md §19), so the
            # checkpoint-interval J/token tradeoff is first-class
            self.replayed_ticks += 1
            self.restore_flops += m.flops
            self.restore_bytes += m.bytes_moved
            if self.accountant is not None:
                self.accountant.observe_durability(
                    restore_flops=m.flops, restore_bytes=m.bytes_moved,
                    replayed_ticks=1)
        elif self._journal is not None:
            # tick record first (replay needs every tick, even idle ones:
            # fault schedules and deadlines key on absolute tick index),
            # THEN the snapshot — its journal_seq cut must sit after this
            # tick's record so replay resumes exactly at tick_idx
            d_journal = self._journal.append_tick(
                tick=tick,
                finished=[[r.uid,
                           [int(t) for t in r.generated],
                           ([[int(t) for t in s] for s in r.nbest]
                            if r.nbest is not None else None)]
                          for r in finished])
            self.journal_bytes_total += d_journal
            d_snapshot = 0
            if (self.scfg.checkpoint_interval > 0
                    and self._tick_idx % self.scfg.checkpoint_interval
                    == 0):
                d_snapshot = self._write_snapshot()
            if self.accountant is not None:
                self.accountant.observe_durability(
                    journal_bytes=d_journal, snapshot_bytes=d_snapshot,
                    snapshots=1 if d_snapshot else 0)
        return finished

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not len(self.scheduler) and all(r is None
                                               for r in self.slot_req):
                break
        return done

    # -- durability: crash-consistent snapshot + journal replay ---------------

    def _write_snapshot(self) -> int:
        """Persist a crash-consistent checkpoint of the full engine:
        the device tree (caches, page table, positions, RNG keys) plus the
        complete host mirror (snapshot.host_state_dict) ride one atomic
        CheckpointManager save. ``journal_seq`` marks the replay cut:
        journal records with seq below it are baked into this snapshot;
        restore replays everything at or after it. Returns bytes written
        (billed as durability DRAM traffic)."""
        step = self._tick_idx
        extra = host_state_dict(self)
        extra["journal_seq"] = self._journal.seq
        self._ckpt_mgr.save(step, self.state, extra=extra)
        d = self._ckpt_mgr._step_dir(step)
        nbytes = sum(os.path.getsize(os.path.join(d, f))
                     for f in os.listdir(d))
        self.snapshots_taken += 1
        self.snapshot_bytes_total += nbytes
        return nbytes

    def restore(self) -> List[Request]:
        """Warm restart from disk (DESIGN.md §19): load the latest
        snapshot (if any), then deterministically replay the journal tail.
        Must be called on a FRESH engine built with the same ServeConfig
        and the same ``checkpoint_dir`` as the dead one. Replayed ticks
        repeat the original run bit-identically (seeded RNG folds, sorted
        host iteration, seeded fault plans) — divergence or a corrupted
        snapshot fails loudly rather than serving wrong streams.

        Returns every request finished up to now — both pre-crash
        finishes reconstructed from the journal and finishes produced by
        replay. Delivery is at-least-once: callers that already streamed
        pre-crash results dedupe by uid."""
        if self._ckpt_mgr is None or self._journal is None:
            raise RuntimeError("restore() requires checkpoint_dir")
        if self._tick_idx != 0 or self.metrics_log or len(self.scheduler):
            raise RuntimeError("restore() must run on a fresh engine — "
                               "this one has already ticked or admitted")
        journal_seq = 0
        step = self._ckpt_mgr.latest_step()
        if step is not None:
            extra = self._ckpt_mgr.peek_extra(step)
            # config gate FIRST: a snapshot from a differently-configured
            # engine must be diagnosed as such, not as a shape mismatch
            # halfway through loading the device tree
            check_fingerprint(self.scfg, extra.get("fingerprint", {}))
            if extra.get("fell_back"):
                # the snapshot's device tree is fp — rebuild the runtime
                # from the fp oracle BEFORE restoring so dtypes line up
                self._fell_back = True
                self._init_runtime(*self._oracle)
            _, tree, extra = self._ckpt_mgr.restore(step,
                                                    target=self.state)
            self.state = tree
            install_host_state(self, extra)
            journal_seq = int(extra.get("journal_seq", 0))
            if self.scfg.paged:
                # snapshot-load shares the audit's reconciliation checker
                # (DESIGN.md §19) — but HERE violations refuse, loudly:
                # restoring inconsistent ownership would corrupt streams
                violations = self.pool.audit()
                violations += reconcile_ownership(
                    self.pool, self._slot_pages, self._spike_holds)
                if violations:
                    raise RuntimeError(
                        "snapshot failed consistency check: "
                        + "; ".join(violations))
            self._tick = self._tick_for(self._cur_spec_k)
        recovered: List[Request] = []
        submits: Dict[int, dict] = {}
        post: List[dict] = []
        for rec in self._journal.records():
            if rec["kind"] == "submit":
                submits[int(rec["uid"])] = rec
            if rec["seq"] < journal_seq:
                if rec["kind"] == "tick":
                    # pre-snapshot finishes: reconstruct the completed
                    # requests so the caller sees every result exactly as
                    # the dead engine emitted it
                    for uid, gen, nbest in rec["finished"]:
                        s = submits[int(uid)]
                        recovered.append(Request(
                            int(uid),
                            np.asarray(s["prompt"], np.int32),
                            max_tokens=int(s["max_tokens"]),
                            temperature=s["temperature"],
                            generated=[int(t) for t in gen],
                            done=True,
                            deadline_ticks=s["deadline_ticks"],
                            submit_tick=int(s["tick"]),
                            n_best=int(s["n_best"]),
                            nbest=([[int(t) for t in st] for st in nbest]
                                   if nbest is not None else None)))
            else:
                post.append(rec)
        self._replaying = True
        try:
            for rec in post:
                if rec["kind"] == "submit":
                    uid = self.submit(
                        np.asarray(rec["prompt"], np.int32),
                        max_tokens=int(rec["max_tokens"]),
                        temperature=rec["temperature"],
                        deadline_ticks=rec["deadline_ticks"],
                        n_best=int(rec["n_best"]))
                    if uid != int(rec["uid"]):
                        raise RuntimeError(
                            f"replay diverged: journaled submit uid "
                            f"{rec['uid']}, replay assigned {uid}")
                else:
                    if int(rec["tick"]) != self._tick_idx:
                        raise RuntimeError(
                            f"replay diverged: journal at tick "
                            f"{rec['tick']}, engine at {self._tick_idx}")
                    fins = self.step()
                    got = {int(r.uid): ([int(t) for t in r.generated],
                                        ([[int(t) for t in st]
                                          for st in r.nbest]
                                         if r.nbest is not None else None))
                           for r in fins}
                    want = {int(u): ([int(t) for t in g],
                                     ([[int(t) for t in st] for st in nb]
                                      if nb is not None else None))
                            for u, g, nb in rec["finished"]}
                    if got != want:
                        raise RuntimeError(
                            f"replay diverged at tick {rec['tick']}: "
                            f"journaled finishes {sorted(want)} vs "
                            f"replayed {sorted(got)} (or streams differ)")
                    recovered.extend(fins)
        finally:
            self._replaying = False
        # kills at or before this tick already happened pre-crash; a
        # surviving fault plan must not re-fire them (crash loop)
        self._restore_boundary = self._tick_idx
        return recovered

    # -- aggregate metrics ----------------------------------------------------

    def summary(self) -> Dict[str, float]:
        """Aggregate run stats. Every ratio degrades to 0.0 — never NaN or
        a ZeroDivisionError — on degenerate runs (no ticks, no emitted
        tokens, no prefix lookups, all drafts rejected): summaries are
        read by dashboards and benches that must survive empty/drained
        workloads (regression-locked in tests/test_serve_spec.py)."""
        toks = sum(m.tokens for m in self.metrics_log)
        wall = sum(m.wall_s for m in self.metrics_log)
        out = {"ticks": len(self.metrics_log),
               "decode_tokens": toks,
               "prefill_tokens": sum(m.prefill_tokens
                                     for m in self.metrics_log),
               "wall_s": wall,
               "decode_tokens_per_s": toks / wall if wall > 0 else 0.0}
        if self.scfg.paged:
            hit = sum(m.prefix_hit_tokens for m in self.metrics_log)
            total = hit + out["prefill_tokens"]
            out["prefix_hit_tokens"] = hit
            out["prefix_hit_rate"] = hit / total if total > 0 else 0.0
            out["saved_bytes"] = sum(m.saved_bytes for m in self.metrics_log)
            out["prefill_gather_bytes"] = sum(m.prefill_gather_bytes
                                              for m in self.metrics_log)
            out["compaction_moves"] = sum(m.compaction_moves
                                          for m in self.metrics_log)
            out["pool_pages"] = self.pool.num_pages
            out["pool_pages_live"] = self.pool.live
            out["pool_hit_rate"] = self.pool.stats.hit_rate
            out["pool_alloc_run_failures"] = \
                self.pool.stats.alloc_run_failures
            # COW fork economy (DESIGN.md §18): copies are paid traffic,
            # fork_saved_* the duplicate-KV bill the forks did NOT pay
            out["cow_bytes"] = sum(m.cow_bytes for m in self.metrics_log)
            out["cow_copies"] = sum(m.cow_copies for m in self.metrics_log)
            out["forks"] = sum(m.forks for m in self.metrics_log)
            out["fork_saved_bytes"] = sum(m.fork_saved_bytes
                                          for m in self.metrics_log)
            out["fork_saved_flops"] = sum(m.fork_saved_flops
                                          for m in self.metrics_log)
            out["pool_forked_pages"] = self.pool.stats.forked_pages
            out["pool_cow_copies"] = self.pool.stats.cow_copies
        if self.scfg.spec_k > 0:
            drafted = sum(m.spec_draft_tokens for m in self.metrics_log)
            accepted = sum(m.spec_accepted_tokens for m in self.metrics_log)
            slot_ticks = sum(m.active_slots for m in self.metrics_log)
            out["spec_draft_tokens"] = drafted
            out["spec_accepted_tokens"] = accepted
            out["accept_rate"] = accepted / drafted if drafted > 0 else 0.0
            # emitted decode tokens per slot-tick: the multi-token win
            # (plain decode is exactly 1.0; upper bound spec_k + 1)
            out["accepted_tokens_per_tick"] = (
                toks / slot_ticks if slot_ticks > 0 else 0.0)
            out["spec_backoffs"] = self.spec_backoffs
            out["spec_k_current"] = self._cur_spec_k
        # resilience tier (DESIGN.md §17): every ratio 0.0-guards its
        # denominator like the rest of this summary — chaos summaries are
        # read by the bench gate on empty/fully-shed runs too
        n_ticks = len(self.metrics_log)
        done_total = self.n_shed + self.n_finished_ok
        rec_tok = sum(m.recovery_tokens for m in self.metrics_log)
        rec_fl = sum(m.recovery_flops for m in self.metrics_log)
        rec_by = sum(m.recovery_bytes for m in self.metrics_log)
        out["faults_injected"] = sum(m.faults_injected
                                     for m in self.metrics_log)
        out["quarantined"] = self.n_quarantined
        out["quarantine_rate"] = (self.n_quarantined / n_ticks
                                  if n_ticks > 0 else 0.0)
        out["shed"] = self.n_shed
        out["shed_rate"] = (self.n_shed / done_total
                            if done_total > 0 else 0.0)
        out["recovery_tokens"] = rec_tok
        out["recovery_j"] = (energy.compute_energy_j(rec_fl)
                             + energy.dram_energy_j(rec_by))
        out["recovery_j_per_token"] = (out["recovery_j"] / toks
                                       if toks > 0 else 0.0)
        out["degraded_ticks"] = sum(m.degraded for m in self.metrics_log)
        out["readback_retries"] = self.readback_retries_total
        out["fp_fallbacks"] = self.fp_fallbacks
        out["compaction_pauses"] = self.compaction_pauses
        out["audit_failures"] = self.audit_failures
        # durability tier (DESIGN.md §19): all 0.0 on an engine that never
        # checkpoints — the zero-state guard benches and dashboards rely on
        out["snapshots_taken"] = self.snapshots_taken
        out["snapshot_bytes"] = self.snapshot_bytes_total
        out["journal_bytes"] = self.journal_bytes_total
        out["replayed_ticks"] = self.replayed_ticks
        out["restore_j"] = (energy.compute_energy_j(self.restore_flops)
                            + energy.dram_energy_j(self.restore_bytes))
        out["restore_j_per_token"] = (out["restore_j"] / toks
                                      if toks > 0 else 0.0)
        out["durability_write_j"] = energy.dram_energy_j(
            self.snapshot_bytes_total + self.journal_bytes_total)
        return out


def _sample(logits: jnp.ndarray, keys: jnp.ndarray, temp: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-slot sampling: greedy where temp == 0, else categorical at temp,
    each slot drawing from its own PRNG key. Returns (tokens, new keys)."""
    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # (B, 2, 2)
    sub = split[:, 1]
    new_keys = jnp.where((temp > 0)[:, None], split[:, 0], keys)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tsafe = jnp.where(temp > 0, temp, 1.0)
    sampled = jax.vmap(jax.random.categorical)(
        sub, logits / tsafe[:, None]).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy), new_keys
