"""Deterministic fault injection for the paged serve engine (DESIGN.md §17).

An unattended edge deployment — the paper's setting — meets faults the lab
never sees: a NaN logit from a marginal accelerator, a flipped bit in an
int8 KV page, a pool briefly exhausted by a co-tenant, a straggling tick, a
dropped readback. This module makes every one of those a *reproducible
input*: a :class:`FaultPlan` is a seeded schedule of :class:`FaultEvent`\\ s
threaded through ``ServeConfig.faults``, and the engine consults one
:class:`FaultInjector` per run. Same seed, same plan, same tick-by-tick
corruption — so each failure mode is a regression test, not an anecdote.

The *detection and recovery* half (numerics sentinel, quarantine, the
degradation ladder) lives in serve/engine.py; the knobs that arm it are
:class:`GuardrailConfig` (``ServeConfig.guard``). Every default here keeps
the pre-chaos behavior bit-for-bit: no plan means no injection, and an
all-default guard config only adds the sentinel (which is free — it rides
the existing packed readback).

Fault classes and where they land:

* ``nan_logits`` / ``inf_logits`` — a poison vector added to the victim
  slot's decode (or verify) logits *inside* the jitted tick; caught by the
  per-tick numerics sentinel, the slot makes no progress that tick and is
  quarantined by the host.
* ``kv_bitflip`` — host-side corruption of one of the victim slot's
  *private* (refcount-1, unpublished) KV pages: NaN patterns in float
  pools (sentinel catches the very next tick), XOR'd codes in int8 pools
  (finite garbage — the numerics-drift rung's case). Shared prefix pages
  are never touched: the blast radius is one slot by construction.
* ``pool_spike`` — ``magnitude`` pages allocated out from under the
  engine and held for ``duration`` ticks (a co-tenant grabbing memory);
  exercises deferral, backpressure, and deadline shedding.
* ``stall`` — the host sleeps ``STALL_BASE_S * magnitude`` seconds before
  the tick (straggler simulation); the tick-latency EWMA (train/ft.py's
  estimator) sees the spike and the compaction-pause rung reacts.
* ``readback_garble`` / ``readback_drop`` — the tick's one packed host
  readback arrives corrupted (out-of-range by construction) or not at
  all on its first attempt; the engine validates ranges and re-reads.
  In-range flips are undetectable without ECC — a documented limit, not
  a silent one.
* ``process_kill`` — the whole engine dies at the scheduled tick
  (:class:`ProcessKilled` propagates out of ``step()``): preemption, OOM
  kill, node failure. Unlike every transient kind above, recovery is not
  in-tick — it is the durability tier (DESIGN.md §19): restart from the
  latest snapshot, replay the journal, resume token-identically. A kill
  at or before an engine's restore boundary is treated as already-fired
  (it is the crash the restore just recovered from) and does not
  re-raise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

FAULT_KINDS = ("nan_logits", "inf_logits", "kv_bitflip", "pool_spike",
               "stall", "readback_garble", "readback_drop",
               "process_kill")

# transient kinds the in-tick ladder recovers from without restart; the
# chaos matrix loops that drain a single engine iterate these —
# ``process_kill`` needs the restart harness (benchmarks/serve_bench.py
# ``bench_restore``) instead
TRANSIENT_FAULT_KINDS = tuple(k for k in FAULT_KINDS
                              if k != "process_kill")


class ProcessKilled(RuntimeError):
    """Raised out of ``ServeEngine.step()`` when a ``process_kill`` fault
    fires: the simulated process death. Callers model the crash by
    abandoning the engine object and restarting from disk via
    ``ServeEngine.restore()`` (DESIGN.md §19)."""

# host sleep per unit of a stall event's magnitude — big enough to spike a
# tick-wall EWMA whose healthy ticks are milliseconds, small enough that a
# chaos matrix of them stays a smoke test
STALL_BASE_S = 0.02

# the value a garbled readback element is overwritten with: far outside
# every packed field's valid range ({0,1} flags, 0..k+1 emission counts),
# so validation MUST reject it — the injected corruption is detectable by
# construction (the in-range-flip case needs ECC and is out of scope)
GARBLE_VALUE = 1 << 20


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``slot == -1`` resolves to the first active
    decoding slot at fire time (events outlive any particular admission
    order); ``magnitude`` is pages for ``pool_spike``/``kv_bitflip`` and
    the sleep multiplier for ``stall``; ``duration`` is hold ticks for
    ``pool_spike``."""
    tick: int
    kind: str
    slot: int = -1
    magnitude: float = 1.0
    duration: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")
        if self.tick < 0:
            raise ValueError(f"fault tick must be >= 0, got {self.tick}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable fault schedule. ``events`` fire at exact ticks;
    the seed additionally determines every in-event random choice (which
    element of a readback to garble, which byte pattern to flip), so one
    ``(seed, events)`` pair replays bit-identically."""
    seed: int = 0
    events: Tuple[FaultEvent, ...] = ()

    @staticmethod
    def single(kind: str, tick: int = 1, *, seed: int = 0,
               slot: int = -1, magnitude: float = 1.0,
               duration: int = 1) -> "FaultPlan":
        return FaultPlan(seed=seed, events=(
            FaultEvent(tick=tick, kind=kind, slot=slot,
                       magnitude=magnitude, duration=duration),))

    @staticmethod
    def matrix(seed: int, n_ticks: int,
               kinds: Sequence[str] = FAULT_KINDS,
               events_per_kind: int = 1) -> "FaultPlan":
        """One deterministic schedule covering every kind: fire ticks are
        drawn from ``default_rng(seed)`` in ``[1, n_ticks)`` — tick 0 is
        skipped so the first admission always lands cleanly."""
        rng = np.random.default_rng(seed)
        events = []
        for kind in kinds:
            for _ in range(events_per_kind):
                t = int(rng.integers(1, max(n_ticks, 2)))
                events.append(FaultEvent(tick=t, kind=kind))
        return FaultPlan(seed=seed, events=tuple(events))

    def for_tick(self, tick: int) -> List[FaultEvent]:
        return [e for e in self.events if e.tick == tick]

    @property
    def max_tick(self) -> int:
        return max((e.tick for e in self.events), default=-1)


@dataclasses.dataclass
class GuardrailConfig:
    """Detection/degradation knobs (``ServeConfig.guard``). Defaults keep
    the engine's pre-chaos behavior exactly: every rung is off until its
    knob arms it. The numerics sentinel itself has no knob — it is free
    (packed into the existing readback) and always on."""
    # walk PagePool.audit() + the engine's ownership mirror every N ticks
    # (0 = off). Violations are counted (summary: audit_failures), never
    # raised — the auditor is a detector, not a crash vector.
    audit_interval: int = 0
    # paged admission deferrals per request before it is shed (0 =
    # unlimited retries — the pre-chaos behavior)
    admit_max_retries: int = 0
    # exponential admission backoff: after its n-th deferral a request
    # waits base * 2^(n-1) ticks (capped at 32) before it is considered
    # again (0 = retry every tick)
    admit_backoff: int = 0
    # spec-k backoff: halve spec_k (floor 1) when the acceptance-rate EWMA
    # sits below this threshold with at least ``spec_backoff_window``
    # observed spec ticks of evidence (0.0 = off)
    spec_backoff_threshold: float = 0.0
    spec_backoff_window: int = 8
    # int8 numerics-drift watch: every N ticks re-decode one live slot's
    # last emitted token through the fp32 oracle path and update a
    # disagreement EWMA; above ``drift_threshold`` the engine falls back
    # to fp serving wholesale (0 = off)
    drift_check_interval: int = 0
    drift_threshold: float = 0.5
    drift_min_checks: int = 3
    # straggler rung: a tick whose wall time exceeds ``stall_factor`` x
    # the tick-wall EWMA pauses compaction for ``compact_pause_ticks``
    # ticks (0.0 = off)
    stall_factor: float = 0.0
    compact_pause_ticks: int = 4
    # re-reads of a dropped/garbled packed readback before giving up
    readback_max_retries: int = 2
    # smoothing for every guardrail EWMA (train/ft.py Ewma convention:
    # weight on history)
    ewma_alpha: float = 0.9

    def __post_init__(self):
        for name in ("audit_interval", "admit_max_retries", "admit_backoff",
                     "drift_check_interval", "compact_pause_ticks"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        if not (0.0 <= self.spec_backoff_threshold <= 1.0):
            raise ValueError("spec_backoff_threshold must be in [0, 1]")
        if not (0.0 <= self.drift_threshold <= 1.0):
            raise ValueError("drift_threshold must be in [0, 1]")
        if self.readback_max_retries < 1:
            raise ValueError("readback_max_retries must be >= 1")


def corrupt_kv_page(caches, page: int):
    """Return a cache tree with physical ``page`` poisoned in every layer's
    K pool: NaN for float storage (the numerics sentinel fires on the next
    tick that attends the page), XOR'd codes for int8 storage (finite
    garbage — only the drift rung can see it). V is left intact: one
    corrupted projection is enough to taint the victim's logits, and
    keeping the corruption minimal makes the blast-radius assertion
    (unaffected slots bit-identical) the strongest version of itself.

    Only the K codes are touched — int8 scale pools stay valid, so the
    corrupted values remain in-range finite numbers, exactly the silent
    class of fault a bit flip in DRAM produces."""
    new = {}
    for name, entry in caches.items():
        e2 = dict(entry)
        kv = entry["kv"]
        # pattern pools carry the stacked layer dim first; tails are flat
        idx = ((slice(None), page) if name.startswith("pat")
               else (page,))
        if jnp.issubdtype(kv.k.dtype, jnp.floating):
            k2 = kv.k.at[idx].set(jnp.nan)
        else:
            k2 = kv.k.at[idx].set(kv.k[idx] ^ jnp.asarray(0x55, kv.k.dtype))
        e2["kv"] = dataclasses.replace(kv, k=k2)
        new[name] = e2
    return new


class FaultInjector:
    """Per-run dispatcher for one :class:`FaultPlan`.

    The injector owns the *randomness* and the *ledger* (``counts`` per
    kind; a fault is counted when it is actually applied, so a
    ``kv_bitflip`` scheduled while no slot is decoding counts zero). The
    engine owns the mutations that need its internals (pool allocation for
    spikes, cache surgery for bit flips) and calls back ``count()``."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self.counts: Dict[str, int] = {k: 0 for k in FAULT_KINDS}

    @property
    def faults_injected(self) -> int:
        return sum(self.counts.values())

    def count(self, kind: str, n: int = 1) -> None:
        self.counts[kind] += n

    def events_for(self, tick: int) -> List[FaultEvent]:
        return self.plan.for_tick(tick)

    # -- host-side faults -----------------------------------------------------

    def stall_seconds(self, tick: int) -> float:
        """Total straggler sleep scheduled for this tick (0.0 = none)."""
        secs = 0.0
        for e in self.events_for(tick):
            if e.kind == "stall":
                secs += STALL_BASE_S * float(e.magnitude)
                self.count("stall")
        return secs

    def logit_poison(self, tick: int, active_slots: Sequence[int],
                     n_slots: int) -> Optional[np.ndarray]:
        """(B,) float32 poison vector for this tick's decode/verify logits
        (``logits + poison[:, None]``): NaN or +inf at each victim slot,
        0.0 elsewhere. None when no logit fault fires (the engine then
        passes its cached zero vector — no per-tick host->device churn)."""
        vec = None
        for e in self.events_for(tick):
            if e.kind not in ("nan_logits", "inf_logits") or not active_slots:
                continue
            victim = e.slot if e.slot in active_slots else active_slots[0]
            if vec is None:
                vec = np.zeros(n_slots, np.float32)
            vec[victim] = np.nan if e.kind == "nan_logits" else np.inf
            self.count(e.kind)
        return vec

    # -- readback faults ------------------------------------------------------

    def filter_readback(self, arr: np.ndarray, tick: int,
                        attempt: int = 0) -> Optional[np.ndarray]:
        """Pass the tick's packed readback through this tick's readback
        faults. Only the FIRST attempt is corrupted (the model is a torn
        transfer, not a persistently bad link): a retry sees the true
        array, so the engine's validate-and-retry loop always converges."""
        if attempt > 0:
            return arr
        for e in self.events_for(tick):
            if e.kind == "readback_drop":
                self.count("readback_drop")
                return None
            if e.kind == "readback_garble":
                bad = np.array(arr, copy=True)
                flat = bad.reshape(-1)
                flat[int(self._rng.integers(flat.size))] = GARBLE_VALUE
                self.count("readback_garble")
                return bad
        return arr
