"""Paged KV subsystem: host-side block pool + exact-match prefix cache.

The paper's argument is that DRAM bytes — not FLOPs — dominate edge
inference energy, and PR 2's bench confirmed it here. The dense serve core
still books one ``max_len`` KV region per slot and re-prefills every prompt
from scratch, so the common serving pattern (a shared system prompt +
distinct user tails) pays its DRAM/FLOP bill once per request. This module
is the host half of the fix (DESIGN.md §14):

* **PagePool** — the allocator/refcount ledger for a device-resident block
  pool (``transformer.init_paged_caches``). Physical page ``num_pages`` is
  a reserved *sink*: device-side writes from dead/padded lanes land there,
  so freed pages can be reused without any device-side page-table scrub.
* **Prefix cache** — full prompt blocks are published under the key
  ``(parent page id, block token tuple)``. Because the parent page is
  itself content-verified by induction (block 0's parent is the root
  sentinel), a registry hit proves *exact* token equality of the entire
  prefix — there is no hash involved and therefore no collision mode that
  could serve another request's KV pages. A later admission whose prompt
  starts with the same blocks *retains* those pages instead of recomputing
  and re-storing their K/V: the page-table copy replaces the prefill.
* **Copy-on-write forks** (DESIGN.md §18) — ``fork()`` clones a slot's
  committed page run by *retaining* the shared pages instead of copying
  their bytes, so k n-best streams (or the branches of a speculation
  tree) share one physical prefix. A page is ``writable()`` only while
  its holder is the sole referent AND it is unpublished — the same
  predicate as ``movable_suffix`` — and the first write to a shared page
  goes through ``cow_write()``: allocate a private page, copy the shared
  one's bytes (billed by the engine as COW bytes), release the shared
  reference. The *last* co-owner to diverge finds itself sole referent
  again and writes in place, so a k-way fork costs at most k - 1 page
  copies, all on the partial boundary page — full committed blocks are
  never copied, which is the entire point.
* **Eviction** — pages whose refcount drops to zero but that are published
  in the prefix cache park in an LRU; ``alloc`` reclaims from it only when
  the free list runs dry, so cached prefixes survive as long as capacity
  allows.

The device half (pool arrays, page-table-indirect attention) lives in
models/transformer.py and kernels/decode_attention.py; the admission logic
that ties them together in serve/engine.py.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# parent id of a prompt's first block in the prefix registry
ROOT = -1


def fragmentation(pages: Sequence[int]) -> float:
    """Scatter score of one slot's page run: 1 minus the fraction of
    adjacent table entries that are physically contiguous ascending.
    0.0 = a perfect run (every gather is one long DMA), -> 1.0 = fully
    scattered (every page is its own transfer). The engine compares this
    against ``ServeConfig.compact_threshold`` to trigger compaction."""
    if len(pages) < 2:
        return 0.0
    adj = sum(1 for a, b in zip(pages, pages[1:]) if b == a + 1)
    return 1.0 - adj / (len(pages) - 1)

BlockKey = Tuple[int, Tuple[int, ...]]          # (parent page, block tokens)


def block_tokens(tokens: Sequence[int], page_size: int
                 ) -> List[Tuple[int, ...]]:
    """Token tuples of the *full* blocks of ``tokens``. The trailing
    partial block (if any) is never returned: only full, frozen blocks are
    shareable."""
    toks = np.asarray(tokens, np.int64)
    return [tuple(int(t) for t in toks[j * page_size:(j + 1) * page_size])
            for j in range(len(toks) // page_size)]


@dataclasses.dataclass
class PoolStats:
    """Cumulative prefix-cache/allocator counters (block granularity)."""
    hit_blocks: int = 0
    missed_blocks: int = 0      # full blocks that were not cached
    evicted_blocks: int = 0
    alloc_failures: int = 0
    # contiguous-run allocation failures (compaction starvation): booked by
    # ``alloc_run`` returning None, the satellite ``alloc`` always booked
    alloc_run_failures: int = 0
    # COW channels (DESIGN.md §18): pages copied on first write to a shared
    # page, and pages whose bytes a fork *retained* instead of duplicating
    cow_copies: int = 0
    forked_pages: int = 0

    @property
    def hit_rate(self) -> float:
        """Block-level hit fraction; 0.0 (never NaN/raise) when no lookup
        has been booked — empty pools and drained engines report clean
        zeros (regression-locked in tests/test_serve_spec.py)."""
        n = self.hit_blocks + self.missed_blocks
        return self.hit_blocks / n if n > 0 else 0.0


class PagePool:
    """Host-side allocator + prefix registry for ``num_pages`` KV pages.

    Invariants:

    * every page is in exactly one of: the free list, the LRU park (cached,
      refcount 0), or live (refcount > 0);
    * a page carries at most one published key, and ``_key_to_page`` /
      ``_page_key`` mirror each other;
    * shared (published) pages are immutable — the engine only writes to
      pages it holds privately (allocated this admission or for decode).

    ``evict_policy`` selects how the park is reclaimed when the free list
    runs dry: ``"lru"`` pops the least-recently-parked page; ``"cost"``
    trims the parked prefix forest at its leaves, evicting the leaf that
    is *cheapest to recompute* first (scored by ``block_cost(depth)``,
    the engine's ``costing.block_recompute_flops`` closure over the
    block's chain depth — DESIGN.md §16). Under "cost" a long document's
    chain survives pressure that would LRU-evict its root (and thereby
    cascade-unpublish the whole chain): short/shallow chains go first,
    because regenerating a deep block means re-prefilling its entire
    prefix.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 evict_policy: str = "lru",
                 block_cost: Optional[Callable[[int], float]] = None):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        if evict_policy not in ("lru", "cost"):
            raise ValueError(f"unknown evict_policy {evict_policy!r}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.evict_policy = evict_policy
        self.block_cost = block_cost
        self.sink = num_pages          # reserved garbage row in the pool
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: List[int] = [0] * num_pages
        self._key_to_page: Dict[BlockKey, int] = {}
        self._page_key: Dict[int, Optional[BlockKey]] = {}
        # parent page -> published child pages: when a page is evicted (or
        # otherwise unpublished) every key that names it as parent becomes
        # uncertifiable — the page id may be recycled with new content —
        # so children cascade-unpublish (no stale-chain false hits)
        self._children: Dict[int, set] = {}
        # page -> 0-based depth of its block in the prefix chain (set at
        # publish; the cost policy's recompute score grows with depth)
        self._page_depth: Dict[int, int] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.stats = PoolStats()

    # -- capacity -------------------------------------------------------------

    @property
    def available(self) -> int:
        """Pages allocatable right now (free + evictable cached)."""
        return len(self._free) + len(self._lru)

    @property
    def live(self) -> int:
        return self.num_pages - self.available

    # -- allocation / refcounting ---------------------------------------------

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` private pages (refcount 1), evicting LRU-parked
        cached pages only if the free list runs dry. Returns None (and books
        an alloc failure) when capacity is insufficient — the caller defers
        the admission rather than corrupting live slots."""
        if n > self.available:
            self.stats.alloc_failures += 1
            return None
        pages: List[int] = []
        for _ in range(n):
            if self._free:
                p = self._free.pop()
            else:
                p = self._evict_one()
            self._ref[p] = 1
            pages.append(p)
        return pages

    def _evict_one(self) -> int:
        """Reclaim one parked page. ``lru``: least-recently-parked.
        ``cost``: trim the prefix forest at its LEAVES, cheapest leaf
        first. Candidates are parked pages with no published children —
        evicting an interior block would cascade-unpublish every
        descendant (their keys name its page id), destroying far more
        recompute value than the block's own score; a leaf cascades
        nothing. Among leaves the lowest ``block_cost(depth)`` goes first
        (shallow blocks of short chains are cheap to regenerate; a deep
        leaf implies its whole prefix must be re-prefilled), park order
        breaks ties, and a parked page whose key was already
        cascade-unpublished certifies nothing — it is worthless and
        always goes first."""
        if self.evict_policy == "cost" and self.block_cost is not None:
            best = best_score = None
            for p in self._lru:         # iteration order = park order
                if self._page_key.get(p) is None:
                    best = p
                    break
                if self._children.get(p):
                    continue            # interior: eviction would cascade
                score = self.block_cost(self._page_depth.get(p, 0))
                if best_score is None or score < best_score:
                    best, best_score = p, score
            if best is None:            # defensive: all parked are interior
                p, _ = self._lru.popitem(last=False)
            else:
                del self._lru[best]
                p = best
        else:
            p, _ = self._lru.popitem(last=False)
        self._unpublish(p)
        self.stats.evicted_blocks += 1
        return p

    def retain(self, page: int) -> None:
        if self._ref[page] == 0:
            if page in self._lru:
                del self._lru[page]
            else:
                # a free-listed page is allocatable: silently refcounting it
                # would let ``alloc`` hand the same physical page to another
                # slot (double-allocation — two writers, one page). COW
                # forks retain aggressively, so this is a raise, not a
                # debug assert.
                raise RuntimeError(
                    f"retain() on free-listed page {page}: only live or "
                    f"parked (published) pages may gain references")
        self._ref[page] += 1

    def release(self, page: int) -> None:
        assert self._ref[page] > 0, f"double release of page {page}"
        self._ref[page] -= 1
        if self._ref[page] == 0:
            if self._page_key.get(page) is not None:
                self._lru[page] = None          # parked, evictable
            else:
                self._free.append(page)

    def release_all(self, pages: Sequence[int]) -> None:
        for p in pages:
            self.release(p)

    # -- copy-on-write forks (DESIGN.md §18) ----------------------------------

    def writable(self, page: int) -> bool:
        """True iff the (sole) holder may write ``page`` in place: refcount
        exactly 1 and no published key — the ``movable_suffix`` predicate.
        A shared or published page is frozen; writes must go through
        ``cow_write``."""
        return self._ref[page] == 1 and self._page_key.get(page) is None

    def fork(self, pages: Sequence[int]) -> List[int]:
        """Clone a slot's committed page run for a fork: retain every page
        (the child holds one reference each, exactly like a prefix-cache
        hit) and return the same physical ids. No bytes move — divergence
        is paid lazily, page by page, via ``cow_write`` when a fork first
        writes into a shared page. Callers release the returned run
        through ``release_all`` like any owned pages."""
        for p in pages:
            self.retain(p)
        self.stats.forked_pages += len(pages)
        return list(pages)

    def cow_write(self, page: int) -> Optional[Tuple[int, bool]]:
        """Make ``page`` writable for its caller (one current referent).
        Sole-referent unpublished pages are returned as-is (in-place write,
        no copy). Otherwise allocate a private replacement, drop the
        caller's reference on the shared page, and return
        ``(new_page, True)`` — the *caller* owns the device-side byte copy
        old -> new and the COW-bytes bill. Returns None when the pool
        cannot supply the replacement page (the caller degrades
        gracefully; never corrupts the shared page)."""
        if self.writable(page):
            return page, False
        fresh = self.alloc(1)
        if fresh is None:
            return None
        self.release(page)
        self.stats.cow_copies += 1
        return fresh[0], True

    # -- compaction (DESIGN.md §16) -------------------------------------------

    def movable_suffix(self, pages: Sequence[int]) -> int:
        """Index into ``pages`` (one slot's live page run) where the
        *movable private suffix* begins. A page may be relocated only when
        this slot holds its sole reference AND it is unpublished — a
        published page's id is baked into registry keys (children name the
        parent page id) and possibly other slots' tables, so moving it
        would tear the certification chain. Everything from the returned
        index on is refcount-1 and unkeyed; shared prefix blocks are never
        moved."""
        i = len(pages)
        while i > 0 and self.writable(pages[i - 1]):
            i -= 1
        return i

    def alloc_run(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` physically *contiguous* ascending pages from the
        free list ONLY — compaction must never evict cached prefixes to
        make room (that would trade gather bytes for recompute FLOPs, the
        wrong direction). Returns None when no free run of length ``n``
        exists; picks the lowest-addressed run otherwise (keeps the free
        space itself defragmented)."""
        if n <= 0:
            return []
        free = sorted(self._free)
        run_start = 0
        for i in range(1, len(free) + 1):
            if i == len(free) or free[i] != free[i - 1] + 1:
                if i - run_start >= n:
                    run = free[run_start:run_start + n]
                    taken = set(run)
                    self._free = [p for p in self._free if p not in taken]
                    for p in run:
                        self._ref[p] = 1
                    return run
                run_start = i
        # book the starvation: without this counter a fragmented free list
        # silently stalls compaction forever (summary() shows nothing)
        self.stats.alloc_run_failures += 1
        return None

    # -- prefix cache ---------------------------------------------------------

    def _unpublish(self, page: int) -> None:
        stack = [page]
        while stack:
            p = stack.pop()
            self._page_depth.pop(p, None)
            key = self._page_key.pop(p, None)
            if key is not None:
                if self._key_to_page.get(key) == p:
                    del self._key_to_page[key]
                if key[0] != ROOT:
                    sibs = self._children.get(key[0])
                    if sibs is not None:
                        sibs.discard(p)
                        if not sibs:
                            # prune the emptied set: stale entries would
                            # grow the dict without bound over a long
                            # churny serve, and audit() walks every entry
                            del self._children[key[0]]
            # descendants' prefixes are no longer certifiable through p
            stack.extend(self._children.pop(p, ()))

    def publish(self, page: int, parent: int, block: Tuple[int, ...]) -> int:
        """Register a *full, frozen* block under ``(parent page, tokens)``.
        ``parent`` is the *canonical* page holding the previous block (ROOT
        for the first), so a registry hit certifies the whole prefix by
        induction. First writer wins: if the key is already published (an
        earlier admission computed the same prefix), the existing page
        stays canonical. Returns the canonical page for the key — callers
        publishing a chain MUST thread it as the next block's parent, or a
        duplicate chain would register keys no lookup can reach."""
        key: BlockKey = (parent, block)
        existing = self._key_to_page.get(key)
        if existing is not None:
            return existing
        self._unpublish(page)           # a page carries at most one key
        self._page_key[page] = key
        self._key_to_page[key] = page
        if parent != ROOT:
            self._children.setdefault(parent, set()).add(page)
            self._page_depth[page] = self._page_depth.get(parent, 0) + 1
        else:
            self._page_depth[page] = 0
        return page

    def lookup(self, blocks: Sequence[Tuple[int, ...]]) -> List[int]:
        """Longest cached chain for a prompt's full-block token tuples.
        Retains every returned page (caller owns one reference each) and
        books block-level hit/miss stats."""
        pages: List[int] = []
        parent = ROOT
        for block in blocks:
            p = self._key_to_page.get((parent, block))
            if p is None:
                break
            self.retain(p)
            pages.append(p)
            parent = p
        self.stats.hit_blocks += len(pages)
        self.stats.missed_blocks += len(blocks) - len(pages)
        return pages

    def unbook_lookup(self, n_hits: int, n_total: int) -> None:
        """Roll back one ``lookup``'s stats booking — used when the caller
        defers the admission (the retry will look up, and book, again).
        Without this, every deferral double-counts its blocks and inflates
        ``hit_rate``; with it, each admission books exactly once."""
        self.stats.hit_blocks -= n_hits
        self.stats.missed_blocks -= n_total - n_hits
        assert (self.stats.hit_blocks >= 0
                and self.stats.missed_blocks >= 0), \
            "unbook_lookup rolled back more than was booked"

    # -- integrity audit (DESIGN.md §17) --------------------------------------

    def audit(self) -> List[str]:
        """Walk every pool invariant and return the violations (empty =
        healthy). The chaos tier's integrity detector: the engine runs
        this every ``guard.audit_interval`` ticks and surfaces failures
        as a counter — a refcount drifting under fault churn is exactly
        the silent-corruption class this exists to catch. Checks:

        * partition — every page is in exactly one of free / parked (LRU)
          / live (refcount > 0);
        * refcounts are non-negative, free/parked pages hold refcount 0;
        * free pages carry no published key (release parks keyed pages);
        * the key registry mirrors are a bijection
          (``_key_to_page[_page_key[p]] == p`` and back);
        * every child edge matches its key's parent, and a child's chain
          depth is its parent's + 1;
        * no orphaned bookkeeping: ``_children`` holds no empty sets and
          no entries for unpublished parents, and ``_page_depth`` covers
          published pages only (stale entries would accumulate without
          bound and could mis-score cost eviction for a recycled page id).
        """
        v: List[str] = []
        free, parked = set(self._free), set(self._lru)
        if len(free) != len(self._free):
            v.append("free list holds duplicate pages")
        for p in range(self.num_pages):
            states = ((p in free) + (p in parked) + (self._ref[p] > 0))
            if states != 1:
                v.append(f"page {p} in {states} states "
                         f"(free={p in free}, parked={p in parked}, "
                         f"ref={self._ref[p]})")
            if self._ref[p] < 0:
                v.append(f"page {p} refcount {self._ref[p]} < 0")
            if p in free and self._page_key.get(p) is not None:
                v.append(f"free page {p} still published")
        for p, key in self._page_key.items():
            if key is None:
                continue
            if self._key_to_page.get(key) != p:
                v.append(f"page {p} key {key} not mirrored in registry")
            parent = key[0]
            if parent != ROOT:
                if p not in self._children.get(parent, ()):
                    v.append(f"page {p} missing from parent {parent}'s "
                             f"children")
                want = self._page_depth.get(parent, 0) + 1
                if self._page_depth.get(p) != want:
                    v.append(f"page {p} depth {self._page_depth.get(p)} "
                             f"!= parent depth + 1 ({want})")
        for key, p in self._key_to_page.items():
            if self._page_key.get(p) != key:
                v.append(f"registry key {key} -> page {p} not mirrored")
        for parent, kids in self._children.items():
            if not kids:
                v.append(f"empty _children set for page {parent} not "
                         f"pruned")
            if self._page_key.get(parent) is None:
                v.append(f"_children entry for unpublished page {parent}")
            for kid in kids:
                k = self._page_key.get(kid)
                if k is None or k[0] != parent:
                    v.append(f"child edge {parent}->{kid} has no matching "
                             f"key")
        for p in self._page_depth:
            if self._page_key.get(p) is None:
                v.append(f"_page_depth entry for unpublished page {p}")
        return v

    # -- snapshot serialization (DESIGN.md §19) -------------------------------

    def state_dict(self) -> Dict:
        """JSON-able snapshot of the whole allocator + registry. Order is
        semantic and preserved exactly: ``free`` is the LIFO free list
        (``alloc`` pops its tail), ``lru`` is park order (eviction pops its
        head) — a reordered restore would allocate different physical
        pages and break bit-identical replay."""
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "evict_policy": self.evict_policy,
            "free": list(self._free),
            "ref": list(self._ref),
            # the registry bijection, one entry per published page:
            # [page, parent, block tokens]
            "registry": [[p, key[0], list(key[1])]
                         for p, key in self._page_key.items()
                         if key is not None],
            "children": {str(parent): sorted(kids)
                         for parent, kids in self._children.items()},
            "page_depth": {str(p): d for p, d in self._page_depth.items()},
            "lru": list(self._lru),
            "stats": dataclasses.asdict(self.stats),
        }

    def load_state(self, d: Dict) -> None:
        """Restore :meth:`state_dict` in place (``block_cost`` and the
        identity knobs stay as constructed). Refuses a snapshot taken
        under different pool geometry — its page ids would be
        meaningless here."""
        for field in ("num_pages", "page_size", "evict_policy"):
            if d[field] != getattr(self, field):
                raise RuntimeError(
                    f"pool snapshot mismatch: {field} = {d[field]!r} in "
                    f"snapshot, {getattr(self, field)!r} in this pool")
        self._free = [int(p) for p in d["free"]]
        self._ref = [int(r) for r in d["ref"]]
        self._key_to_page = {}
        self._page_key = {}
        for page, parent, block in d["registry"]:
            key: BlockKey = (int(parent), tuple(int(t) for t in block))
            self._page_key[int(page)] = key
            self._key_to_page[key] = int(page)
        self._children = {int(parent): set(int(k) for k in kids)
                          for parent, kids in d["children"].items()}
        self._page_depth = {int(p): int(depth)
                            for p, depth in d["page_depth"].items()}
        self._lru = OrderedDict((int(p), None) for p in d["lru"])
        self.stats = PoolStats(**d["stats"])

    # -- introspection --------------------------------------------------------

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def cached_pages(self) -> Tuple[int, ...]:
        return tuple(p for p, k in self._page_key.items() if k is not None)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"PagePool(pages={self.num_pages}, free={len(self._free)}, "
                f"parked={len(self._lru)}, live={self.live}, "
                f"hit_rate={self.stats.hit_rate:.2%})")
