"""Accuracy oracles for the serving fast paths (DESIGN.md §12/§14).

The full-precision model (the same functions serve/reference.py drives) is
the ground truth; the int8 fast path must stay *bounded* against it. The
check is teacher-forced so one early argmax flip cannot cascade into a
meaningless whole-suffix mismatch: both models decode the **same** token
stream (the full-precision greedy trajectory) and we compare, position by
position, the next-token argmax each would emit plus the worst logit gap.

``token_agreement`` is the acceptance metric: the int8 path ships with a
documented >= 99% greedy-token agreement over >= 500 decoded tokens
(tests/test_serve_quant.py) and BENCH_quant.json records the measured value.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf_lib

PyTree = Any


def token_agreement(params: PyTree, cfg: tf_lib.LMConfig,
                    prompts: np.ndarray, n_tokens: int,
                    qparams: PyTree = None) -> Dict[str, float]:
    """Teacher-forced greedy agreement, int8 fast path vs full precision.

    ``prompts``: (B, L) int32 equal-length prompt batch. Both models prefill
    the batch, then decode ``n_tokens`` steps feeding the full-precision
    greedy token back to BOTH — identical contexts, so every step is an
    independent argmax comparison. Returns agreement fraction, token count,
    and the max |logit| gap observed.
    """
    prompts = jnp.asarray(prompts, jnp.int32)
    b, plen = prompts.shape
    max_len = plen + n_tokens + 1
    fp_cfg = dataclasses.replace(cfg, quant=tf_lib.QuantPolicy())
    q_cfg = dataclasses.replace(cfg, quant=tf_lib.INT8_QUANT)
    qparams = tf_lib.quantize_lm(params) if qparams is None else qparams

    lg_fp, cc_fp = tf_lib.prefill(params, fp_cfg, prompts, max_len=max_len,
                                  cache_dtype=jnp.float32)
    lg_q, cc_q = tf_lib.prefill(qparams, q_cfg, prompts, max_len=max_len,
                                cache_dtype=jnp.float32)

    step_fp = jax.jit(lambda p, t, pos, c: tf_lib.decode_step(
        p, fp_cfg, t, pos, c))
    step_q = jax.jit(lambda p, t, pos, c: tf_lib.decode_step(
        p, q_cfg, t, pos, c))

    agree = total = 0
    max_gap = 0.0
    cur = None
    for t in range(n_tokens):
        a_fp = jnp.argmax(lg_fp[:, 0], axis=-1).astype(jnp.int32)
        a_q = jnp.argmax(lg_q[:, 0], axis=-1).astype(jnp.int32)
        agree += int((a_fp == a_q).sum())
        total += b
        max_gap = max(max_gap, float(jnp.abs(lg_fp - lg_q).max()))
        if t == n_tokens - 1:
            break
        cur = a_fp                       # teacher forcing: fp greedy drives
        pos = jnp.asarray(plen + t)
        lg_fp, cc_fp = step_fp(params, cur[:, None], pos, cc_fp)
        lg_q, cc_q = step_q(qparams, cur[:, None], pos, cc_q)
    return {"agreement": agree / total, "tokens": total,
            "max_logit_gap": max_gap}


def run_workload(engine, prompts, max_tokens: int = 8,
                 max_ticks: int = 10000) -> Dict[int, list]:
    """Submit ``prompts`` in order and drain — the shared driver for
    engine-vs-engine comparisons. Returns {uid: generated tokens}."""
    for p in prompts:
        engine.submit(np.asarray(p, np.int32), max_tokens=max_tokens)
    done = engine.run_until_drained(max_ticks=max_ticks)
    return {r.uid: list(r.generated) for r in done}


def generation_agreement(got: Dict[int, list], want: Dict[int, list]
                         ) -> Dict[str, float]:
    """Position-wise token agreement between two engines' outputs on the
    same workload (matched by request uid) — the paged-vs-dense acceptance
    metric (DESIGN.md §14): exact on non-shared workloads, >= 99% on
    shared-prefix workloads where chunk boundaries may shift one argmax.

    ``identical`` is 1.0 iff every stream matches token for token
    (including lengths)."""
    assert set(got) == set(want), (sorted(got), sorted(want))
    agree = total = 0
    ident = True
    for uid in got:
        a, b = got[uid], want[uid]
        ident &= a == b
        total += max(len(a), len(b))
        agree += sum(1 for x, y in zip(a, b) if x == y)
    return {"agreement": agree / total if total else 1.0,
            "tokens": total,
            "identical": 1.0 if ident else 0.0}
