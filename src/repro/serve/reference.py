"""Host-loop reference serving engine (the pre-serve-core implementation).

Kept as the correctness oracle and the benchmark "before": per-prompt
prefill, expand/squeeze-vmapped single-row decode, and host-side sampling
with one ``int(tok)`` device sync per active slot per tick. The fused
device-resident engine (serve/engine.py) must be token-identical to this
under greedy decoding; benchmarks/serve_bench.py measures the speedup.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting
from repro.models import transformer as tf_lib
from repro.serve.engine import (PyTree, Request, ServeConfig, StepMetrics,
                                _batch_axis_tree)


class ReferenceEngine:
    """Slot-based continuous batching with a host-driven control loop."""

    def __init__(self, params: PyTree, cfg: tf_lib.LMConfig,
                 serve_cfg: ServeConfig,
                 accountant: Optional[accounting.CarbonAccountant] = None):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self.accountant = accountant
        b = serve_cfg.max_slots
        self.caches = tf_lib.init_caches(cfg, b, serve_cfg.max_len,
                                         serve_cfg.cache_dtype)
        self.slot_req: List[Optional[Request]] = [None] * b
        self.slot_pos = np.zeros(b, np.int32)
        self.slot_tok = np.zeros(b, np.int32)
        self.queue: Deque[Request] = deque()
        self._uid = 0
        self._rng = jax.random.PRNGKey(serve_cfg.seed)
        self.metrics_log: List[StepMetrics] = []
        self._admit_finished: List[Request] = []
        self._build_fns()

    # -- compiled paths -------------------------------------------------------

    def _build_fns(self):
        cfg, scfg = self.cfg, self.scfg

        def prefill_one(params, tokens):
            return tf_lib.prefill(params, cfg, tokens, max_len=scfg.max_len,
                                  cache_dtype=scfg.cache_dtype)

        self._prefill = jax.jit(prefill_one)

        cache_axes = _batch_axis_tree(self.caches)

        def decode_row(params, token, pos, cache):
            # vmap strips the batch axis from cache leaves; run a B=1 decode
            cache_b = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                                   cache, cache_axes)
            logits, new_cache = tf_lib.decode_step(
                params, cfg, token[None, None], pos, cache_b)
            new_cache = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax),
                                     new_cache, cache_axes)
            return logits[0, 0], new_cache

        self._decode = jax.jit(
            jax.vmap(decode_row, in_axes=(None, 0, 0, cache_axes),
                     out_axes=(0, cache_axes)))

    # -- queue API ------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_tokens))
        return self._uid

    def _write_slot_cache(self, slot: int, row_caches: PyTree) -> None:
        """Insert a prefilled (batch=1) cache into the batched cache at slot."""
        def ins(batched, row, ax):
            idx = [slice(None)] * batched.ndim
            idx[ax] = slot
            return batched.at[tuple(idx)].set(jnp.squeeze(row, axis=ax))
        axes = _batch_axis_tree(self.caches)
        self.caches = jax.tree.map(ins, self.caches, row_caches, axes)

    def _admit(self) -> None:
        for slot in range(self.scfg.max_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            prompt = jnp.asarray(req.prompt[None, :])
            logits, row_cache = self._prefill(self.params, prompt)
            self._write_slot_cache(slot, row_cache)
            tok = self._sample(logits[0, -1])
            req.generated.append(int(tok))
            # same admission-time finish rules as the fused engine
            # (max_tokens == 1, prompt at the length cap, EOS at prefill) —
            # the engines must stay token-identical at the edges too
            if (req.max_tokens <= 1
                    or len(req.prompt) >= self.scfg.max_len - 1
                    or (self.scfg.eos_id >= 0
                        and int(tok) == self.scfg.eos_id)):
                req.done = True
                self._admit_finished.append(req)
                continue
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            self.slot_tok[slot] = int(tok)

    def _sample(self, logits: jnp.ndarray) -> int:
        if self.scfg.temperature <= 0:
            return int(jnp.argmax(logits))
        self._rng, sub = jax.random.split(self._rng)
        return int(jax.random.categorical(sub, logits / self.scfg.temperature))

    # -- main tick ------------------------------------------------------------

    def step(self) -> List[Request]:
        """Admit + one decode tick for all active slots. Returns finished."""
        t0 = time.monotonic()
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        finished: List[Request] = self._admit_finished
        self._admit_finished = []
        if active:
            toks = jnp.asarray(self.slot_tok)
            poss = jnp.asarray(self.slot_pos)
            logits, self.caches = self._decode(self.params, toks, poss,
                                               self.caches)
            for i in active:
                req = self.slot_req[i]
                tok = self._sample(logits[i])
                req.generated.append(tok)
                self.slot_pos[i] += 1
                self.slot_tok[i] = tok
                hit_eos = (self.scfg.eos_id >= 0 and tok == self.scfg.eos_id)
                if (len(req.generated) >= req.max_tokens or hit_eos
                        or self.slot_pos[i] >= self.scfg.max_len - 1):
                    req.done = True
                    finished.append(req)
                    self.slot_req[i] = None
        m = StepMetrics(tokens=len(active), active_slots=len(active),
                        wall_s=time.monotonic() - t0,
                        queue_depth=len(self.queue))
        self.metrics_log.append(m)
        if self.accountant is not None:
            self.accountant.observe_serve(m)
        return finished

    def run_until_drained(self, max_ticks: int = 10000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done.extend(self.step())
            if not self.queue and all(r is None for r in self.slot_req):
                break
        return done
