"""Admission scheduling policy for the continuous-batching serve core.

The engine owns device state (caches, slot arrays); the scheduler owns the
*policy* of which queued requests enter freed slots:

* ``fifo`` — arrival order (the seed engine's implicit policy);
* ``longest_prompt`` — longest-prompt-first. Long prompts dominate both the
  padded batched-prefill cost and the per-tick KV footprint; admitting them
  together groups similar lengths into one pad-and-stack prefill call
  (less padding waste) and starts the expensive requests earliest, which
  lowers mean slot residency under a deep queue.

Requests picked in one ``select`` call are prefilled as ONE padded batch
(engine._admit), so the policy also controls prefill batch composition.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.serve.engine import Request


@dataclasses.dataclass
class SchedulerConfig:
    policy: str = "fifo"               # "fifo" | "longest_prompt"
    # queue aging (DESIGN.md §17): under ``longest_prompt`` every
    # ``age_boost_ticks`` ticks a request has waited count as one extra
    # prompt token of priority, so short prompts cannot starve behind a
    # steady stream of long ones. 0 = off (pure length order). The engine
    # passes the current tick via ``select(..., now=)``; without it aging
    # is inert.
    age_boost_ticks: int = 0


class Scheduler:
    def __init__(self, config: SchedulerConfig | None = None):
        self.config = config or SchedulerConfig()
        if self.config.policy not in ("fifo", "longest_prompt"):
            raise ValueError(f"unknown policy {self.config.policy!r}")
        self._q: Deque["Request"] = deque()

    def submit(self, req: "Request") -> None:
        self._q.append(req)

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> List["Request"]:
        return list(self._q)

    def select(self, n_free: int,
               fits: Optional[Callable[["Request"], bool]] = None,
               now: Optional[int] = None) -> List["Request"]:
        """Pop up to ``n_free`` requests for admission, per policy.

        ``fits`` is the engine's capacity gate (the paged engine passes its
        page-pool estimate; it may consume budget as a side effect, so it
        is called at most once per candidate). FIFO stops at the first
        non-fitting request — head-of-line order is the policy's contract —
        while ``longest_prompt`` skips non-fitting candidates (it already
        reorders, so admitting a shorter prompt that fits is in-policy).

        ``now`` is the engine's tick counter; with
        ``config.age_boost_ticks`` set it feeds the anti-starvation aging
        term under ``longest_prompt``.
        """
        if n_free <= 0 or not self._q:
            return []
        if self.config.policy == "fifo":
            out: List["Request"] = []
            while self._q and len(out) < n_free:
                if fits is not None and not fits(self._q[0]):
                    break
                out.append(self._q.popleft())
            return out

        def rank(r: "Request") -> float:
            n = float(len(r.prompt))
            boost_every = self.config.age_boost_ticks
            if boost_every > 0 and now is not None:
                submitted = getattr(r, "submit_tick", -1)
                if submitted >= 0:
                    n += (now - submitted) // boost_every
            return -n

        # longest_prompt: stable pick of the n longest pending prompts
        # (aging-adjusted length when armed)
        ranked = sorted(self._q, key=rank)
        picked: List["Request"] = []
        for r in ranked:
            if len(picked) >= n_free:
                break
            if fits is None or fits(r):
                picked.append(r)
        chosen = set(id(r) for r in picked)
        self._q = deque(r for r in self._q if id(r) not in chosen)
        return picked

    def load(self, reqs: List["Request"]) -> None:
        """Replace the queue wholesale, in order — snapshot restore
        (DESIGN.md §19) rebuilds the exact pending sequence so replayed
        admission decisions repeat bit-identically."""
        self._q = deque(reqs)

    def requeue_front(self, reqs: List["Request"]) -> None:
        """Return selected-but-not-admitted requests to the queue head
        (e.g. SSD archs admit only equal-length groups per prefill call)."""
        self._q.extendleft(reversed(reqs))

    def drop(self, pred: Callable[["Request"], bool]) -> List["Request"]:
        """Remove and return every queued request matching ``pred``, in
        queue order. The paged engine's never-fittable guard: a request
        whose worst-case page demand (which books speculative-decode
        growth too) exceeds the whole pool would pin a FIFO queue's head
        forever — the engine drops it and fails it fast instead. ``pred``
        is called exactly once per queued request."""
        kept: Deque["Request"] = deque()
        dropped: List["Request"] = []
        for r in self._q:
            (dropped if pred(r) else kept).append(r)
        if dropped:
            self._q = kept
        return dropped
