"""Durability layer for the serve engine (DESIGN.md §19).

A process death loses what PR 7's transient-fault ladder cannot protect:
every live stream's device state, the paged pool, the prefix registry, the
queue. This module supplies the two host-side halves of crash-consistent
warm restart:

* **Write-ahead journal** (:class:`Journal`) — an append-only JSONL file of
  sequence-numbered records. A ``submit`` record is fsync'd before the
  request is acknowledged (the WAL contract: an acked request survives any
  crash); one ``tick`` record per engine tick captures which requests
  finished and with exactly which tokens. Because the engine is seeded and
  deterministic end to end (per-uid PRNG folds, seeded fault injection,
  deterministic scheduling — DESIGN.md §17), *replaying* the journaled
  admissions and ticks from a snapshot reproduces every stream
  bit-identically; the tick records double as a divergence detector during
  replay.
* **Host state (de)serialization** — ``host_state_dict`` /
  ``install_host_state`` round-trip every host mirror the engine keeps
  beside its device arrays (slot tables, page ownership, prefix registry,
  fork groups, recovery ledgers, guardrail EWMAs, injector RNG state,
  accountant ledgers) as a JSON-able dict that rides the checkpoint
  manifest's ``extra`` field. The device tree itself goes through
  ``repro/checkpoint/manager.py`` (atomic rename + keep-k + checksum).
* **Shared consistency checker** (:func:`reconcile_ownership`) — the
  refcount/ownership reconciliation that ``ServeEngine._run_audit`` runs
  every audit interval and that snapshot *load* runs before serving: a
  tampered or bit-rotted checkpoint fails loudly with the violated
  invariant named, never silently serves corrupt state.

Torn writes are handled at both ends: the checkpoint directory appears
atomically (manager), and ``Journal`` truncates a torn trailing record on
open, so a crash mid-append costs at most the unacked record being written.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.train.ft import Ewma

# sentinel marker for a fork stream resolved as a mirror of stream 0
# (engine._FORK_MIRROR is an object(); JSON needs a stable spelling)
_MIRROR_TAG = "__mirror__"


# -- write-ahead journal ------------------------------------------------------


class Journal:
    """Append-only, crash-tolerant JSONL journal.

    Record framing is one JSON object per ``\\n``-terminated line with a
    monotonically increasing ``seq``. On open, any torn tail (bytes after
    the last parsable newline-terminated record — a crash mid-append) is
    truncated so later appends can never merge into a half-written line;
    ``seq`` continues from the last good record. ``submit`` records are
    fsync'd (the ack must be durable); ``tick`` records are flushed only —
    a lost trailing tick record just means that tick replays live.
    """

    def __init__(self, path: str):
        self.path = path
        self.seq = 0
        self.bytes_written = 0
        if os.path.exists(path):
            with open(path, "rb") as f:
                raw = f.read()
            good_end, last_seq, pos = 0, -1, 0
            while True:
                nl = raw.find(b"\n", pos)
                if nl < 0:
                    break
                try:
                    rec = json.loads(raw[pos:nl])
                    last_seq = int(rec["seq"])
                except (ValueError, KeyError, TypeError):
                    break
                good_end = nl + 1
                pos = nl + 1
            if good_end < len(raw):
                with open(path, "r+b") as f:
                    f.truncate(good_end)
            self.seq = last_seq + 1
            self.bytes_written = good_end
        self._f = open(path, "a", encoding="utf-8")

    def _append(self, rec: Dict[str, Any], fsync: bool) -> int:
        rec["seq"] = self.seq
        line = json.dumps(rec, sort_keys=True) + "\n"
        data = line.encode("utf-8")
        self._f.write(line)
        self._f.flush()
        if fsync:
            os.fsync(self._f.fileno())
        self.seq += 1
        self.bytes_written += len(data)
        return len(data)

    def append_submit(self, *, uid: int, prompt: List[int], max_tokens: int,
                      temperature: Optional[float],
                      deadline_ticks: Optional[int], n_best: int,
                      tick: int) -> int:
        """Durably record one admission BEFORE it is acked. Returns bytes
        written (billed as durability write traffic)."""
        return self._append({
            "kind": "submit", "uid": uid, "prompt": prompt,
            "max_tokens": max_tokens, "temperature": temperature,
            "deadline_ticks": deadline_ticks, "n_best": n_best,
            "tick": tick}, fsync=True)

    def append_tick(self, *, tick: int,
                    finished: List[List[Any]]) -> int:
        """Record one completed tick and its finished streams
        (``[[uid, generated, nbest-or-null], ...]``). Every tick gets a
        record — even idle ones: fault schedules and deadline math key on
        the absolute tick index, so replay must count them."""
        return self._append({"kind": "tick", "tick": tick,
                             "finished": finished}, fsync=False)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def records(self) -> List[Dict[str, Any]]:
        """Parse the journal from disk, stopping at the first unparsable
        line (a torn tail that raced the truncating open)."""
        out: List[Dict[str, Any]] = []
        if not os.path.exists(self.path):
            return out
        with open(self.path, "rb") as f:
            for line in f:
                if not line.endswith(b"\n"):
                    break
                try:
                    out.append(json.loads(line))
                except ValueError:
                    break
        return out


# -- shared refcount/ownership reconciliation ---------------------------------


def reconcile_ownership(pool, slot_pages: List[List[int]],
                        spike_holds: List[Tuple[int, List[int]]]
                        ) -> List[str]:
    """Reconcile the engine's page-ownership mirrors against the pool's
    refcounts: every page the engine holds (slot page lists + injector
    spike holds) must carry at least that many pool references, and no
    slot may list a page twice. Returns violation strings (empty =
    consistent). Shared between the periodic chaos-tier audit
    (``ServeEngine._run_audit``) and snapshot load — one checker, so a
    bit-rotted checkpoint fails the SAME invariants a live corruption
    would."""
    violations: List[str] = []
    owned: Dict[int, int] = {}
    for slot, pages in enumerate(slot_pages):
        if len(set(pages)) != len(pages):
            violations.append(f"slot {slot} lists a page twice")
        for p in pages:
            owned[p] = owned.get(p, 0) + 1
    for _, pages in spike_holds:
        for p in pages:
            owned[p] = owned.get(p, 0) + 1
    for p in sorted(owned):
        n = owned[p]
        ref = pool.refcount(p)
        if ref < n:
            violations.append(
                f"page {p}: engine holds {n} refs, pool says {ref}")
    return violations


# -- request (de)serialization ------------------------------------------------


def request_to_dict(req) -> Dict[str, Any]:
    return {
        "uid": int(req.uid),
        "prompt": [int(t) for t in np.asarray(req.prompt).tolist()],
        "max_tokens": int(req.max_tokens),
        "temperature": req.temperature,
        "generated": [int(t) for t in req.generated],
        "done": bool(req.done),
        "deadline_ticks": req.deadline_ticks,
        "submit_tick": int(req.submit_tick),
        "n_best": int(req.n_best),
        "nbest": ([[int(t) for t in s] for s in req.nbest]
                  if req.nbest is not None else None),
        "fork_group": req.fork_group,
        "fork_idx": int(req.fork_idx),
    }


def request_from_dict(d: Dict[str, Any]):
    from repro.serve.engine import Request
    req = Request(
        int(d["uid"]), np.asarray(d["prompt"], np.int32),
        max_tokens=int(d["max_tokens"]), temperature=d["temperature"],
        deadline_ticks=d["deadline_ticks"],
        submit_tick=int(d["submit_tick"]), n_best=int(d["n_best"]),
        fork_group=d["fork_group"], fork_idx=int(d["fork_idx"]))
    req.generated = [int(t) for t in d["generated"]]
    req.done = bool(d["done"])
    if d["nbest"] is not None:
        req.nbest = [[int(t) for t in s] for s in d["nbest"]]
    return req


def _ewma_to_list(e: Ewma) -> List[Any]:
    return [e.value, int(e.n)]


def _ewma_from_list(v: List[Any], alpha: float) -> Ewma:
    e = Ewma(alpha=alpha)
    e.value = v[0]
    e.n = int(v[1])
    return e


# engine ServeConfig fields that must match between the snapshotting and
# restoring processes — a mismatch would silently change replay semantics
_FINGERPRINT_FIELDS = (
    "max_slots", "max_len", "eos_id", "temperature", "seed", "quant",
    "paged", "page_size", "num_pages", "prefix_cache", "prefill_chunk",
    "spec_k", "spec_drafter", "spec_tree_m", "compact_threshold",
    "evict_policy")


def config_fingerprint(scfg) -> Dict[str, Any]:
    fp = {f: getattr(scfg, f) for f in _FINGERPRINT_FIELDS}
    fp["cache_dtype"] = str(np.dtype(scfg.cache_dtype))
    return fp


def check_fingerprint(scfg, fp: Dict[str, Any]) -> None:
    """Refuse (RuntimeError naming the field) when a snapshot was taken
    under a different serve config. Runs BEFORE the device tree is
    touched — a shape mismatch must surface as a config diagnosis, not an
    array-loading error."""
    want = config_fingerprint(scfg)
    for field in want:
        if fp.get(field) != want[field]:
            raise RuntimeError(
                f"snapshot config mismatch: {field} = {fp.get(field)!r} "
                f"in snapshot, {want[field]!r} in this engine")


# -- engine host state --------------------------------------------------------


def host_state_dict(eng) -> Dict[str, Any]:
    """Everything the engine keeps host-side, as one JSON-able dict. The
    device tree (caches, slot arrays, RNG keys, page tables) travels
    separately through the checkpoint manager; this dict rides the
    manifest's ``extra`` field and is covered by the same checksum."""
    from repro.serve.engine import _FORK_MIRROR
    d: Dict[str, Any] = {
        "fingerprint": config_fingerprint(eng.scfg),
        "uid": int(eng._uid),
        "tick_idx": int(eng._tick_idx),
        "cur_spec_k": int(eng._cur_spec_k),
        "fell_back": bool(eng._fell_back),
        "fit_checked": sorted(int(u) for u in eng._fit_checked),
        "queue": [request_to_dict(r) for r in eng.scheduler.pending],
        "slot_req": [request_to_dict(r) if r is not None else None
                     for r in eng.slot_req],
        "host_gen": [int(g) for g in eng._host_gen],
        "slot_pages": [[int(p) for p in pages]
                       for pages in eng._slot_pages],
        "prefilling": {
            str(slot): {"plen": int(w["plen"]), "next": int(w["next"]),
                        "blocks": [[int(t) for t in b]
                                   for b in w["blocks"]]}
            for slot, w in eng._prefilling.items()},
        "fork_wait": {str(k): int(v) for k, v in eng._fork_wait.items()},
        "fork_children": {str(k): [int(x) for x in v]
                          for k, v in eng._fork_children.items()},
        "fork_groups": {
            str(gid): {
                "req": request_to_dict(g["req"]), "k": int(g["k"]),
                "streams": {
                    str(i): (_MIRROR_TAG if s is _FORK_MIRROR
                             else [int(t) for t in s])
                    for i, s in g["streams"].items()}}
            for gid, g in eng._fork_groups.items()},
        "recovery": {
            str(uid): {
                "prompt": [int(t)
                           for t in np.asarray(rec["prompt"]).tolist()],
                "max_tokens": int(rec["max_tokens"]),
                "tokens": [int(t) for t in rec["tokens"]]}
            for uid, rec in eng._recovery.items()},
        "recovering": sorted(int(u) for u in eng._recovering),
        "defer_counts": {str(k): int(v)
                         for k, v in eng._defer_counts.items()},
        "retry_after": {str(k): int(v)
                        for k, v in eng._retry_after.items()},
        "spike_holds": [[int(exp), [int(p) for p in pages]]
                        for exp, pages in eng._spike_holds],
        "ewmas": {"wall": _ewma_to_list(eng._tick_wall_ewma),
                  "accept": _ewma_to_list(eng._accept_ewma),
                  "drift": _ewma_to_list(eng._drift_ewma)},
        "compact_pause_until": int(eng._compact_pause_until),
        "drift_rr": int(eng._drift_rr),
        "restore_boundary": int(eng._restore_boundary),
        "counters": {
            "n_quarantined": eng.n_quarantined,
            "n_shed": eng.n_shed,
            "n_finished_ok": eng.n_finished_ok,
            "spec_backoffs": eng.spec_backoffs,
            "fp_fallbacks": eng.fp_fallbacks,
            "compaction_pauses": eng.compaction_pauses,
            "audit_failures": eng.audit_failures,
            "readback_retries_total": eng.readback_retries_total},
        "audit_log": list(eng.audit_log),
        "durability": {
            "snapshots_taken": eng.snapshots_taken,
            "snapshot_bytes_total": eng.snapshot_bytes_total,
            "journal_bytes_total": eng.journal_bytes_total,
            "replayed_ticks": eng.replayed_ticks,
            "restore_flops": eng.restore_flops,
            "restore_bytes": eng.restore_bytes},
        "metrics_log": [dataclasses.asdict(m) for m in eng.metrics_log],
        "pool": eng.pool.state_dict() if eng.pool is not None else None,
        "injector": None,
        "accountant": (eng.accountant.state_dict()
                       if eng.accountant is not None else None),
    }
    if eng._injector is not None:
        d["injector"] = {
            "counts": dict(eng._injector.counts),
            "rng_state": eng._injector._rng.bit_generator.state}
    return d


def install_host_state(eng, d: Dict[str, Any]) -> None:
    """Inverse of :func:`host_state_dict`: rebuild every host mirror on a
    freshly constructed engine whose device tree was just restored. Raises
    RuntimeError (naming the mismatch) when the snapshot was taken under a
    different serve config — replaying it here would not be the same
    engine."""
    from repro.serve.engine import _FORK_MIRROR, StepMetrics
    check_fingerprint(eng.scfg, d["fingerprint"])
    eng._uid = int(d["uid"])
    eng._tick_idx = int(d["tick_idx"])
    eng._cur_spec_k = int(d["cur_spec_k"])
    eng._fell_back = bool(d["fell_back"])
    eng._fit_checked = set(int(u) for u in d["fit_checked"])
    eng.scheduler.load([request_from_dict(r) for r in d["queue"]])
    eng.slot_req = [request_from_dict(r) if r is not None else None
                    for r in d["slot_req"]]
    eng._host_gen = [int(g) for g in d["host_gen"]]
    eng._slot_pages = [[int(p) for p in pages]
                       for pages in d["slot_pages"]]
    # _prefilling["req"]/["pages"] alias slot_req/_slot_pages in the live
    # engine (one object, two views) — relink instead of re-deserializing
    eng._prefilling = {}
    for slot_s, w in d["prefilling"].items():
        slot = int(slot_s)
        eng._prefilling[slot] = {
            "req": eng.slot_req[slot], "plen": int(w["plen"]),
            "next": int(w["next"]),
            "blocks": [tuple(int(t) for t in b) for b in w["blocks"]],
            "pages": eng._slot_pages[slot]}
    eng._fork_wait = {int(k): int(v) for k, v in d["fork_wait"].items()}
    eng._fork_children = {int(k): [int(x) for x in v]
                          for k, v in d["fork_children"].items()}
    # fork-group parents alias the slot/queue request carrying their uid
    by_uid: Dict[int, Any] = {}
    for r in list(eng.scheduler.pending) + [r for r in eng.slot_req
                                            if r is not None]:
        by_uid.setdefault(r.uid, r)
    eng._fork_groups = {}
    for gid_s, g in d["fork_groups"].items():
        req = by_uid.get(int(g["req"]["uid"]))
        if req is None:
            req = request_from_dict(g["req"])
        eng._fork_groups[int(gid_s)] = {
            "req": req, "k": int(g["k"]),
            "streams": {
                int(i): (_FORK_MIRROR if s == _MIRROR_TAG
                         else [int(t) for t in s])
                for i, s in g["streams"].items()}}
    eng._recovery = {
        int(uid): {"prompt": np.asarray(rec["prompt"], np.int32),
                   "max_tokens": int(rec["max_tokens"]),
                   "tokens": [int(t) for t in rec["tokens"]]}
        for uid, rec in d["recovery"].items()}
    eng._recovering = set(int(u) for u in d["recovering"])
    eng._defer_counts = {int(k): int(v)
                         for k, v in d["defer_counts"].items()}
    eng._retry_after = {int(k): int(v)
                        for k, v in d["retry_after"].items()}
    eng._spike_holds = [(int(exp), [int(p) for p in pages])
                        for exp, pages in d["spike_holds"]]
    alpha = eng.guard.ewma_alpha
    eng._tick_wall_ewma = _ewma_from_list(d["ewmas"]["wall"], alpha)
    eng._accept_ewma = _ewma_from_list(d["ewmas"]["accept"], alpha)
    eng._drift_ewma = _ewma_from_list(d["ewmas"]["drift"], alpha)
    eng._compact_pause_until = int(d["compact_pause_until"])
    eng._drift_rr = int(d["drift_rr"])
    eng._restore_boundary = int(d["restore_boundary"])
    c = d["counters"]
    eng.n_quarantined = int(c["n_quarantined"])
    eng.n_shed = int(c["n_shed"])
    eng.n_finished_ok = int(c["n_finished_ok"])
    eng.spec_backoffs = int(c["spec_backoffs"])
    eng.fp_fallbacks = int(c["fp_fallbacks"])
    eng.compaction_pauses = int(c["compaction_pauses"])
    eng.audit_failures = int(c["audit_failures"])
    eng.readback_retries_total = int(c["readback_retries_total"])
    eng.audit_log = list(d["audit_log"])
    dur = d["durability"]
    eng.snapshots_taken = int(dur["snapshots_taken"])
    eng.snapshot_bytes_total = float(dur["snapshot_bytes_total"])
    eng.journal_bytes_total = float(dur["journal_bytes_total"])
    eng.replayed_ticks = int(dur["replayed_ticks"])
    eng.restore_flops = float(dur["restore_flops"])
    eng.restore_bytes = float(dur["restore_bytes"])
    eng.metrics_log = [StepMetrics(**m) for m in d["metrics_log"]]
    eng.last_metrics = eng.metrics_log[-1] if eng.metrics_log else None
    if d["pool"] is not None:
        if eng.pool is None:
            raise RuntimeError("snapshot config mismatch: snapshot is "
                               "paged, this engine is dense")
        eng.pool.load_state(d["pool"])
    if d["injector"] is not None:
        if eng._injector is None:
            raise RuntimeError(
                "snapshot config mismatch: snapshot carries fault-injector "
                "state but this engine has no fault plan")
        eng._injector.counts = {k: int(v)
                                for k, v in d["injector"]["counts"].items()}
        eng._injector._rng.bit_generator.state = d["injector"]["rng_state"]
    if d["accountant"] is not None and eng.accountant is not None:
        eng.accountant.load_state(d["accountant"])
