"""Speculative multi-token decode for the paged serve core (DESIGN.md §15).

The paper's operational-energy argument is a DRAM-bytes argument: every
decode tick streams the whole weight tree from HBM to emit ONE token per
slot. Speculative decoding amortizes that stream — a cheap drafter
proposes ``k`` tokens per slot, and a single multi-query verification
pass scores all ``k`` positions at once, so one weight fetch can commit
up to ``k + 1`` tokens. Rejected positions cost only their (already
masked-out) cache writes: the sink-page design and the ``pos < length``
validity invariant mean rollback is a per-slot length rewind, with no
device-side scrub.

This module is the *device-side policy* half: drafters and the
accept/rewind math. Both are pure jittable functions the engine fuses
into its tick; the verification forward itself lives in
``models/transformer.paged_verify_step``.

Drafters:

* ``ngram_draft`` — prompt-lookup decoding (self-drafting without a draft
  model): match the slot's trailing bigram against its own token history
  (prompt + everything generated) and propose the continuation of the
  most recent earlier occurrence. Near-zero cost (one history scan, no
  weights), and effective exactly when decode is repetitive — which is
  also when the energy win is largest.
* the ``"oracle"`` drafter (engine-side) runs the target model itself
  greedily for ``k`` steps — an accept-everything harness for parity
  tests and an upper bound on acceptance, not an energy win.

Acceptance (``speculative_accept``) preserves the target distribution:
at temperature 0 the emitted stream is *exactly* the plain greedy stream
(accept iff the draft equals the verify-pass argmax; the first rejection
emits the argmax instead). At temperature > 0 the drafter is a point
mass, so standard speculative rejection sampling reduces to: accept
draft ``d`` with probability ``p(d)``, else resample from ``p`` with
``d`` removed (the renormalized residual ``max(p - q, 0)``) — the
marginal of each emitted token is the target softmax.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

DRAFTERS = ("ngram", "oracle")


def ngram_draft(hist: jnp.ndarray, pos: jnp.ndarray, k: int) -> jnp.ndarray:
    """Prompt-lookup drafter: propose ``k`` tokens per slot from the slot's
    own token history.

    ``hist`` (B, L) int32 — per-slot token history, valid through ``pos``
    inclusive (``hist[b, pos[b]]`` is the slot's *pending* token: sampled,
    not yet in the KV cache). ``pos`` (B,) int32. Rows whose trailing
    bigram ``(hist[pos-1], hist[pos])`` occurred earlier in the history
    draft the ``k`` tokens that followed the most recent occurrence
    (clamped at ``pos`` — a near-end match pads by repeating); rows with
    no match repeat the pending token (cheap, usually rejected, costs one
    verify lane). Inactive rows produce garbage the engine masks off.
    """
    b, length = hist.shape
    rows = jnp.arange(b)
    pend = hist[rows, pos]
    prev = hist[rows, jnp.maximum(pos - 1, 0)]
    p_idx = jnp.arange(length - 1, dtype=jnp.int32)
    # occurrence at p matches the trailing bigram and ends strictly before
    # it (p + 1 <= pos - 1), so the continuation starts at a valid index
    match = ((hist[:, :-1] == prev[:, None])
             & (hist[:, 1:] == pend[:, None])
             & (p_idx[None] <= (pos - 2)[:, None]))
    best = jnp.max(jnp.where(match, p_idx[None], -1), axis=1)     # (B,)
    start = jnp.where(best >= 0, best + 2, pos)   # no match -> repeat pending
    idx = jnp.minimum(start[:, None] + jnp.arange(k, dtype=jnp.int32)[None],
                      pos[:, None])
    return jnp.take_along_axis(hist, idx, axis=1).astype(jnp.int32)


def ngram_draft_tree(hist: jnp.ndarray, pos: jnp.ndarray, k: int, m: int
                     ) -> jnp.ndarray:
    """Tree drafter (DESIGN.md §18): ``m`` independent ``k``-token branches
    per slot from the ``m`` most recent occurrences of the trailing bigram.

    Branch 0 is *exactly* ``ngram_draft`` (the most recent match), so tree
    speculation degenerates to the linear drafter at ``m == 1`` and branch
    0's stream is the linear stream bit-for-bit. Later branches take the
    next-most-recent matches — a repetitive history usually continues like
    one of its recent occurrences, but not always the most recent one, and
    verifying several candidate continuations in one multi-query pass costs
    no extra weight traffic. Slots with fewer than ``m`` matches pad the
    tail branches by repeating the pending token (cheap, rejected lanes).
    Returns (B, M, K) int32; inactive rows produce garbage the engine
    masks off.
    """
    b, length = hist.shape
    rows = jnp.arange(b)
    pend = hist[rows, pos]
    prev = hist[rows, jnp.maximum(pos - 1, 0)]
    p_idx = jnp.arange(length - 1, dtype=jnp.int32)
    match = ((hist[:, :-1] == prev[:, None])
             & (hist[:, 1:] == pend[:, None])
             & (p_idx[None] <= (pos - 2)[:, None]))
    # m most recent match positions, descending (-1 pads short match lists)
    ranked = -jnp.sort(jnp.where(match, -p_idx[None], 1), axis=1)[:, :m]
    starts = jnp.where(ranked >= 0, ranked + 2, pos[:, None])   # (B, M)
    idx = jnp.minimum(
        starts[:, :, None] + jnp.arange(k, dtype=jnp.int32)[None, None],
        pos[:, None, None])                                     # (B, M, K)
    return jnp.take_along_axis(hist[:, None].repeat(m, axis=1), idx,
                               axis=2).astype(jnp.int32)


def speculative_accept(logits: jnp.ndarray, drafts: jnp.ndarray,
                       keys: jnp.ndarray, temp: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Accept/reject ``k`` drafted tokens against the verification logits.

    ``logits`` (B, K+1, V) fp32 — position ``j``'s row is the target
    distribution for the token *after* draft ``j`` tokens were consumed
    (row 0: after the committed pending token; row K: the bonus position).
    ``drafts`` (B, K); ``keys`` (B, 2) per-slot PRNG; ``temp`` (B,)
    per-slot temperature (0 = greedy).

    Returns ``(n_acc, fix_tok, new_keys)``: ``n_acc`` (B,) int32 in
    [0, K] — length of the accepted draft prefix; ``fix_tok`` (B,) — the
    token emitted at the first rejected position (greedy: the argmax;
    temperature: a draw from the renormalized residual), or the bonus
    token when every draft was accepted. The emitted stream for a slot is
    ``drafts[:n_acc] + [fix_tok]``. Keys advance only for temperature
    slots (greedy consumes no randomness), mirroring the plain tick.
    """
    b, k1, _ = logits.shape
    k = k1 - 1
    rows = jnp.arange(b)
    use_t = temp > 0
    tsafe = jnp.where(use_t, temp, 1.0)
    accepting = jnp.ones(b, bool)
    n_acc = jnp.zeros(b, jnp.int32)
    fix = jnp.zeros(b, jnp.int32)
    for j in range(k):
        lj = logits[:, j]
        greedy = jnp.argmax(lj, axis=-1).astype(jnp.int32)
        d = drafts[:, j]
        split = jax.vmap(lambda kk: jax.random.split(kk, 3))(keys)  # (B,3,2)
        k_next, k_u, k_res = split[:, 0], split[:, 1], split[:, 2]
        p = jax.nn.softmax(lj / tsafe[:, None], axis=-1)
        p_d = jnp.take_along_axis(p, d[:, None], axis=-1)[:, 0]
        u = jax.vmap(jax.random.uniform)(k_u)
        # point-mass draft: accept w.p. p(d); residual max(p - q, 0) is p
        # with the draft token zeroed (categorical renormalizes)
        res = p.at[rows, d].set(0.0)
        res_tok = jax.vmap(jax.random.categorical)(
            k_res, jnp.log(jnp.maximum(res, 1e-30))).astype(jnp.int32)
        acc = jnp.where(use_t, u < p_d, d == greedy)
        corr = jnp.where(use_t, res_tok, greedy)
        # only slots still inside their accepted prefix consume this draw
        keys = jnp.where((use_t & accepting)[:, None], k_next, keys)
        fix = jnp.where(accepting & ~acc, corr, fix)
        n_acc = n_acc + (accepting & acc)
        accepting &= acc
    # bonus position: every draft accepted -> sample one more from row K
    lb = logits[:, k]
    split = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
    bonus_keys, sub = split[:, 0], split[:, 1]
    greedy_b = jnp.argmax(lb, axis=-1).astype(jnp.int32)
    sampled_b = jax.vmap(jax.random.categorical)(
        sub, lb / tsafe[:, None]).astype(jnp.int32)
    bonus = jnp.where(use_t, sampled_b, greedy_b)
    keys = jnp.where((use_t & accepting)[:, None], bonus_keys, keys)
    fix = jnp.where(accepting, bonus, fix)
    return n_acc, fix, keys
