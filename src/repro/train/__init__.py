"""Training runtime: step builder, Trainer with FT hooks, elastic utilities,
and the device-resident fused TrainEngine (DESIGN.md §13)."""

from repro.train.loop import TrainConfig, Trainer, make_train_step  # noqa: F401
from repro.train.engine import (TrainEngine, TrainEngineConfig,  # noqa: F401
                                TrainStepMetrics)
