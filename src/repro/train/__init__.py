"""Training runtime: step builder, Trainer with FT hooks, elastic utilities."""

from repro.train.loop import TrainConfig, Trainer, make_train_step  # noqa: F401
