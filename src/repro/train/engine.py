"""Device-resident training engine: the serve core's discipline for training.

One jitted **train tick** does everything on device: forward, backward
(through the custom-VJP Pallas kernels when the model config routes
attention through them — DESIGN.md §13), the AdamW update, and metric
accumulation, scanned over ``steps_per_tick`` optimizer steps. Params and
optimizer state are donated and never leave the device; the host stages one
stacked batch block per tick and reads back ONE compact metrics pytree
(per-step loss/grad-norm/lr) per tick — not per step. Step time is therefore
a property of the hardware, not of Python dispatch, loss-readback syncs, or
per-step batch staging (the host-loop Trainer in train/loop.py is exactly
that baseline, and stays on as the correctness oracle and the benchmark's
"before").

Every tick produces a :class:`TrainStepMetrics` billed to the
CarbonAccountant's *training* ledger: forward and backward FLOPs/bytes land
in separate phase accounts (models/costing.py is the shared cost model), so
J/step and J/sample — with the backward phase reported separately — sit next
to the serve path's J/token.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting, energy
from repro.optim import AdamWConfig, apply_updates, init_opt_state

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Dict]]


@dataclasses.dataclass
class TrainEngineConfig:
    # optimizer steps fused into one jitted tick (the scan length): Python
    # dispatch, donation bookkeeping, and the metrics readback amortize over
    # this many steps
    steps_per_tick: int = 8
    donate: bool = True
    # route full-sequence attention through the custom-VJP flash Pallas
    # kernel (kernels/flash_attention.py). None = auto: on for TPU backends,
    # off elsewhere (interpret mode is correctness-only). Only meaningful
    # via for_lm(), which stamps it into the model config.
    use_flash_vjp: Optional[bool] = None


@dataclasses.dataclass
class TrainStepMetrics:
    """What one train tick did — the unit core/accounting.py bills.

    The modeled phase terms come from the engine's TrainStepCost (one
    step's cost scaled by ``steps``); forward and backward stay separate so
    the accountant can report per-phase energy (DESIGN.md §13).
    """
    steps: int                  # optimizer steps in this tick
    tokens: int                 # label tokens consumed
    samples: int                # sequences consumed
    wall_s: float               # host wall time of the tick (incl. staging)
    loss: float                 # last step's loss
    loss_mean: float            # mean loss over the tick
    grad_norm: float            # last step's global grad norm
    fwd_flops: float = 0.0
    bwd_flops: float = 0.0
    fwd_bytes: float = 0.0
    bwd_bytes: float = 0.0
    opt_bytes: float = 0.0

    @property
    def flops(self) -> float:
        return self.fwd_flops + self.bwd_flops

    @property
    def bytes_moved(self) -> float:
        return self.fwd_bytes + self.bwd_bytes + self.opt_bytes


# TrainStepMetrics fields that are deliberately NOT energy channels —
# training-quality telemetry (loss curves, gradient norms) with no joule
# interpretation. Everything else MUST be billed in
# CarbonAccountant.observe_train; the accounting-completeness lint pass
# (repro-lint L401, DESIGN.md §20) fails CI otherwise.
TRAIN_ACCOUNTING_EXEMPT = frozenset({"loss", "loss_mean", "grad_norm"})


class TrainEngine:
    def __init__(self, *, loss_fn: LossFn, params: PyTree,
                 opt_cfg: AdamWConfig,
                 engine_cfg: Optional[TrainEngineConfig] = None,
                 pipeline=None,
                 accountant: Optional[accounting.CarbonAccountant] = None,
                 cost: Optional[energy.TrainStepCost] = None,
                 jit_kwargs: Optional[dict] = None):
        self.loss_fn = loss_fn
        self.params = params
        self.opt_cfg = opt_cfg
        self.cfg = engine_cfg or TrainEngineConfig()
        self.pipeline = pipeline
        self.accountant = accountant
        self.cost = cost
        self.opt_state = init_opt_state(params, opt_cfg)
        self.step_num = 0
        self.last_metrics: Optional[TrainStepMetrics] = None
        self.metrics_log: List[TrainStepMetrics] = []
        # instrumentation (tests assert the tick stays fused: one trace per
        # scan length, one host readback per tick)
        self.tick_trace_count = 0
        self.host_readbacks = 0
        self._build_tick(jit_kwargs)

    @classmethod
    def for_lm(cls, params: PyTree, cfg, *, opt_cfg: AdamWConfig,
               pipeline, engine_cfg: Optional[TrainEngineConfig] = None,
               accountant: Optional[accounting.CarbonAccountant] = None,
               jit_kwargs: Optional[dict] = None) -> "TrainEngine":
        """LM-aware constructor: stamps the flash-VJP routing into the model
        config, builds the loss closure, and derives the per-step cost model
        from the live param/opt-state trees."""
        from repro.models import costing
        from repro.models import transformer as tf_lib
        ecfg = engine_cfg or TrainEngineConfig()
        use_flash = ecfg.use_flash_vjp
        if use_flash is None:
            use_flash = jax.default_backend() == "tpu"
        mcfg = dataclasses.replace(cfg, flash_train=bool(use_flash))

        def loss_fn(p, batch):
            return tf_lib.loss_fn(p, mcfg, batch)

        eng = cls(loss_fn=loss_fn, params=params, opt_cfg=opt_cfg,
                  engine_cfg=ecfg, pipeline=pipeline, accountant=accountant,
                  jit_kwargs=jit_kwargs)
        eng.model_cfg = mcfg
        if pipeline is not None:
            eng.cost = costing.lm_train_step_cost(
                params, mcfg, batch=pipeline.cfg.local_batch,
                seq_len=pipeline.cfg.seq_len, opt_state=eng.opt_state)
        return eng

    # -- compiled path --------------------------------------------------------

    def _build_tick(self, jit_kwargs: Optional[dict]) -> None:
        loss_fn, opt_cfg = self.loss_fn, self.opt_cfg

        def tick(params, opt_state, batches):
            self.tick_trace_count += 1      # python side effect: trace count

            def one(carry, batch):
                p, s = carry
                (loss, _aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(p, batch)
                p, s, om = apply_updates(p, grads, s, opt_cfg)
                out = {"loss": loss, "grad_norm": om["grad_norm"],
                       "lr": om["lr"]}
                return (p, s), out

            (params, opt_state), ms = jax.lax.scan(
                one, (params, opt_state), batches)
            return params, opt_state, ms

        kwargs = dict(jit_kwargs or {})
        if self.cfg.donate:
            kwargs.setdefault("donate_argnums", (0, 1))
        self._tick = jax.jit(tick, **kwargs)

    # -- host loop ------------------------------------------------------------

    def _stage(self, start: int, k: int) -> Tuple[Dict[str, jnp.ndarray],
                                                  int, int]:
        """Stack pipeline batches [start, start+k) into one (k, ...) block."""
        batches = [self.pipeline.batch_at(start + i) for i in range(k)]
        stacked = {key: jnp.asarray(np.stack([b[key] for b in batches]))
                   for key in batches[0]}
        tok = batches[0].get("labels", batches[0].get("tokens"))
        samples = k * int(tok.shape[0])
        tokens = k * int(tok.size)
        return stacked, tokens, samples

    def run(self, num_steps: int) -> Dict[str, float]:
        """Run ``num_steps`` optimizer steps in fused ticks.

        Staging is double-buffered: tick i+1's batch block is synthesized
        and staged while the device is still crunching tick i (dispatch is
        async; the metrics readback is the only sync point, after staging).
        The host-loop Trainer pays stage -> dispatch -> sync serially every
        step; here the pipeline cost hides behind device compute.
        """
        assert self.pipeline is not None, "run() needs a pipeline"
        if num_steps <= 0:
            return {}
        plan: List[int] = []
        left = num_steps
        while left > 0:
            k = min(self.cfg.steps_per_tick, left)
            plan.append(k)
            left -= k
        last: Dict[str, float] = {}
        t_prev = time.monotonic()
        staged = self._stage(self.step_num, plan[0])
        for i, k in enumerate(plan):
            batches, tokens, samples = staged
            self.params, self.opt_state, ms = self._tick(
                self.params, self.opt_state, batches)
            if i + 1 < len(plan):   # overlap: stage while the device runs
                staged = self._stage(self.step_num + k, plan[i + 1])
            ms_host = jax.device_get(ms)    # the ONE per-tick readback
            self.host_readbacks += 1
            now = time.monotonic()
            wall = now - t_prev
            t_prev = now
            self.step_num += k
            self.pipeline.restore({"step": self.step_num})
            c = (self.cost.scaled(k) if self.cost is not None
                 else energy.TrainStepCost(0.0, 0.0, 0.0, 0.0))
            m = TrainStepMetrics(
                steps=k, tokens=tokens, samples=samples, wall_s=wall,
                loss=float(ms_host["loss"][-1]),
                loss_mean=float(np.mean(ms_host["loss"])),
                grad_norm=float(ms_host["grad_norm"][-1]),
                fwd_flops=c.fwd_flops, bwd_flops=c.bwd_flops,
                fwd_bytes=c.fwd_bytes, bwd_bytes=c.bwd_bytes,
                opt_bytes=c.opt_bytes)
            self.last_metrics = m
            self.metrics_log.append(m)
            if self.accountant is not None:
                self.accountant.observe_train(m)
            last = {"loss": m.loss, "grad_norm": m.grad_norm,
                    "lr": float(ms_host["lr"][-1]),
                    "step": float(self.step_num)}
        return last

    # -- aggregate metrics ----------------------------------------------------

    def summary(self) -> Dict[str, float]:
        steps = sum(m.steps for m in self.metrics_log)
        wall = sum(m.wall_s for m in self.metrics_log)
        return {"ticks": len(self.metrics_log),
                "steps": steps,
                "tokens": sum(m.tokens for m in self.metrics_log),
                "wall_s": wall,
                "steps_per_s": steps / wall if wall > 0 else 0.0,
                "s_per_step": wall / steps if steps else 0.0}
