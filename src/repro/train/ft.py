"""Fleet fault-tolerance: heartbeats, straggler detection, elastic planning.

At thousands of nodes the failure modes the launcher must absorb are:
  * **dead host** — heartbeat older than ``dead_after_s`` -> exclude, replan;
  * **straggler** — step time EWMA > ``straggler_factor`` x fleet median ->
    flag; policy: warn first, exclude after ``strikes`` consecutive flags
    (hot-spare swap on a real fleet);
  * **shrink/grow** — ElasticPlanner picks the largest valid mesh from the
    healthy host set (model-parallel degree fixed by the arch; DP shrinks),
    the checkpoint reshards on restore (checkpoint.manager), and the data
    pipeline re-slices deterministically (data.pipeline.reshard).

Everything is plain files + math — simulated multi-host tests drive it
(tests/test_ft.py).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Ewma:
    """Exponentially-weighted moving average with the heartbeat smoothing
    convention (``alpha`` is the weight on history, first observation
    seeds the average). Shared by the fleet straggler detector below and
    the serve engine's tick-latency / accept-rate / numerics-drift
    monitors (serve/faults.py, DESIGN.md §17) so every "is this run
    degrading" question uses the same estimator."""
    alpha: float = 0.9
    value: Optional[float] = None
    n: int = 0

    def update(self, x: float) -> float:
        self.n += 1
        if self.value is None:
            self.value = float(x)
        else:
            self.value = self.alpha * self.value + (1 - self.alpha) * float(x)
        return self.value


@dataclasses.dataclass
class HostStatus:
    host_id: str
    step: int
    step_time_ewma: float
    last_beat: float           # unix time

    def age(self, now: Optional[float] = None) -> float:
        return (now if now is not None else time.time()) - self.last_beat


class HeartbeatWriter:
    """Each host writes {host_id}.json on every step."""

    def __init__(self, directory: str, host_id: str, ewma: float = 0.9):
        self.dir = directory
        self.host_id = host_id
        self.ewma = ewma
        self._ewma = Ewma(alpha=ewma)
        os.makedirs(directory, exist_ok=True)

    @property
    def _step_time(self) -> Optional[float]:
        return self._ewma.value

    @_step_time.setter
    def _step_time(self, value: Optional[float]) -> None:
        self._ewma.value = value

    def beat(self, step: int, step_time_s: float,
             now: Optional[float] = None) -> None:
        self._ewma.update(step_time_s)
        payload = {"host_id": self.host_id, "step": step,
                   "step_time_ewma": self._step_time,
                   "last_beat": now if now is not None else time.time()}
        tmp = os.path.join(self.dir, f".{self.host_id}.tmp")
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, os.path.join(self.dir, f"{self.host_id}.json"))


@dataclasses.dataclass
class MonitorConfig:
    dead_after_s: float = 60.0
    straggler_factor: float = 2.0
    strikes_to_exclude: int = 3


class HealthMonitor:
    """Coordinator-side view over the heartbeat directory."""

    def __init__(self, directory: str, cfg: MonitorConfig = MonitorConfig()):
        self.dir = directory
        self.cfg = cfg
        self._strikes: Dict[str, int] = {}

    def read(self) -> Dict[str, HostStatus]:
        out = {}
        if not os.path.isdir(self.dir):
            return out
        for name in os.listdir(self.dir):
            if not name.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.dir, name)) as f:
                    d = json.load(f)
                out[d["host_id"]] = HostStatus(**d)
            except (json.JSONDecodeError, KeyError, TypeError):
                continue   # torn read of a non-atomic writer; skip this cycle
        return out

    def assess(self, now: Optional[float] = None
               ) -> Tuple[List[str], List[str], List[str]]:
        """-> (healthy, dead, stragglers) host-id lists."""
        statuses = self.read()
        now = now if now is not None else time.time()
        dead = [h for h, s in statuses.items()
                if s.age(now) > self.cfg.dead_after_s]
        alive = {h: s for h, s in statuses.items() if h not in dead}
        stragglers: List[str] = []
        if len(alive) >= 2:
            times = sorted(s.step_time_ewma for s in alive.values())
            median = times[len(times) // 2]
            for h, s in alive.items():
                if s.step_time_ewma > self.cfg.straggler_factor * median:
                    self._strikes[h] = self._strikes.get(h, 0) + 1
                    if self._strikes[h] >= self.cfg.strikes_to_exclude:
                        stragglers.append(h)
                else:
                    self._strikes[h] = 0
        healthy = [h for h in alive if h not in stragglers]
        return sorted(healthy), sorted(dead), sorted(stragglers)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    n_hosts_used: int
    dp_size: int
    restart_required: bool


class ElasticPlanner:
    """Choose the largest valid mesh from the healthy host set.

    The model axis is fixed by the architecture (TP degree must divide
    heads/ffn); DP absorbs all elasticity. Pods shrink to 1 when the healthy
    set no longer fills a pod.
    """

    def __init__(self, chips_per_host: int, model_parallel: int,
                 chips_per_pod: int = 256):
        self.chips_per_host = chips_per_host
        self.model_parallel = model_parallel
        self.chips_per_pod = chips_per_pod

    def plan(self, n_healthy_hosts: int,
             current: Optional[ElasticPlan] = None) -> ElasticPlan:
        chips = n_healthy_hosts * self.chips_per_host
        mp = self.model_parallel
        if chips < mp:
            raise RuntimeError(
                f"{chips} chips cannot fit model-parallel degree {mp}")
        pods = max(chips // self.chips_per_pod, 1)
        per_pod = chips // pods
        dp = per_pod // mp
        while dp < 1 and pods > 1:
            pods -= 1
            per_pod = chips // pods
            dp = per_pod // mp
        if pods > 1:
            shape: Tuple[int, ...] = (pods, dp, mp)
            axes: Tuple[str, ...] = ("pod", "data", "model")
        else:
            shape = (dp, mp)
            axes = ("data", "model")
        used_hosts = (pods * dp * mp) // self.chips_per_host
        restart = current is None or shape != current.mesh_shape
        return ElasticPlan(shape, axes, used_hosts, pods * dp, restart)
