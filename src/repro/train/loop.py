"""Training loop: step builder (grad-accum, remat, mixed precision) + Trainer.

The Trainer wires together every FT feature:
  * CheckpointManager (atomic/async/keep-k) with auto-resume-latest,
  * data-pipeline state in the checkpoint manifest (exact stream replay),
  * heartbeat + straggler detection hooks (train.ft),
  * SIGTERM-preemption -> synchronous final checkpoint,
  * CarbonAccountant observation per step (the paper's holistic accounting,
    live in the loop).

``make_train_step`` builds the pure step function; distribution is supplied
by jitting it with shardings from parallel.sharding (see launch/train.py for
the mesh-scale path; the Trainer itself also runs single-device for the
examples).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accounting
from repro.checkpoint import CheckpointManager, CheckpointConfig
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.train import ft as ft_lib

PyTree = Any
LossFn = Callable[[PyTree, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Dict]]


@dataclasses.dataclass
class TrainConfig:
    num_steps: int = 100
    grad_accum: int = 1
    log_every: int = 10
    checkpoint_every: int = 100
    seed: int = 0
    donate: bool = True


def make_train_step(loss_fn: LossFn, opt_cfg: AdamWConfig,
                    grad_accum: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With grad_accum > 1, batch leading dim must be (grad_accum * mb) and is
    scanned in microbatches (activation memory / overlap knob).
    """

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, grads

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, aux, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                acc, loss_sum = carry
                loss, _aux, grads = grads_of(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
                return (acc, loss_sum + loss), None

            micro_batch = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, 0.0), micro_batch)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            aux = {}
        new_params, new_state, opt_metrics = apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **opt_metrics}
        if isinstance(aux, dict):
            metrics.update({k: v for k, v in aux.items()
                            if jnp.ndim(v) == 0})
        return new_params, new_state, metrics

    return step


class Trainer:
    def __init__(self, *, loss_fn: LossFn, params: PyTree,
                 opt_cfg: AdamWConfig, train_cfg: TrainConfig,
                 pipeline, ckpt_cfg: Optional[CheckpointConfig] = None,
                 accountant: Optional[accounting.CarbonAccountant] = None,
                 heartbeat: Optional[ft_lib.HeartbeatWriter] = None,
                 jit_kwargs: Optional[dict] = None):
        self.loss_fn = loss_fn
        self.params = params
        self.opt_cfg = opt_cfg
        self.cfg = train_cfg
        self.pipeline = pipeline
        self.opt_state = init_opt_state(params, opt_cfg)
        self.accountant = accountant
        self.heartbeat = heartbeat
        self.ckpt = CheckpointManager(ckpt_cfg) if ckpt_cfg else None
        self.step_num = 0
        self.metrics_log: list = []
        self._preempted = False
        step = make_train_step(loss_fn, opt_cfg, train_cfg.grad_accum)
        kwargs = dict(jit_kwargs or {})
        if train_cfg.donate:
            kwargs.setdefault("donate_argnums", (0, 1))
        self._jit_step = jax.jit(step, **kwargs)

    # -- FT ---------------------------------------------------------------------

    def install_preemption_handler(self) -> None:
        def _handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, _handler)
        except ValueError:
            pass  # not on main thread (tests) — caller sets _preempted directly

    def maybe_restore(self) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        step, restored, extra = self.ckpt.restore(target=tree)
        self.params, self.opt_state = restored["params"], restored["opt"]
        self.step_num = step
        if "data_state" in extra:
            self.pipeline.restore(extra["data_state"])
        return True

    def save(self, wait: bool = False) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step_num,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"data_state": self.pipeline.state})
        if wait:
            self.ckpt.wait()

    # -- loop ---------------------------------------------------------------------

    def run(self, num_steps: Optional[int] = None) -> Dict[str, float]:
        n = num_steps or self.cfg.num_steps
        target = self.step_num + n
        last_metrics: Dict[str, float] = {}
        while self.step_num < target and not self._preempted:
            batch_np = self.pipeline.batch_at(self.step_num)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            self.step_num += 1
            self.pipeline.restore({"step": self.step_num})
            if self.accountant is not None:
                n_tokens = float(np.prod(batch_np["tokens"].shape)) \
                    if "tokens" in batch_np else 0.0
                self.accountant.observe_step(dt, n_tokens)
            if self.heartbeat is not None:
                self.heartbeat.beat(self.step_num, dt)
            if self.step_num % self.cfg.log_every == 0 or self.step_num == target:
                last_metrics = {k: float(v) for k, v in metrics.items()}
                last_metrics["step_time_s"] = dt
                self.metrics_log.append({"step": self.step_num, **last_metrics})
            if self.ckpt and self.step_num % self.cfg.checkpoint_every == 0:
                self.save()
        if self._preempted:
            self.save(wait=True)   # preemption: synchronous final checkpoint
        if self.ckpt:
            self.ckpt.wait()
        return last_metrics
