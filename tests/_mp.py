"""Subprocess helper for multi-device tests.

XLA locks the host-platform device count at first jax init, and the main
pytest process must stay single-device (assignment: smoke tests see 1
device). Multi-device tests therefore run their body in a fresh python
subprocess with XLA_FLAGS set.
"""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multi-device subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-3000:]}")
    return proc.stdout
