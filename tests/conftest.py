"""Shared test config.

Hypothesis shim: seven modules use property-based tests. When ``hypothesis``
is not installed (minimal CI images), install a stub that keeps the modules
importable and marks the ``@given`` tests as skipped instead of erroring the
whole collection. ``pip install -r requirements-dev.txt`` restores the real
property-based runs.
"""

import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        """Inert strategy: absorbs combinators, never generates."""

        def map(self, f):
            return self

        def filter(self, f):
            return self

        def flatmap(self, f):
            return self

        def __call__(self, *a, **k):
            return self

    def _given(*a, **k):
        def deco(f):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(f)
        return deco

    def _settings(*a, **k):
        return lambda f: f

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: (lambda *a, **k: _Strategy())

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
