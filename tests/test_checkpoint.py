"""Checkpoint manager: atomicity, async, keep-k GC, reshard-on-load."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.arange(4, dtype=jnp.float32)},
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(3)}}


class TestRoundtrip:
    def test_save_restore(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        tree = _tree()
        mgr.save(10, tree, extra={"data_state": {"step": 10}})
        step, restored, extra = mgr.restore(target=tree)
        assert step == 10 and extra["data_state"]["step"] == 10
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_selected(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        for s in (1, 5, 3):
            mgr.save(s, _tree(s))
        assert mgr.latest_step() == 5

    def test_async_save_then_wait(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=True))
        tree = _tree()
        mgr.save(1, tree)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        mgr.save(1, _tree())
        bad = {"params": {"w": jnp.zeros((9, 4)), "b": jnp.zeros(4)},
               "opt": {"m": jnp.zeros((8, 4)), "step": jnp.asarray(0)}}
        with pytest.raises(ValueError):
            mgr.restore(target=bad)


class TestGC:
    def test_keep_last_k(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep_last=2,
                                                 async_save=False))
        for s in range(5):
            mgr.save(s, _tree(s))
        assert mgr.all_steps() == [3, 4]

    def test_stale_tmp_cleaned(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        stale = tmp_path / "ckpt_00000001.tmp.abc"
        stale.mkdir()
        mgr.save(2, _tree())
        assert not stale.exists()

    def test_crash_leaves_no_partial_checkpoint(self, tmp_path):
        """Atomicity: only fully-renamed dirs count as checkpoints."""
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        mgr.save(7, _tree())
        # simulate a crashed save: tmp dir with partial content
        partial = tmp_path / "ckpt_00000009.tmp.x"
        partial.mkdir()
        (partial / "arrays.npz").write_bytes(b"garbage")
        assert mgr.all_steps() == [7]
        assert mgr.latest_step() == 7


class TestReshard:
    def test_restore_with_new_sharding(self, tmp_path):
        """Elastic restart: restore onto a different device layout."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        tree = {"w": jnp.arange(16.0).reshape(8, 2)}
        mgr.save(1, tree)
        from repro.parallel.compat import make_mesh
        mesh = make_mesh((1,), ("data",))
        shardings = {"w": NamedSharding(mesh, P("data", None))}
        step, restored, _ = mgr.restore(target=tree, shardings=shardings)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding == shardings["w"]

    def test_dtype_cast_on_restore(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        mgr.save(1, {"w": jnp.ones((4,), jnp.float32)})
        target = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
        _, restored, _ = mgr.restore(target=target)
        assert restored["w"].dtype == jnp.bfloat16
