"""Int8 error-feedback gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import compression as comp
from tests._mp import run_multidevice


class TestErrorFeedback:
    def test_ef_residual_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,))}
        ef = comp.init_ef_state(g)
        dq, ef2 = comp.compress_grads_with_ef(g, ef)
        # int8 absmax quantization: residual < scale = amax/127
        amax = float(jnp.abs(g["w"]).max())
        assert float(jnp.abs(ef2["w"]).max()) <= amax / 127 * 0.51 + 1e-6

    def test_ef_accumulates_small_signals(self):
        """A gradient smaller than one quantization step must eventually pass
        through via error feedback (the property that preserves convergence).
        Emission happens in whole quanta (scale = amax/127 ~ 0.79 here), so
        the running mean is checked within quantization granularity."""
        g = {"w": jnp.full((4,), 1e-3)}
        big = {"w": jnp.array([100.0, -100.0, 0.0, 0.0])}
        ef = comp.init_ef_state(g)
        n = 4000
        total = jnp.zeros((4,))
        for i in range(n):
            grads = {"w": big["w"] + g["w"]}
            dq, ef = comp.compress_grads_with_ef(grads, ef)
            total = total + dq["w"]
        mean = np.asarray(total) / n
        # one quantum (~0.787) per ~787 steps: mean within ~25% of 1e-3
        np.testing.assert_allclose(mean[2:], 1e-3, rtol=0.3)
        # and the residual never exceeds one quantum
        assert float(jnp.abs(ef["w"]).max()) < 100.0 / 127 + 1e-6

    def test_sgd_with_ef_converges(self):
        target = jax.random.normal(jax.random.PRNGKey(1), (64,))
        w = jnp.zeros((64,))
        ef = comp.init_ef_state({"w": w})
        for _ in range(300):
            g = {"w": 2 * (w - target)}
            dq, ef = comp.compress_grads_with_ef(g, ef)
            w = w - 0.05 * dq["w"]
        assert float(jnp.sum((w - target) ** 2)) < 1e-3


class TestRingAllreduceInt8:
    def test_matches_psum_multidevice(self):
        out = run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel import compression as comp
from repro.parallel.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("dp",))
x = jnp.arange(8 * 1000, dtype=jnp.float32).reshape(8, 1000) / 777.0

def per_rank(xs):
    return comp.ring_allreduce_int8(xs[0], "dp")

f = jax.jit(shard_map(per_rank, mesh=mesh, in_specs=P("dp"),
                          out_specs=P("dp")))
got = np.asarray(f(x)).reshape(8, 1000)   # stacked per-rank results
want = np.asarray(x.mean(0))
# every rank must hold the same reduced vector
assert np.abs(got - got[0]).max() < 1e-6
rel = np.abs(got[0] - want).max() / (np.abs(want).max() + 1e-9)
print("REL", rel)
assert rel < 0.05, rel
print("OK")
""", n_devices=8)
        assert "OK" in out
