"""Data pipeline: determinism, sharding invariance, resumability."""

import numpy as np
import pytest

from repro.data import DataConfig, TokenPipeline
from repro.data.pipeline import write_token_file


def _cfg(**kw):
    base = dict(vocab=100, seq_len=16, global_batch=8, seed=3, source="synthetic")
    base.update(kw)
    return DataConfig(**base)


class TestDeterminism:
    def test_same_step_same_batch(self):
        p1, p2 = TokenPipeline(_cfg()), TokenPipeline(_cfg())
        b1, b2 = p1.batch_at(7), p2.batch_at(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_different_steps_differ(self):
        p = TokenPipeline(_cfg())
        assert not np.array_equal(p.batch_at(1)["tokens"],
                                  p.batch_at(2)["tokens"])

    def test_labels_are_shifted_tokens(self):
        p = TokenPipeline(_cfg())
        b = p.batch_at(0)
        # labels[i] continues tokens[i]: they come from one (seq_len+1) row
        assert b["tokens"].shape == b["labels"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestSharding:
    def test_shards_partition_global_batch(self):
        full = TokenPipeline(_cfg(dp_size=1, dp_rank=0)).batch_at(5)["tokens"]
        parts = [TokenPipeline(_cfg(dp_size=4, dp_rank=r)).batch_at(5)["tokens"]
                 for r in range(4)]
        np.testing.assert_array_equal(full, np.concatenate(parts, 0))

    def test_reshard_preserves_stream(self):
        """Elastic resize mid-training keeps the global token stream."""
        p = TokenPipeline(_cfg(dp_size=2, dp_rank=0))
        p.restore({"step": 11})
        q = p.reshard(dp_rank=0, dp_size=4)
        assert q.state == {"step": 11}
        full = TokenPipeline(_cfg()).batch_at(11)["tokens"]
        np.testing.assert_array_equal(q.batch_at(11)["tokens"], full[:2])

    def test_indivisible_batch_raises(self):
        with pytest.raises(AssertionError):
            TokenPipeline(_cfg(global_batch=10, dp_size=4)).batch_at(0)


class TestResume:
    def test_state_roundtrip(self):
        p = TokenPipeline(_cfg())
        a = next(p)
        b = next(p)
        q = TokenPipeline(_cfg())
        q.restore({"step": 1})
        np.testing.assert_array_equal(next(q)["tokens"], b["tokens"])


class TestSources:
    def test_markov_learnable_structure(self):
        """Markov tokens must have non-uniform bigram stats (else the
        loss-decreases tests are meaningless)."""
        p = TokenPipeline(_cfg(source="markov", vocab=16, seq_len=256))
        toks = p.batch_at(0)["tokens"].ravel()
        big = np.zeros((16, 16))
        for a, b in zip(toks[:-1], toks[1:]):
            big[a, b] += 1
        row = big[big.sum(1) > 10]
        maxp = (row / row.sum(1, keepdims=True)).max(1)
        assert maxp.mean() > 0.3    # peaked transitions

    def test_file_source(self, tmp_path):
        path = str(tmp_path / "tokens.bin")
        write_token_file(path, np.arange(10000) % 97)
        p = TokenPipeline(_cfg(source="file", path=path, vocab=97))
        b = p.batch_at(0)
        assert b["tokens"].shape == (8, 16)
        assert b["tokens"].max() < 97
