"""Dry-run machinery on a small fake mesh (cells -> lower -> compile ->
roofline terms), via subprocess so the main process stays single-device."""

from tests._mp import run_multidevice


def test_cell_lowering_small_mesh():
    out = run_multidevice("""
import jax, jax.numpy as jnp, dataclasses
from repro.configs import base as cfgbase
from repro.launch import cells as cells_lib
from repro.launch.mesh import make_mesh
from repro.core import roofline as rl, flops as fl

mesh = make_mesh((2, 4), ("data", "model"))

# shrink the shape grid so the smoke config lowers fast
cfgbase.SHAPES["train_4k"] = dataclasses.replace(
    cfgbase.SHAPES["train_4k"], seq_len=64, global_batch=8)
cfgbase.SHAPES["decode_32k"] = dataclasses.replace(
    cfgbase.SHAPES["decode_32k"], seq_len=128, global_batch=8)

arch = cfgbase.get("gemma3-27b")
small = dataclasses.replace(arch, make_config=arch.make_smoke)

for shape_name in ("train_4k", "decode_32k"):
    cell = cells_lib.build_cell.__wrapped__ if False else None
    cell = cells_lib.build_lm_cell(small, cfgbase.SHAPES[shape_name], mesh)
    compiled = cell.lower(mesh).compile()
    terms = rl.from_compiled(compiled, 8, label=shape_name)
    analytic = fl.cost_of_fn(cell.step_fn, *cell.args_sds, n_devices=8)
    assert analytic["flops_per_device"] > 0
    ma = compiled.memory_analysis()
    print(shape_name, "ok", terms.bound, int(analytic["flops_per_device"]))
print("OK")
""", n_devices=8, timeout=900)
    assert "OK" in out
