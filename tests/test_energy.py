"""Table-3 efficiency reproduction + fleet energy model."""

import pytest

from repro.core import energy, roofline as rl


class TestTable3:
    CASES = [
        ("alexnet", "inference_ternary", "ddr3_pim", 42.4, "FPS"),
        ("alexnet", "inference_ternary", "rm_pim", 526.0, "FPS"),
        ("alexnet", "train_fp32", "gpu", 63.4, "GFLOPS"),
        ("alexnet", "train_fp32", "rm_pim", 8.97, "GFLOPS"),
        ("alexnet", "train_fp32", "fpga", 4.46, "GFLOPS"),
        ("vgg16", "train_fp32", "gpu", 41.6, "GFLOPS"),
        ("vgg16", "train_fp32", "rm_pim", 14.37, "GFLOPS"),
        ("vgg16", "train_fp32", "fpga", 6.09, "GFLOPS"),
    ]

    @pytest.mark.parametrize("bench,phase,dev,per_w,unit", CASES)
    def test_efficiency_per_watt(self, bench, phase, dev, per_w, unit):
        row = energy.table3_efficiency(bench, phase)[dev]
        assert row["per_w"] == pytest.approx(per_w, rel=0.01)

    @pytest.mark.parametrize("bench,phase,dev", [
        ("alexnet", "inference_ternary", "ddr3_pim"),
        ("alexnet", "train_fp32", "gpu"),
        ("alexnet", "train_fp32", "rm_pim"),
        ("alexnet", "train_fp32", "fpga"),
        ("vgg16", "train_fp32", "gpu"),
        ("vgg16", "train_fp32", "rm_pim"),
        ("vgg16", "train_fp32", "fpga"),
    ])
    def test_carbon_efficiency_ranges_match_paper(self, bench, phase, dev):
        row = energy.table3_efficiency(bench, phase)[dev]
        lo, hi = energy.PAPER_TABLE3_EFF[(bench, phase, dev)]
        assert row["carbon_eff_min"] == pytest.approx(lo, rel=0.02)
        assert row["carbon_eff_max"] == pytest.approx(hi, rel=0.02)

    def test_rm_inference_paper_inconsistency_flagged(self):
        """The paper's 4.6-10.8 MF/gCO2eq is ~6.5% above what its own
        526 FPS/W implies (DESIGN.md §10) — we must compute the consistent
        value, not the typo."""
        row = energy.table3_efficiency("alexnet", "inference_ternary")["rm_pim"]
        lo, hi = energy.PAPER_TABLE3_EFF[("alexnet", "inference_ternary",
                                          "rm_pim")]
        assert row["carbon_eff_min"] == pytest.approx(lo, rel=0.08)
        assert row["carbon_eff_min"] < lo   # computed value is lower
        assert row["carbon_eff_max"] == pytest.approx(hi, rel=0.08)

    def test_order_of_magnitude_rm_vs_ddr3(self):
        """Paper: RM PIM gives order-of-magnitude MF/gCO2eq over DDR3 PIM."""
        eff = energy.table3_efficiency("alexnet", "inference_ternary")
        ratio = eff["rm_pim"]["carbon_eff_min"] / eff["ddr3_pim"]["carbon_eff_min"]
        assert ratio > 10.0


class TestFleetEnergy:
    def _terms(self):
        return rl.RooflineTerms(flops_per_device=1.97e13,   # 0.1 s compute
                                bytes_per_device=40.95e9,   # 0.05 s memory
                                collective_bytes_per_device=1e9,  # 0.02 s
                                n_devices=256)

    def test_bound_and_step_time(self):
        t = self._terms()
        assert t.bound == "compute"
        assert t.step_time_s == pytest.approx(0.1)
        assert t.step_time_no_overlap_s == pytest.approx(0.17)

    def test_step_energy_scales_with_devices(self):
        t = self._terms()
        se = energy.step_energy(t)
        assert se.energy_j == pytest.approx(0.1 * 256 * 200.0)

    def test_carbon_follows_grid_mix(self):
        t = self._terms()
        se = energy.step_energy(t)
        assert se.carbon_g("TX") > se.carbon_g("NY") * 2

    def test_roofline_fraction_bounds(self):
        t = self._terms()
        model_flops = 0.8 * t.flops_per_device * t.n_devices
        frac = t.roofline_fraction(model_flops)
        assert 0 < frac <= 1.0
        assert frac == pytest.approx(0.8)

    def test_tokens_per_joule(self):
        t = self._terms()
        tpj = energy.tokens_per_joule(t, n_tokens=1e6)
        assert tpj == pytest.approx(1e6 / (0.1 * 256 * 200.0))
