"""Analytic jaxpr flops walker: exactness on known programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import flops as fl


def test_single_matmul():
    def f(a, b):
        return a @ b
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    c = fl.cost_of_fn(f, sds(32, 64), sds(64, 128))
    assert c["flops_global"] == pytest.approx(2 * 32 * 64 * 128)


def test_batched_einsum():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    c = fl.cost_of_fn(f, sds(4, 8, 16), sds(4, 16, 32))
    assert c["flops_global"] == pytest.approx(2 * 4 * 8 * 16 * 32)


def test_scan_multiplies_by_length():
    w = jnp.zeros((16, 16))

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    c = fl.cost_of_fn(f, jax.ShapeDtypeStruct((4, 16), jnp.float32))
    assert c["flops_global"] == pytest.approx(7 * 2 * 4 * 16 * 16)


def test_grad_includes_backward():
    w_sds = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    x = jnp.ones((4, 16))

    def loss(w):
        return jnp.sum(x @ w)
    c_f = fl.cost_of_fn(loss, w_sds)
    c_g = fl.cost_of_fn(jax.grad(loss), w_sds)
    assert c_g["flops_global"] >= c_f["flops_global"]


def test_remat_recompute_counted():
    w = jnp.zeros((16, 16))

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=5)
        return jnp.sum(y)
    g = jax.grad(f)
    c = fl.cost_of_fn(g, jax.ShapeDtypeStruct((4, 16), jnp.float32))
    # fwd 5 matmuls + bwd per-step recompute (1 matmul) + 2 transpose matmuls
    base = 2 * 4 * 16 * 16
    assert c["flops_global"] >= 10 * base * 0.99


def test_conv_flops():
    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.ShapeDtypeStruct((1, 8, 8, 3), jnp.float32)
    k = jax.ShapeDtypeStruct((3, 3, 3, 16), jnp.float32)
    c = fl.cost_of_fn(f, x, k)
    assert c["flops_global"] == pytest.approx(2 * 8 * 8 * 16 * 3 * 3 * 3,
                                              rel=0.01)


def test_traffic_positive_and_per_device_split():
    def f(a, b):
        return a @ b
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    c = fl.cost_of_fn(f, sds(32, 64), sds(64, 128), n_devices=4)
    assert c["traffic_bytes_global"] >= (32 * 64 + 64 * 128 + 32 * 128) * 4
    assert c["flops_per_device"] == pytest.approx(c["flops_global"] / 4)
