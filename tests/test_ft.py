"""Heartbeats, straggler detection, elastic mesh planning."""

import json
import os

import pytest

from repro.train import ft


def _beat(directory, host, step, ewma, t):
    w = ft.HeartbeatWriter(directory, host)
    w._step_time = ewma           # bypass EWMA warmup for test determinism
    w.beat(step, ewma, now=t)


class TestMonitor:
    def test_all_healthy(self, tmp_path):
        d = str(tmp_path)
        for h in ("h0", "h1", "h2"):
            _beat(d, h, 10, 1.0, t=1000.0)
        mon = ft.HealthMonitor(d)
        healthy, dead, strag = mon.assess(now=1001.0)
        assert healthy == ["h0", "h1", "h2"] and not dead and not strag

    def test_dead_host_detected(self, tmp_path):
        d = str(tmp_path)
        _beat(d, "h0", 10, 1.0, t=1000.0)
        _beat(d, "h1", 10, 1.0, t=900.0)   # stale
        mon = ft.HealthMonitor(d, ft.MonitorConfig(dead_after_s=60))
        healthy, dead, _ = mon.assess(now=1000.0)
        assert dead == ["h1"] and healthy == ["h0"]

    def test_straggler_needs_strikes(self, tmp_path):
        d = str(tmp_path)
        cfg = ft.MonitorConfig(straggler_factor=2.0, strikes_to_exclude=3)
        mon = ft.HealthMonitor(d, cfg)
        for h, t in (("h0", 1.0), ("h1", 1.0), ("h2", 5.0)):
            _beat(d, h, 10, t, t=1000.0)
        for i in range(2):
            _, _, strag = mon.assess(now=1000.0)
            assert strag == []            # not yet: strikes accumulate
        _, _, strag = mon.assess(now=1000.0)
        assert strag == ["h2"]

    def test_recovered_straggler_resets_strikes(self, tmp_path):
        d = str(tmp_path)
        cfg = ft.MonitorConfig(straggler_factor=2.0, strikes_to_exclude=2)
        mon = ft.HealthMonitor(d, cfg)
        for h, t in (("h0", 1.0), ("h1", 1.0), ("h2", 5.0)):
            _beat(d, h, 10, t, t=1000.0)
        mon.assess(now=1000.0)
        _beat(d, "h2", 11, 1.0, t=1000.5)   # recovered
        mon.assess(now=1001.0)
        _beat(d, "h2", 12, 5.0, t=1001.5)   # slow again: strikes restart at 1
        _, _, strag = mon.assess(now=1002.0)
        assert strag == []

    def test_torn_heartbeat_skipped(self, tmp_path):
        d = str(tmp_path)
        _beat(d, "h0", 3, 1.0, t=1000.0)
        with open(os.path.join(d, "h1.json"), "w") as f:
            f.write("{not json")
        mon = ft.HealthMonitor(d)
        healthy, dead, _ = mon.assess(now=1000.5)
        assert healthy == ["h0"]


class TestElasticPlanner:
    def test_full_two_pods(self):
        pl = ft.ElasticPlanner(chips_per_host=4, model_parallel=16)
        plan = pl.plan(n_healthy_hosts=128)    # 512 chips
        assert plan.mesh_shape == (2, 16, 16)
        assert plan.mesh_axes == ("pod", "data", "model")

    def test_shrink_below_pod(self):
        pl = ft.ElasticPlanner(chips_per_host=4, model_parallel=16)
        plan = pl.plan(n_healthy_hosts=50)     # 200 chips -> (12, 16) = 192
        assert plan.mesh_shape == (12, 16)
        assert plan.dp_size == 12

    def test_restart_only_on_shape_change(self):
        pl = ft.ElasticPlanner(chips_per_host=4, model_parallel=16)
        p1 = pl.plan(64)
        p2 = pl.plan(64, current=p1)
        assert p1.restart_required and not p2.restart_required

    def test_infeasible_raises(self):
        pl = ft.ElasticPlanner(chips_per_host=1, model_parallel=16)
        with pytest.raises(RuntimeError):
            pl.plan(8)
