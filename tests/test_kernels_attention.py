"""Flash-attention Pallas kernel vs. the jnp oracle: shape/mask/GQA sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # (b, sq, h, hkv, d)
    (1, 128, 4, 4, 64),
    (2, 256, 8, 2, 64),
    (1, 128, 4, 1, 128),
    (2, 128, 2, 2, 32),
]


@pytest.mark.parametrize("b,s,h,hkv,d", CASES)
@pytest.mark.parametrize("window", [-1, 32], ids=["global", "win32"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_flash_matches_oracle(b, s, h, hkv, d, window, dtype):
    key = jax.random.PRNGKey(s + h)
    q = jax.random.normal(key, (b, s, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    expect = ref.attention_ref(q, k, v, scale=d ** -0.5, causal=True,
                               window=window)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=atol)


def test_ragged_seq_padding():
    """Non-multiple sequence lengths go through the padded path."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 100, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 100, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 100, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True)
    expect = ref.attention_ref(q, k, v, scale=32 ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_noncausal_small():
    key = jax.random.PRNGKey(4)
    q = jax.random.normal(key, (1, 64, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 2, 32))
    out = ops.flash_attention(q, k, v, causal=False)
    expect = ref.attention_ref(q, k, v, scale=32 ** -0.5, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_online_softmax_stability():
    """Large logits must not overflow the running max/denominator."""
    key = jax.random.PRNGKey(5)
    q = 30.0 * jax.random.normal(key, (1, 128, 2, 64))
    k = 30.0 * jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True)
    assert bool(jnp.isfinite(out).all())
    expect = ref.attention_ref(q, k, v, scale=64 ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-4)
