"""Fwd+bwd parity matrix for the custom-VJP Pallas kernels (DESIGN.md §13).

The contract that makes the training fast path trustworthy: for every
(dtype, block shape, odd/even sequence length, mask mode) cell,
``jax.grad`` through the custom-VJP kernel wrappers must match ``jax.grad``
through the pure-jnp references in kernels/ref.py within per-dtype
tolerance. Kernels run in interpret mode (bit-accurate kernel-body
semantics) so the matrix is CPU-checkable.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref as kref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=6e-2, atol=6e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _flash_grads(q, k, v, ct, *, scale, causal, window, block_q, block_k):
    def f(q, k, v):
        out = kops.flash_attention_train(
            q, k, v, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k)
        return jnp.sum(out.astype(jnp.float32) * ct)
    return jax.grad(f, argnums=(0, 1, 2))(q, k, v)


def _ref_grads(q, k, v, ct, *, scale, causal, window):
    def f(q, k, v):
        out = kref.attention_ref(q, k, v, scale=scale, causal=causal,
                                 window=window)
        return jnp.sum(out.astype(jnp.float32) * ct)
    return jax.grad(f, argnums=(0, 1, 2))(q, k, v)


# -- flash attention: dtype x block x odd-length x mask matrix ----------------

FLASH_CASES = [
    # (sq, sk, h, hkv, d, causal, window, block_q, block_k)
    (16, 16, 2, 2, 8, True, -1, 8, 8),          # aligned, MHA
    (16, 16, 4, 2, 8, True, -1, 8, 8),          # GQA
    (13, 13, 2, 1, 8, True, -1, 8, 8),          # odd seq -> padded blocks
    (24, 24, 2, 2, 8, True, 7, 8, 8),           # sliding window
    (16, 16, 2, 2, 8, False, -1, 8, 8),         # non-causal
    (13, 16, 2, 2, 8, True, -1, 8, 16),         # sq < sk (chunked prefill)
    (16, 16, 2, 2, 16, True, -1, 16, 8),        # asymmetric blocks
    (9, 9, 2, 2, 8, True, 4, 8, 8),             # odd + window
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize(
    "sq,sk,h,hkv,d,causal,window,bq,bk", FLASH_CASES,
    ids=[f"sq{c[0]}sk{c[1]}h{c[2]}kv{c[3]}d{c[4]}"
         f"{'c' if c[5] else 'f'}w{c[6]}b{c[7]}x{c[8]}" for c in FLASH_CASES])
def test_flash_attention_grad_parity(sq, sk, h, hkv, d, causal, window,
                                     bq, bk, dtype):
    rng = np.random.default_rng(hash((sq, sk, h, causal, window)) % 2**32)
    b = 2
    q = _rand(rng, (b, sq, h, d), dtype)
    k = _rand(rng, (b, sk, hkv, d), dtype)
    v = _rand(rng, (b, sk, hkv, d), dtype)
    ct = _rand(rng, (b, sq, h, d), jnp.float32)
    scale = 0.4
    got = _flash_grads(q, k, v, ct, scale=scale, causal=causal,
                       window=window, block_q=bq, block_k=bk)
    want = _ref_grads(q, k, v, ct, scale=scale, causal=causal, window=window)
    for name, g, w in zip("qkv", got, want):
        assert g.dtype == w.dtype, (name, g.dtype, w.dtype)
        np.testing.assert_allclose(
            np.asarray(g, np.float32), np.asarray(w, np.float32),
            **TOL[dtype], err_msg=f"d{name}")


def test_flash_attention_fwd_matches_inference_wrapper():
    """The trainable wrapper's forward is the same kernel math as the
    serving wrapper (no train/serve numerics drift)."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 13, 4, 8), jnp.float32)
    k = _rand(rng, (2, 13, 2, 8), jnp.float32)
    v = _rand(rng, (2, 13, 2, 8), jnp.float32)
    a = kops.flash_attention_train(q, k, v, scale=0.35)
    b = kops.flash_attention(q, k, v, scale=0.35)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_flash_attention_grad_jits():
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 16, 2, 8), jnp.float32)
    k = _rand(rng, (1, 16, 2, 8), jnp.float32)
    v = _rand(rng, (1, 16, 2, 8), jnp.float32)

    @jax.jit
    def g(q, k, v):
        return jax.grad(lambda q: jnp.sum(
            kops.flash_attention_train(q, k, v, scale=0.3)))(q)

    want = jax.grad(lambda q: jnp.sum(
        kops.flash_attention_train(q, k, v, scale=0.3)))(q)
    np.testing.assert_allclose(np.asarray(g(q, k, v)), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# -- int8 matmul: dtype x block x ragged-shape matrix -------------------------

INT8_CASES = [
    # (m, k, n, block_n, block_k)
    (8, 32, 16, 16, 32),            # aligned
    (5, 40, 24, 16, 32),            # ragged everything
    (3, 17, 9, 8, 16),              # tiny + odd
    (16, 64, 32, 32, 64),           # bigger blocks
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["fp32", "bf16"])
@pytest.mark.parametrize("m,k,n,bn,bk", INT8_CASES,
                         ids=[f"m{c[0]}k{c[1]}n{c[2]}b{c[3]}x{c[4]}"
                              for c in INT8_CASES])
def test_int8_matmul_grad_parity(m, k, n, bn, bk, dtype):
    rng = np.random.default_rng(hash((m, k, n)) % 2**32)
    x = _rand(rng, (m, k), dtype)
    q = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.01, 0.1, (n,)), jnp.float32)
    ct = _rand(rng, (m, n), jnp.float32)

    def f_kernel(x):
        y = kops.int8_matmul_train(x, q, scale, block_n=bn, block_k=bk)
        return jnp.sum(y.astype(jnp.float32) * ct)

    def f_ref(x):
        y = kref.ternary_matmul_ref(x, q, scale, out_dtype=jnp.float32)
        return jnp.sum(y * ct)

    gx = jax.grad(f_kernel)(x)
    rx = jax.grad(f_ref)(x)
    assert gx.dtype == x.dtype
    tol = dict(TOL[dtype])
    if dtype == jnp.bfloat16:
        # bf16 grads differ only by accumulation-order rounding; compare at
        # the scale of the gradient (near-zero elements cancel differently)
        tol["atol"] = 0.02 * float(np.max(np.abs(np.asarray(rx, np.float32))))
    np.testing.assert_allclose(np.asarray(gx, np.float32),
                               np.asarray(rx, np.float32), **tol)


def test_int8_matmul_dscale_parity():
    """scale gets a real gradient, recovered from the saved fp32 output."""
    rng = np.random.default_rng(7)
    x = _rand(rng, (6, 32), jnp.float32)
    q = jnp.asarray(rng.integers(-127, 128, (32, 16)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.02, 0.2, (16,)), jnp.float32)
    ct = _rand(rng, (6, 16), jnp.float32)

    gs = jax.grad(lambda s: jnp.sum(
        kops.int8_matmul_train(x, q, s, block_n=16, block_k=32) * ct))(scale)
    rs = jax.grad(lambda s: jnp.sum(
        (x @ q.astype(jnp.float32)) * s * ct))(scale)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(rs),
                               rtol=1e-4, atol=1e-4)


def test_int8_matmul_codes_not_differentiable():
    """The int8 codes are frozen: their cotangent is symbolic-zero (float0),
    and grads wrt x still flow through a jit boundary."""
    rng = np.random.default_rng(8)
    x = _rand(rng, (4, 32), jnp.float32)
    q = jnp.asarray(rng.integers(-127, 128, (32, 16)), jnp.int8)
    scale = jnp.ones((16,), jnp.float32)

    @jax.jit
    def g(x):
        return jax.grad(lambda x: jnp.sum(
            kops.int8_matmul_train(x, q, scale, block_n=16, block_k=32)))(x)

    assert g(x).shape == x.shape
    out, vjp = jax.vjp(
        lambda x, q, s: kops.int8_matmul_train(x, q, s, block_n=16,
                                               block_k=32), x, q, scale)
    dx, dq, ds = vjp(jnp.ones_like(out))
    assert dq.dtype == jax.dtypes.float0
    assert dx.shape == x.shape and ds.shape == scale.shape


# -- the model-level route: attention() with flash_vjp on ---------------------

def test_attention_layer_flash_vjp_grad_parity():
    """layers.attention with cfg.flash_vjp routes through the kernel; its
    grads wrt the projection weights match the sdpa path."""
    from repro.models import layers

    cfg = dict(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16)
    acfg_ref = layers.AttnConfig(**cfg)
    acfg_fast = layers.AttnConfig(**cfg, flash_vjp=True)
    key = jax.random.PRNGKey(0)
    params = layers.init_attention(key, acfg_ref, jnp.float32).params
    x = jnp.asarray(np.random.default_rng(3).standard_normal((2, 12, 32)),
                    jnp.float32)

    def loss(p, acfg):
        return jnp.sum(jnp.square(layers.attention(p, acfg, x)))

    g_ref = jax.grad(lambda p: loss(p, acfg_ref))(params)
    g_fast = jax.grad(lambda p: loss(p, acfg_fast))(params)
    flat_ref = jax.tree_util.tree_leaves_with_path(g_ref)
    flat_fast = dict(jax.tree_util.tree_leaves_with_path(g_fast))
    assert flat_ref and len(flat_ref) == len(flat_fast)
    for path, a in flat_ref:
        np.testing.assert_allclose(np.asarray(a),
                                   np.asarray(flat_fast[path]),
                                   rtol=2e-4, atol=2e-4, err_msg=str(path))
