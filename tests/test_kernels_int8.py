"""Int8 serving kernels: fused matmul + int8-KV attention (DESIGN.md §12).

Interpret-mode validation against dequantize-then-compute oracles: the
kernels keep int8 in memory and widen in-register, so their outputs must
match the XLA fallback (wl()/dequant + einsum/SDPA) to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import layers
from repro.quant import int8 as q8


class TestInt8Matmul:
    @pytest.mark.parametrize("m,k,n", [(8, 64, 32), (10, 48, 33), (1, 128, 7)])
    def test_matches_dequant_oracle(self, m, k, n):
        rng = np.random.default_rng(m + k + n)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        wq = q8.quantize_weight(w)
        got = kops.int8_matmul(x, wq["q8"], wq["s8"])
        want = x @ (wq["q8"].astype(jnp.float32) * wq["s8"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)

    def test_scalar_scale(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
        iw = q8.quantize(w, axis=-1)  # per-channel Int8Weight
        got = kops.int8_matmul(x, iw.q, jnp.asarray(0.5))
        want = x @ (iw.q.astype(jnp.float32) * 0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)

    def test_q8_matmul_layer_helper_3d(self):
        """q8_matmul reshapes (d,h,dh) / (h,dh,d) weights through the 2D
        kernel and matches the wl()+einsum fallback."""
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((2, 5, 48)), jnp.float32)
        wq = q8.quantize_weight(
            jnp.asarray(rng.standard_normal((48, 4, 12)), jnp.float32),
            out_dims=2)
        wo = q8.quantize_weight(
            jnp.asarray(rng.standard_normal((4, 12, 48)), jnp.float32),
            out_dims=1)
        got = layers.q8_matmul(x, wq)
        want = jnp.einsum("bsd,dhk->bshk", x, layers.wl(wq, jnp.float32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)
        got_o = layers.q8_matmul(got, wo, contract_ndim=2)
        want_o = jnp.einsum("bshk,hkd->bsd", want,
                            layers.wl(wo, jnp.float32))
        np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                                   atol=2e-3)


def _quantized_kv(rng, b, s, hkv, d):
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    kq, ks = q8.quantize_rowwise(k)
    vq, vs = q8.quantize_rowwise(v)
    kd = kq.astype(jnp.float32) * ks[..., None]
    vd = vq.astype(jnp.float32) * vs[..., None]
    return (kq, ks, kd), (vq, vs, vd)


class TestInt8DecodeAttention:
    def test_matches_dequant_sdpa_ragged_lengths(self):
        """Int8-KV kernel vs the tag-masked SDPA over the dequantized cache:
        ragged lengths incl. a dead slot, global + windowed."""
        rng = np.random.default_rng(3)
        b, s, h, hkv, d = 4, 24, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((b, 1, h, d)), jnp.float32)
        (kq, ks, kd), (vq, vs, vd) = _quantized_kv(rng, b, s, hkv, d)
        lens = jnp.asarray([24, 10, 0, 1], jnp.int32)
        for window in (-1, 6):
            got = kops.decode_attention(q[:, 0], kq, vq, lens, scale=0.25,
                                        window=window, interpret=True,
                                        k_scale=ks, v_scale=vs)
            tags = jnp.where(jnp.arange(s)[None] < lens[:, None],
                             jnp.arange(s)[None], -1)
            mask = layers.attention_mask((lens - 1)[:, None], tags,
                                         causal=True, window=window)
            mask &= (tags >= 0)[:, None, :]
            want = layers.sdpa(q, kd, vd, mask, 0.25)[:, 0]
            live = np.asarray(lens) > 0
            err = np.abs(np.asarray(got)[live] - np.asarray(want)[live]).max()
            assert err < 1e-5, (window, err)
            assert np.abs(np.asarray(got)[~live]).max() == 0.0

    def test_scales_required_in_pairs(self):
        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)
        (kq, ks, _), (vq, _, _) = _quantized_kv(rng, 2, 8, 2, 8)
        with pytest.raises(AssertionError):
            kops.decode_attention(q, kq, vq, jnp.asarray([8, 8]), scale=0.35,
                                  interpret=True, k_scale=ks, v_scale=None)


class TestInt8FlashAttention:
    @pytest.mark.parametrize("window", [-1, 5])
    def test_matches_dequant_reference(self, window):
        rng = np.random.default_rng(5)
        b, s, h, hkv, d = 2, 16, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        (kq, ks, kd), (vq, vs, vd) = _quantized_kv(rng, b, s, hkv, d)
        got = kops.flash_attention(q, kq, vq, scale=0.25, causal=True,
                                   window=window, k_scale=ks, v_scale=vs)
        want = kref.attention_ref(q, kd, vd, scale=0.25, causal=True,
                                  window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_padded_seq_lengths(self):
        """ops wrapper pads K/V AND the scale arrays to block multiples."""
        rng = np.random.default_rng(6)
        b, s, h, hkv, d = 1, 11, 2, 1, 8
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        (kq, ks, kd), (vq, vs, vd) = _quantized_kv(rng, b, s, hkv, d)
        got = kops.flash_attention(q, kq, vq, scale=0.3, causal=True,
                                   k_scale=ks, v_scale=vs)
        want = kref.attention_ref(q, kd, vd, scale=0.3, causal=True,
                                  window=-1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
