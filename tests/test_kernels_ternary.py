"""Ternary-matmul Pallas kernel vs. the jnp oracle: shape/dtype sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.quant import ternary

SHAPES = [(8, 512, 128), (128, 512, 128), (64, 1024, 256), (100, 300, 50),
          (1, 512, 128), (256, 128, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_kernel_matches_oracle(m, k, n, dtype):
    key = jax.random.PRNGKey(m * 31 + n)
    x = jax.random.normal(key, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    tw = ternary.ternarize(w)
    y = ops.ternary_matmul(x, tw)
    y_ref = ref.ternary_matmul_ref(x, tw.q, tw.scale)
    scale = max(float(jnp.abs(y_ref).max()), 1e-6)
    np.testing.assert_allclose(np.asarray(y, np.float32) / scale,
                               np.asarray(y_ref, np.float32) / scale,
                               atol=1e-6)


def test_leading_batch_dims():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 3, 256), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (256, 64))
    tw = ternary.ternarize(w)
    y = ops.ternary_matmul(x, tw)
    assert y.shape == (2, 3, 64)
    y_ref = ref.ternary_matmul_ref(x.reshape(-1, 256), tw.q, tw.scale)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 64)),
                               np.asarray(y_ref), atol=1e-5)


def test_binary_weights_path():
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (16, 512), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (512, 128))
    tw = ternary.binarize(w)
    assert int(jnp.sum(tw.q == 0)) == 0          # binary: no zeros
    y = ops.ternary_matmul(x, tw)
    y_ref = ref.ternary_matmul_ref(x, tw.q, tw.scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_bias_fusion():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (8, 256), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (256, 32))
    b = jax.random.normal(jax.random.fold_in(key, 2), (32,))
    tw = ternary.ternarize(w)
    y = ops.ternary_dense(x, tw, bias=b)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.ternary_matmul_ref(x, tw.q, tw.scale) + b),
        atol=1e-5)


@given(st.integers(1, 48), st.integers(1, 8).map(lambda i: i * 64),
       st.integers(1, 4).map(lambda i: i * 32))
@settings(max_examples=10, deadline=None)
def test_property_random_shapes(m, k, n):
    key = jax.random.PRNGKey(m + k + n)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    tw = ternary.ternarize(w)
    y = ops.ternary_matmul(x, tw, block_k=64, block_n=32)
    y_ref = ref.ternary_matmul_ref(x, tw.q, tw.scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)
