"""Layer-level unit + property tests (norms, RoPE, GQA attention, chunking)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers
from repro.models.layers import AttnConfig


class TestNorms:
    def test_rms_norm_unit_variance(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 5 + 2
        y = layers.rms_norm({"scale": jnp.ones(64)}, x)
        ms = jnp.mean(jnp.square(y), axis=-1)
        assert jnp.allclose(ms, 1.0, atol=1e-2)

    def test_rms_custom_vjp_matches_autodiff(self):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (2, 8, 32))
        sc = jax.random.normal(jax.random.fold_in(key, 1), (32,)) * 0.1 + 1.0

        def ref(x, sc):
            x32 = x.astype(jnp.float32)
            var = jnp.mean(jnp.square(x32), -1, keepdims=True)
            return jnp.sum(jnp.sin(x32 * jax.lax.rsqrt(var + 1e-6) * sc))

        def mine(x, sc):
            return jnp.sum(jnp.sin(layers.rms_norm({"scale": sc}, x)))

        g1 = jax.grad(ref, (0, 1))(x, sc)
        g2 = jax.grad(mine, (0, 1))(x, sc)
        np.testing.assert_allclose(g1[0], g2[0], atol=2e-5)
        np.testing.assert_allclose(g1[1], g2[1], atol=2e-5)

    def test_layer_norm_zero_mean_unit_var(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 64)) * 3 + 7
        p = {"scale": jnp.ones(64), "bias": jnp.zeros(64)}
        y = layers.layer_norm(p, x)
        assert jnp.allclose(jnp.mean(y, -1), 0.0, atol=1e-2)
        assert jnp.allclose(jnp.var(y, -1), 1.0, atol=2e-2)


class TestRoPE:
    def test_relative_position_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))

        def dot_at(m, n):
            qm = layers.apply_rope(q, jnp.array([[m]], jnp.float32))
            kn = layers.apply_rope(k, jnp.array([[n]], jnp.float32))
            return float(jnp.sum(qm * kn))

        assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), abs=1e-3)
        assert dot_at(0, 0) == pytest.approx(dot_at(77, 77), abs=1e-3)

    def test_norm_preserved(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 6, 4, 64))
        pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
        y = layers.apply_rope(x, pos)
        np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                                   jnp.linalg.norm(y, axis=-1), rtol=1e-4)

    def test_mrope_equals_rope_when_positions_equal(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
        pos3 = jnp.broadcast_to(pos[..., None], (2, 6, 3))
        y1 = layers.apply_rope(x, pos)
        y2 = layers.apply_mrope(x, pos3, (6, 5, 5))
        np.testing.assert_allclose(y1, y2, atol=1e-5)


def _mk_attn(h, kv, dh=16, d=32, window=-1, qkv_bias=False):
    cfg = AttnConfig(d_model=d, n_heads=h, n_kv_heads=kv, head_dim=dh,
                     window=window, qkv_bias=qkv_bias)
    params = layers.init_attention(jax.random.PRNGKey(7), cfg, jnp.float32)
    return cfg, params.params


class TestAttention:
    def test_gqa_equals_mha_when_kv_equals_heads(self):
        """GQA grouping must be exact replication math, not approximate."""
        cfg, p = _mk_attn(4, 4)
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 10, 32))
        y = layers.attention(p, cfg, x)
        # manual MHA with same params
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
        q, k = layers.apply_rope(q, pos), layers.apply_rope(k, pos)
        mask = layers.attention_mask(pos, pos, causal=True, window=-1)
        out = layers.sdpa(q, k, v, mask, cfg.scale)
        y2 = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        np.testing.assert_allclose(y, y2, atol=1e-5)

    def test_causality(self):
        """Changing a future token cannot change past outputs."""
        cfg, p = _mk_attn(2, 1)
        x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, 32))
        y1 = layers.attention(p, cfg, x)
        x2 = x.at[:, 6].set(99.0)
        y2 = layers.attention(p, cfg, x2)
        np.testing.assert_allclose(y1[:, :6], y2[:, :6], atol=1e-5)

    def test_window_masks_far_context(self):
        """With window w, token t ignores tokens < t-w+1."""
        cfg, p = _mk_attn(2, 2, window=3)
        x = jax.random.normal(jax.random.PRNGKey(10), (1, 12, 32))
        y1 = layers.attention(p, cfg, x)
        x2 = x.at[:, 0:4].set(7.7)     # outside window of the last token
        y2 = layers.attention(p, cfg, x2)
        np.testing.assert_allclose(y1[:, -1], y2[:, -1], atol=1e-5)

    @given(st.integers(1, 4).map(lambda g: (4 * g, g)))
    @settings(max_examples=8, deadline=None)
    def test_gqa_group_counts(self, hg):
        h, kv = hg
        cfg, p = _mk_attn(h, kv)
        x = jax.random.normal(jax.random.PRNGKey(11), (1, 6, 32))
        y = layers.attention(p, cfg, x)
        assert y.shape == (1, 6, 32)
        assert bool(jnp.isfinite(y).all())

    def test_chunked_equals_dense(self):
        key = jax.random.PRNGKey(12)
        q = jax.random.normal(key, (2, 100, 4, 16))
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, 100, 2, 16))
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, 100, 2, 16))
        pos = jnp.broadcast_to(jnp.arange(100)[None], (2, 100))
        mask = layers.attention_mask(pos, pos, causal=True, window=17)
        ref = layers.sdpa(q, k, v, mask, 0.25)
        chk = layers.sdpa_q_chunked(q, k, v, pos, pos, causal=True, window=17,
                                    scale=0.25, chunk=32)
        np.testing.assert_allclose(ref, chk, atol=2e-5)

    def test_decode_matches_full(self):
        cfg, p = _mk_attn(2, 2)
        x = jax.random.normal(jax.random.PRNGKey(13), (2, 6, 32))
        full = layers.attention(p, cfg, x)
        cache = layers.init_kv_cache(2, 6, 2, 16, jnp.float32)
        outs = []
        for t in range(6):
            y, cache = layers.attention_decode(p, cfg, x[:, t:t + 1], cache,
                                               jnp.asarray(t))
            outs.append(y)
        np.testing.assert_allclose(full, jnp.concatenate(outs, 1), atol=1e-5)
