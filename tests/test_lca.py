"""Paper Table 1 + Table 2 reproduction (hard oracles) + LCA properties."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import grid, hw, lca


class TestGridMixes:
    def test_paper_mix_row_exact(self):
        """Table 1 Mix row: AZ 395 / CA 234 / TX 438 / NY 188 gCO2eq/kWh."""
        for state, expected in grid.PAPER_MIX_ROW.items():
            got = grid.mix_intensity(state)
            assert got == pytest.approx(expected, abs=0.55), (state, got)

    def test_range_over_states(self):
        lo, hi = grid.intensity_range()
        assert lo == pytest.approx(188.0, abs=0.5)
        assert hi == pytest.approx(438.3, abs=0.5)

    def test_unknown_state_raises(self):
        with pytest.raises(KeyError):
            grid.mix_intensity("ZZ")

    @given(st.floats(0.01, 1.0))
    @settings(max_examples=25, deadline=None)
    def test_mix_bounded_by_sources(self, frac):
        mix = {"coal": frac}
        val = grid.mix_intensity(mix)
        assert 0 < val <= 980.0 * frac + 1e-9

    def test_joules_kwh_consistency(self):
        assert grid.joules_to_gco2(3.6e6, "NY") == pytest.approx(
            grid.kwh_to_gco2(1.0, "NY"))


class TestTable2:
    def test_pe_kwh_per_wafer(self):
        t2 = lca.table2()
        for label, row in t2.items():
            assert row["pe_kwh"] == pytest.approx(
                lca.PAPER_TABLE2[label]["pe_kwh"], rel=1e-6), label

    def test_embodied_energy_mj_per_die(self):
        t2 = lca.table2()
        for label, row in t2.items():
            assert row["mj_die"] == pytest.approx(
                lca.PAPER_TABLE2[label]["mj_die"], rel=0.005), label

    def test_embodied_carbon_all_grids(self):
        t2 = lca.table2()
        for label, row in t2.items():
            ref = lca.PAPER_TABLE2[label]
            for state in ("az", "ca", "tx", "ny"):
                assert row[state] == pytest.approx(ref[state], rel=0.011), (
                    label, state, row[state], ref[state])

    def test_dies_per_wafer_published(self):
        assert lca.dies_per_wafer(hw.RM_PIM) == 1847
        assert lca.dies_per_wafer(hw.DDR3_PIM) == 967

    def test_geometric_dies_close_to_published(self):
        for spec in (hw.RM_PIM, hw.DDR3_PIM, hw.VERSAL_VM1802, hw.JETSON_NX):
            geo = lca.dies_per_wafer_geometric(spec.die_area_mm2)
            assert abs(geo - spec.dies_per_wafer_published) \
                / spec.dies_per_wafer_published < 0.01, spec.name

    def test_spintronic_adder_applied_to_rm_only(self):
        with_spin = lca.wafer_energy_kwh(hw.RM_PIM, study="boyd2011")
        without = lca.wafer_energy_kwh(hw.RM_PIM, study="boyd2011",
                                       spintronic=False)
        assert with_spin - without == pytest.approx(
            lca.SPINTRONIC_EXTRA_KWH_PER_WAFER)

    def test_study_mixing_guard(self):
        """The paper never crosses studies outside their node range."""
        with pytest.raises(ValueError):
            lca.STUDIES["boyd2011"].energy_kwh(7.0)   # boyd stops at 32 nm
        with pytest.raises(ValueError):
            lca.STUDIES["bardon2020"].energy_kwh(55.0)

    @given(st.floats(3.0, 28.0))
    @settings(max_examples=30, deadline=None)
    def test_bardon_monotone_below_28(self, node):
        """finer node -> more energy per wafer (EUV/multi-patterning trend)."""
        e1 = lca.STUDIES["bardon2020"].energy_kwh(node)
        e2 = lca.STUDIES["bardon2020"].energy_kwh(min(node + 2.0, 28.0))
        assert e1 >= e2 - 1e-9

    def test_module_energy_is_16x_die(self):
        die = lca.embodied_energy_mj(hw.DDR3_PIM)
        module = lca.embodied_energy_mj(hw.DDR3_PIM, per_module=True)
        assert module == pytest.approx(16 * die)

    def test_tpu_package_estimate_sane(self):
        mj = lca.tpu_package_embodied_mj()
        # logic die alone is ~30 MJ at 5 nm; package must exceed it but stay
        # within an order of magnitude of the GPU die estimate
        assert 30.0 < mj < 250.0
